"""Jitted scout engine — Algorithm 1 as a ``lax.while_loop`` state machine.

Semantics are decision-for-decision identical to ``routing.scout_route_ref``
(same xorshift32 tie-break stream); ``tests/test_routing.py`` enforces parity
over thousands of randomized (mesh, occupancy, src, dst, seed) cases.

The engine is written to be embedded in the SSD simulator's ``lax.scan`` over
I/O transactions: all state is fixed-shape, the DFS is bounded by the paper's
livelock rule (each output port of each router reservable at most once per
scout ⇒ ≤ 4·n_nodes pushes), and the result exposes the reserved path as a
link *mask* so the caller can commit occupancy with one vector op.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rng import xorshift32_jax
from repro.core.topology import MeshTopology, OPPOSITE

RIGHT, UP, LEFT, DOWN = 0, 1, 2, 3


class ScoutTables(NamedTuple):
    """Static mesh tables as device constants (closed over by jit)."""

    port_link: jnp.ndarray  # [n_nodes, 4] int32, -1 = off mesh
    port_neighbor: jnp.ndarray  # [n_nodes, 4] int32
    cols: int
    n_nodes: int
    n_links: int
    stack_cap: int


def make_tables(topo: MeshTopology) -> ScoutTables:
    return ScoutTables(
        port_link=jnp.asarray(topo.port_link, dtype=jnp.int32),
        port_neighbor=jnp.asarray(topo.port_neighbor, dtype=jnp.int32),
        cols=topo.cols,
        n_nodes=topo.n_nodes,
        n_links=topo.n_links,
        stack_cap=4 * topo.n_nodes,
    )


class ScoutState(NamedTuple):
    cur: jnp.ndarray  # int32 node
    entry: jnp.ndarray  # int32 port we arrived on (-1 at source)
    busy: jnp.ndarray  # bool [n_links] — global occupancy + our reservations
    tried: jnp.ndarray  # bool [n_nodes*4]
    stack_node: jnp.ndarray  # int32 [cap]
    stack_entry: jnp.ndarray  # int32 [cap]
    stack_exit: jnp.ndarray  # int32 [cap]
    stack_mis: jnp.ndarray  # bool [cap] — was the hop a misroute?
    depth: jnp.ndarray  # int32
    rng: jnp.ndarray  # uint32
    steps: jnp.ndarray  # int32
    backtracks: jnp.ndarray  # int32
    done: jnp.ndarray  # bool
    success: jnp.ndarray  # bool


class ScoutOut(NamedTuple):
    success: jnp.ndarray  # bool
    path_mask: jnp.ndarray  # bool [n_links] — links of the reserved path
    hops: jnp.ndarray  # int32 — path length (= reserved links)
    steps: jnp.ndarray  # int32 — DFS steps (scout latency proxy)
    backtracks: jnp.ndarray  # int32
    misroutes: jnp.ndarray  # int32 — non-minimal hops on the final path
    dst_entry_port: jnp.ndarray  # int32 — port the scout entered the dst on


def _port_free(t: ScoutTables, st: ScoutState, node, port):
    """port>=0, on-mesh, link unreserved, not yet tried from this node."""
    p = jnp.maximum(port, 0)
    lnk = t.port_link[node, p]
    ok = (port >= 0) & (lnk >= 0)
    ok &= ~st.busy[jnp.maximum(lnk, 0)]
    ok &= ~st.tried[node * 4 + p]
    return ok


def scout_route(
    t: ScoutTables,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    link_busy: jnp.ndarray,
    seed: jnp.ndarray,
    allow_nonminimal: bool | jnp.ndarray = True,
) -> ScoutOut:
    """Route one scout; returns the reserved path as a link mask.

    ``link_busy`` (bool, at least [n_links] — padded tails are ignored) is
    the occupancy snapshot at the scout's send time.  Purely functional —
    the caller commits ``path_mask``.  ``allow_nonminimal`` may be a traced
    bool (the table-driven simulator batches designs that differ in it);
    ``src == dst`` degenerates to an immediate 0-hop success, which is how
    routing-disabled (bus) lanes share this engine.
    """
    cap = t.stack_cap
    st = ScoutState(
        cur=jnp.asarray(src, jnp.int32),
        entry=jnp.int32(-1),
        busy=link_busy,
        tried=jnp.zeros((t.n_nodes * 4,), dtype=bool),
        stack_node=jnp.zeros((cap,), jnp.int32),
        stack_entry=jnp.zeros((cap,), jnp.int32),
        stack_exit=jnp.zeros((cap,), jnp.int32),
        stack_mis=jnp.zeros((cap,), bool),
        depth=jnp.int32(0),
        rng=jnp.asarray(seed, jnp.uint32),
        steps=jnp.int32(0),
        backtracks=jnp.int32(0),
        done=jnp.bool_(False),
        success=jnp.bool_(False),
    )
    dst = jnp.asarray(dst, jnp.int32)

    def cond(st: ScoutState):
        return ~st.done

    def body(st: ScoutState) -> ScoutState:
        at_dst = st.cur == dst
        # --- minimal ports (x candidate then y candidate, as in the ref) ---
        diffx = dst % t.cols - st.cur % t.cols
        diffy = dst // t.cols - st.cur // t.cols
        px = jnp.where(diffx > 0, RIGHT, jnp.where(diffx < 0, LEFT, -1))
        py = jnp.where(diffy > 0, UP, jnp.where(diffy < 0, DOWN, -1))
        fmin = jnp.stack([_port_free(t, st, st.cur, px), _port_free(t, st, st.cur, py)])
        n_min = fmin.sum()
        # --- misroute ports: any free port except the entry (RIGHT,UP,LEFT,DOWN)
        ports4 = jnp.arange(4, dtype=jnp.int32)
        fmis = jax.vmap(lambda p: _port_free(t, st, st.cur, p))(ports4)
        fmis &= ports4 != st.entry
        # static or traced flag: minimal-only mode masks every misroute port
        fmis &= jnp.asarray(allow_nonminimal)
        n_mis = fmis.sum()

        use_min = n_min > 0
        count = jnp.where(use_min, n_min, n_mis).astype(jnp.int32)
        need_rng = (~at_dst) & (count > 1)
        rng_next = jnp.where(need_rng, xorshift32_jax(st.rng), st.rng)
        # Unsigned modulo to match the reference's python-int (non-negative) mod.
        idx = (rng_next % jnp.maximum(count, 1).astype(jnp.uint32)).astype(jnp.int32)

        cand_ports = jnp.concatenate([jnp.stack([px, py]), ports4])
        cand_flags = jnp.concatenate(
            [fmin & use_min, fmis & ~use_min]
        )
        cum = jnp.cumsum(cand_flags.astype(jnp.int32))
        sel = cand_flags & (cum - 1 == idx)
        pick = jnp.sum(jnp.where(sel, cand_ports, 0)).astype(jnp.int32)
        is_mis = ~use_min
        has_pick = (count > 0) & ~at_dst

        def finish(s: ScoutState) -> ScoutState:
            return s._replace(done=True, success=True)

        def advance(s: ScoutState) -> ScoutState:
            lnk = t.port_link[s.cur, pick]
            return s._replace(
                busy=s.busy.at[lnk].set(True),
                tried=s.tried.at[s.cur * 4 + pick].set(True),
                stack_node=s.stack_node.at[s.depth].set(s.cur),
                stack_entry=s.stack_entry.at[s.depth].set(s.entry),
                stack_exit=s.stack_exit.at[s.depth].set(pick),
                stack_mis=s.stack_mis.at[s.depth].set(is_mis),
                depth=s.depth + 1,
                entry=OPPOSITE_J[pick],
                cur=t.port_neighbor[s.cur, pick],
            )

        def backtrack(s: ScoutState) -> ScoutState:
            def fail(s: ScoutState) -> ScoutState:
                return s._replace(done=True, success=False)

            def pop(s: ScoutState) -> ScoutState:
                d = s.depth - 1
                pnode = s.stack_node[d]
                pexit = s.stack_exit[d]
                lnk = t.port_link[pnode, pexit]
                return s._replace(
                    busy=s.busy.at[lnk].set(False),
                    depth=d,
                    cur=pnode,
                    entry=s.stack_entry[d],
                    backtracks=s.backtracks + 1,
                )

            return jax.lax.cond(s.depth == 0, fail, pop, s)

        st = jax.lax.cond(
            at_dst,
            finish,
            lambda s: jax.lax.cond(has_pick, advance, backtrack, s),
            st,
        )
        return st._replace(steps=st.steps + 1, rng=rng_next)

    st = jax.lax.while_loop(cond, body, st)
    path_mask = st.busy & ~link_busy
    in_path = jnp.arange(cap) < st.depth
    misroutes = jnp.sum(st.stack_mis & in_path).astype(jnp.int32)
    # Port through which the scout entered the destination (ejection handoff).
    last = jnp.maximum(st.depth - 1, 0)
    dst_entry = jnp.where(
        st.depth > 0, OPPOSITE_J[st.stack_exit[last]], jnp.int32(-1)
    )
    return ScoutOut(
        success=st.success,
        path_mask=path_mask,
        hops=st.depth,
        steps=st.steps,
        backtracks=st.backtracks,
        misroutes=misroutes,
        dst_entry_port=jnp.where(st.success, dst_entry, jnp.int32(-1)),
    )


OPPOSITE_J = jnp.asarray(np.asarray(OPPOSITE), dtype=jnp.int32)


def make_scout_fn(topo: MeshTopology, allow_nonminimal: bool = True):
    """Return a jitted ``(src, dst, link_busy, seed) -> ScoutOut`` for ``topo``."""
    t = make_tables(topo)

    @jax.jit
    def fn(src, dst, link_busy, seed):
        return scout_route(t, src, dst, link_busy, seed, allow_nonminimal)

    return fn
