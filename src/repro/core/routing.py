"""Venice Algorithm 1 — non-minimal fully-adaptive routing (reference impl).

This is the *oracle*: a plain-python/numpy depth-first scout walk with the
paper's exact semantics (§4.2-§4.3):

  * per hop, prefer FREE output ports on a MINIMAL path toward the
    destination (random tie-break between the two dimension candidates);
  * if no minimal port is free, MISROUTE over any free non-minimal port
    (never the port we arrived on);
  * if nothing is free, BACKTRACK to the upstream router, cancelling the
    reservation of the link we arrived on;
  * livelock bound: each *output port* of each router can be reserved at
    most once per scout (⇒ a router is revisited ≤ 3 times on a 4-port
    mesh router, paper footnote 5), so the walk is a terminating DFS;
  * deadlock cannot happen because a scout never blocks — it backtracks.

The jitted engine in ``core/scout.py`` must match this function decision-for-
decision (same xorshift32 tie-break stream); tests enforce parity.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.rng import xorshift32_py
from repro.core.topology import EJECT, MeshTopology, N_PORTS, OPPOSITE

# Fixed candidate ordering for random selection (index = port id).
_PORT_ORDER = (0, 1, 2, 3)  # RIGHT, UP, LEFT, DOWN


@dataclasses.dataclass
class ScoutResult:
    """Outcome of one scout walk."""

    success: bool
    path_nodes: list  # nodes visited on the final reserved path (src..dst)
    path_links: np.ndarray  # link ids of the final reserved path (len = hops)
    hops: int
    steps: int  # total DFS steps (incl. backtracks) — scout latency proxy
    backtracks: int
    misroutes: int  # hops taken on non-minimal ports
    minimal_hops: int  # Manhattan distance src->dst (for non-minimality stats)


def minimal_ports(topo: MeshTopology, node: int, dst: int) -> list:
    """Output ports of ``node`` on some minimal path to ``dst`` (Alg. 1 lines 5-26)."""
    r, c = divmod(node, topo.cols)
    rd, cd = divmod(dst, topo.cols)
    ports = []
    # Diff_x = dst_col - col ; Diff_y = dst_row - row (paper: ID%Nc / ID/Nc)
    if cd > c:
        ports.append(0)  # RIGHT
    elif cd < c:
        ports.append(2)  # LEFT
    if rd > r:
        ports.append(1)  # UP
    elif rd < r:
        ports.append(3)  # DOWN
    return ports


def scout_route_ref(
    topo: MeshTopology,
    src_node: int,
    dst_node: int,
    link_busy: np.ndarray,
    seed: int,
    allow_nonminimal: bool = True,
) -> ScoutResult:
    """Walk one scout from ``src_node`` to ``dst_node`` over the mesh.

    ``link_busy`` is the *global* reservation state (bool [n_links]); the walk
    additionally treats links it has reserved itself as busy.  The input array
    is NOT mutated — on success the caller commits ``path_links``.

    ``allow_nonminimal=False`` degrades Algorithm 1 to *minimal* fully-adaptive
    routing (used for ablation in the benchmarks).
    """
    busy = link_busy.copy()
    tried = np.zeros((topo.n_nodes, N_PORTS), dtype=bool)
    # DFS stack of (node, entry_port, exit_port)
    stack: list = []
    cur = src_node
    entry = -1  # port we arrived on at `cur` (-1 at the source)
    rng = seed
    steps = 0
    backtracks = 0
    misroutes_mask: list = []  # parallel to stack: was this hop a misroute?
    max_steps = 8 * topo.n_nodes + 8  # hard safety bound (DFS is ≤ 4*n pushes + pops)

    while True:
        steps += 1
        if steps > max_steps:  # pragma: no cover - DFS bound makes this unreachable
            raise RuntimeError("scout exceeded DFS bound; invariant broken")
        if cur == dst_node:
            links = np.array(
                [topo.port_link[n, p] for (n, _, p) in stack], dtype=np.int32
            )
            nodes = [src_node] + [topo.port_neighbor[n, p] for (n, _, p) in stack]
            r0, c0 = divmod(src_node, topo.cols)
            r1, c1 = divmod(dst_node, topo.cols)
            return ScoutResult(
                success=True,
                path_nodes=nodes,
                path_links=links,
                hops=len(links),
                steps=steps,
                backtracks=backtracks,
                misroutes=int(sum(misroutes_mask)),
                minimal_hops=abs(r0 - r1) + abs(c0 - c1),
            )

        def free(p: int) -> bool:
            lnk = topo.port_link[cur, p]
            return lnk >= 0 and not busy[lnk] and not tried[cur, p]

        # --- minimal candidates (Alg. 1 lines 2-26) ---
        cands = [p for p in minimal_ports(topo, cur, dst_node) if free(p)]
        is_misroute = False
        if not cands and allow_nonminimal:
            # --- misroute: any free port except the one we arrived on (ll. 34-45)
            cands = [p for p in _PORT_ORDER if p != entry and free(p)]
            is_misroute = True

        if cands:
            if len(cands) > 1:
                rng = xorshift32_py(rng)
                pick = cands[rng % len(cands)]
            else:
                pick = cands[0]
            tried[cur, pick] = True
            busy[topo.port_link[cur, pick]] = True
            stack.append((cur, entry, pick))
            misroutes_mask.append(is_misroute)
            entry = int(OPPOSITE[pick])
            cur = int(topo.port_neighbor[cur, pick])
        else:
            # --- backtrack (Alg. 1 lines 46-47): cancel the upstream reservation
            if not stack:
                r0, c0 = divmod(src_node, topo.cols)
                r1, c1 = divmod(dst_node, topo.cols)
                return ScoutResult(
                    success=False,
                    path_nodes=[src_node],
                    path_links=np.zeros((0,), dtype=np.int32),
                    hops=0,
                    steps=steps,
                    backtracks=backtracks,
                    misroutes=0,
                    minimal_hops=abs(r0 - r1) + abs(c0 - c1),
                )
            backtracks += 1
            pnode, pentry, pexit = stack.pop()
            misroutes_mask.pop()
            busy[topo.port_link[pnode, pexit]] = False
            cur = pnode
            entry = pentry
