"""Venice core: mesh topology, Algorithm-1 routing, scout engine, reservation."""
from repro.core.topology import (
    DOWN,
    EJECT,
    LEFT,
    MeshTopology,
    N_PORTS,
    OPPOSITE,
    RIGHT,
    UP,
    all_xy_paths,
    build_mesh,
    xy_path_links,
)
from repro.core.routing import ScoutResult, minimal_ports, scout_route_ref
from repro.core.scout import ScoutOut, ScoutTables, make_scout_fn, make_tables, scout_route
from repro.core.rng import seed_for_scout, xorshift32_jax, xorshift32_py

__all__ = [
    "DOWN", "EJECT", "LEFT", "MeshTopology", "N_PORTS", "OPPOSITE", "RIGHT", "UP",
    "all_xy_paths", "build_mesh", "xy_path_links",
    "ScoutResult", "minimal_ports", "scout_route_ref",
    "ScoutOut", "ScoutTables", "make_scout_fn", "make_tables", "scout_route",
    "seed_for_scout", "xorshift32_jax", "xorshift32_py",
]
