"""Deterministic PRNG shared by the reference router and the JAX scout engine.

The paper uses a 2-bit LFSR inside each router for the random output-port
tie-break (§4.3).  For testability we want the *numpy reference* and the
*jitted JAX engine* to make bit-identical choices, so both use the same
xorshift32 stream seeded per scout.  (A 2-bit LFSR would repeat with period 3;
xorshift32 keeps the same "cheap hardware PRNG" spirit while letting the
simulator draw many tie-breaks per scout without short cycles.)
"""
from __future__ import annotations

import numpy as np

_U32 = np.uint32
MASK32 = np.uint32(0xFFFFFFFF)


def xorshift32_py(state: int) -> int:
    """One xorshift32 step on a python int (reference implementation)."""
    x = state & 0xFFFFFFFF
    x ^= (x << 13) & 0xFFFFFFFF
    x ^= x >> 17
    x ^= (x << 5) & 0xFFFFFFFF
    return x & 0xFFFFFFFF


def xorshift32_jax(state):
    """One xorshift32 step on a jnp.uint32 (jit-safe; import-free via duck typing)."""
    x = state
    x = x ^ (x << 13)
    x = x ^ (x >> 17)
    x = x ^ (x << 5)
    return x


def seed_for_scout(base_seed: int, scout_id: int) -> int:
    """Mix a base seed with a scout id into a non-zero 32-bit state (splitmix-ish)."""
    z = (base_seed + 0x9E3779B9 * (scout_id + 1)) & 0xFFFFFFFF
    z ^= z >> 16
    z = (z * 0x85EBCA6B) & 0xFFFFFFFF
    z ^= z >> 13
    return z | 1  # never zero (xorshift fixed point)
