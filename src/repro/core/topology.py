"""2D-mesh topology of flash nodes (Venice §4.1).

A flash node = (unmodified flash chip) + (router chip). Routers form an
``R x C`` 2D mesh; flash controller ``f`` (one per row, R total) attaches to the
west-edge node ``(f, 0)`` through its injection link.  Links are *bidirectional*
and reserved as a unit (Venice reserves the forward and backward directions of
each hop together so a single circuit serves both the command (forward) and read
data (backward) phases).

Everything here is static numpy — the tables are closed over by jitted code.

Port convention (matches Algorithm 1's Right/Up/Left/Down):
  RIGHT = 0 : (r, c) -> (r, c+1)    Diff_x > 0
  UP    = 1 : (r, c) -> (r+1, c)    Diff_y > 0   (paper: row index grows "Up")
  LEFT  = 2 : (r, c) -> (r, c-1)    Diff_x < 0
  DOWN  = 3 : (r, c) -> (r-1, c)    Diff_y < 0
  EJECT = 4 : router -> local flash chip (not a mesh link; never reserved)
"""
from __future__ import annotations

import dataclasses

import numpy as np

RIGHT, UP, LEFT, DOWN, EJECT = 0, 1, 2, 3, 4
N_PORTS = 4  # mesh ports (EJECT handled separately)
OPPOSITE = np.array([LEFT, DOWN, RIGHT, UP], dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class MeshTopology:
    """Static description of an R x C flash-node mesh with R flash controllers."""

    rows: int
    cols: int
    # --- derived tables (numpy, shape noted) ---
    n_nodes: int
    n_links: int
    port_link: np.ndarray      # [n_nodes, 4] link id per port, -1 if off-mesh
    port_neighbor: np.ndarray  # [n_nodes, 4] neighbor node id per port, -1 if none
    fc_node: np.ndarray        # [rows] node id each flash controller injects into
    link_endpoints: np.ndarray  # [n_links, 2] node ids (for tests / invariants)

    @property
    def n_fcs(self) -> int:
        return self.rows

    def node_id(self, r: int, c: int) -> int:
        return r * self.cols + c

    def node_rc(self, node: int) -> tuple[int, int]:
        return divmod(node, self.cols)


def build_mesh(rows: int, cols: int) -> MeshTopology:
    """Build the static routing tables for an ``rows x cols`` mesh.

    Link ids: horizontal links first (row-major, ``rows*(cols-1)`` of them),
    then vertical (col-major, ``cols*(rows-1)``).
    """
    if rows < 1 or cols < 1:
        raise ValueError(f"mesh must be at least 1x1, got {rows}x{cols}")
    n_nodes = rows * cols
    n_h = rows * (cols - 1)
    n_v = cols * (rows - 1)
    n_links = n_h + n_v

    def h_link(r: int, c: int) -> int:  # (r,c)-(r,c+1)
        return r * (cols - 1) + c

    def v_link(r: int, c: int) -> int:  # (r,c)-(r+1,c)
        return n_h + c * (rows - 1) + r

    port_link = np.full((n_nodes, N_PORTS), -1, dtype=np.int32)
    port_neighbor = np.full((n_nodes, N_PORTS), -1, dtype=np.int32)
    link_endpoints = np.zeros((n_links, 2), dtype=np.int32)

    for r in range(rows):
        for c in range(cols):
            n = r * cols + c
            if c + 1 < cols:
                port_link[n, RIGHT] = h_link(r, c)
                port_neighbor[n, RIGHT] = n + 1
                link_endpoints[h_link(r, c)] = (n, n + 1)
            if c - 1 >= 0:
                port_link[n, LEFT] = h_link(r, c - 1)
                port_neighbor[n, LEFT] = n - 1
            if r + 1 < rows:
                port_link[n, UP] = v_link(r, c)
                port_neighbor[n, UP] = n + cols
                link_endpoints[v_link(r, c)] = (n, n + cols)
            if r - 1 >= 0:
                port_link[n, DOWN] = v_link(r - 1, c)
                port_neighbor[n, DOWN] = n - cols

    fc_node = np.array([r * cols for r in range(rows)], dtype=np.int32)

    return MeshTopology(
        rows=rows,
        cols=cols,
        n_nodes=n_nodes,
        n_links=n_links,
        port_link=port_link,
        port_neighbor=port_neighbor,
        fc_node=fc_node,
        link_endpoints=link_endpoints,
    )


def xy_path_links(topo: MeshTopology, src_node: int, dst_node: int) -> np.ndarray:
    """Deterministic dimension-order (X-then-Y) path, used by the NoSSD baseline.

    Returns the link ids along the path (numpy int32 vector, possibly empty).
    """
    r0, c0 = topo.node_rc(src_node)
    r1, c1 = topo.node_rc(dst_node)
    links = []
    r, c = r0, c0
    while c != c1:
        step = 1 if c1 > c else -1
        port = RIGHT if step == 1 else LEFT
        links.append(topo.port_link[r * topo.cols + c, port])
        c += step
    while r != r1:
        step = 1 if r1 > r else -1
        port = UP if step == 1 else DOWN
        links.append(topo.port_link[r * topo.cols + c, port])
        r += step
    return np.asarray(links, dtype=np.int32)


def all_xy_paths(topo: MeshTopology) -> np.ndarray:
    """[n_fcs, n_nodes, max_len] link ids (padded with -1) for every FC->chip XY
    path, plus [n_fcs, n_nodes] hop counts.  Used by the jitted NoSSD simulator.
    """
    max_len = (topo.rows - 1) + (topo.cols - 1)
    max_len = max(max_len, 1)
    paths = np.full((topo.n_fcs, topo.n_nodes, max_len), -1, dtype=np.int32)
    hops = np.zeros((topo.n_fcs, topo.n_nodes), dtype=np.int32)
    for f in range(topo.n_fcs):
        src = int(topo.fc_node[f])
        for n in range(topo.n_nodes):
            p = xy_path_links(topo, src, n)
            paths[f, n, : len(p)] = p
            hops[f, n] = len(p)
    return paths, hops
