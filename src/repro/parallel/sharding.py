"""Sharding rules: leaf-name → PartitionSpec, with divisibility fallback.

Axes
  "model"        TP/EP: attention heads, MLP ff, MoE experts, vocab
  fsdp axes      parameter/grad sharding (ZeRO-3 style): ("data",) on one
                 pod; ("pod","data") for the >50B archs so the param shards
                 span the whole machine
  batch axes     activations' leading batch dim: ("pod","data") when the pod
                 axis exists, else ("data",)

Rules are keyed on leaf *name* and matched against the TRAILING dims of the
leaf; leading stacked-layer dims (from scan-over-layers vmapped init) are
replicated automatically.  Every axis assignment is validated against the
actual dim size — a non-divisible dim falls back to replication and is
reported (never a compile failure), which is what lets one rule set cover
all 10 archs x 4 shapes x 2 meshes.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def validate_divisible(mesh: Mesh, shape, spec: P, notes=None, name="") -> P:
    """Drop any spec axis that does not divide its dim (replicate instead)."""
    out = []
    for i, axis in enumerate(spec):
        if axis is None or i >= len(shape):
            out.append(None if i >= len(shape) else axis)
            continue
        size = _axis_size(mesh, axis)
        if shape[i] % size == 0:
            out.append(axis)
        else:
            out.append(None)
            if notes is not None:
                notes.append(
                    f"{name}: dim {i} ({shape[i]}) not divisible by {axis}"
                    f" ({size}) — replicated"
                )
    return P(*out)


# --- parameter rules --------------------------------------------------------

# leaf name -> (trailing_ndim, base spec builder(fsdp) )
def _param_rule(name: str, ndim: int, fsdp):
    two = {
        "embed": ("model", fsdp),
        "wq": (fsdp, "model"),
        "wk": (fsdp, "model"),
        "wv": (fsdp, "model"),
        "wo": ("model", fsdp),
        "wg": (fsdp, "model"),
        "wu": (fsdp, "model"),
        "wd": ("model", fsdp),
        "w1": (fsdp, "model"),
        "w2": ("model", fsdp),
        "w_dkv": (fsdp, None),
        "w_kr": (fsdp, None),
        "w_uk": (None, "model"),
        "w_uv": (None, "model"),
        "in_proj": (fsdp, None),
        "out_proj": (None, fsdp),
        "img_proj": (fsdp, "model"),
        "router": (fsdp, None),
        "conv_w": (None, "model"),
    }
    three = {  # MoE expert-stacked weights: EP over "model"
        "wg": ("model", fsdp, None),
        "wu": ("model", fsdp, None),
        "wd": ("model", None, fsdp),
    }
    one = {
        "bq": ("model",),
        "bk": ("model",),
        "bv": ("model",),
        "conv_b": ("model",),
    }
    if ndim >= 3 and name in three:
        return three[name]
    if ndim >= 2 and name in two:
        return two[name]
    if ndim >= 1 and name in one:
        return one[name]
    return ()  # replicate (norm scales, A_log, D, dt_bias, gate, ...)


def param_specs(mesh: Mesh, params_shape, fsdp_axes: Tuple[str, ...],
                notes: Optional[list] = None) -> Dict:
    """tree of PartitionSpec for a params (or optimizer-state) shape tree."""
    fsdp = tuple(a for a in fsdp_axes if a in mesh.shape.keys()) or None
    if fsdp and len(fsdp) == 1:
        fsdp = fsdp[0]

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = leaf.shape
        base = _param_rule(name, len(shape), fsdp)
        base = tuple(base[-len(shape):]) if base else ()
        lead = (None,) * (len(shape) - len(base))
        spec = P(*(lead + tuple(base)))
        return validate_divisible(mesh, shape, spec, notes, name)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


# --- activation / cache rules ------------------------------------------------


def batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape.keys())


def batch_specs(mesh: Mesh, batch_shape, notes=None) -> Dict:
    """Token/modality inputs: shard dim 0 (global batch) over pod+data."""
    b = batch_axes(mesh)
    b = b if len(b) > 1 else (b[0] if b else None)

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if leaf.ndim == 0:
            return P()
        spec = P(*((b,) + (None,) * (leaf.ndim - 1)))
        return validate_divisible(mesh, leaf.shape, spec, notes, f"batch.{name}")

    return jax.tree_util.tree_map_with_path(spec_for, batch_shape)


def cache_specs(mesh: Mesh, cache_shape, seq_shard: bool = False,
                notes=None) -> Dict:
    """Decode-cache sharding.

    KV caches shard batch over pod+data and the *head_dim / latent* feature
    dim over "model" (kv-head counts are often < the model axis, head_dim is
    always 128-aligned).  With ``seq_shard`` (long_500k, global_batch=1) the
    sequence dim is sharded over "data" instead of the batch — sequence
    parallelism for the single-stream KV cache.
    """
    b = batch_axes(mesh)
    b = b if len(b) > 1 else (b[0] if b else None)
    seq_ax = "data" if (seq_shard and "data" in mesh.shape.keys()) else None
    bat = None if seq_shard else b

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = leaf.shape
        nd = len(shape)
        trailing = {
            # trailing-dims spec per leaf kind
            "k": (bat, seq_ax, None, "model"),
            "v": (bat, seq_ax, None, "model"),
            "ckv": (bat, seq_ax, "model"),
            "kr": (bat, seq_ax, None),
            "ssm": (bat, None, "model", None),
            "conv": (bat, None, "model"),
            "img": (bat, None, "model"),
            "enc": (bat, None, "model"),
        }.get(name)
        if trailing is None:
            return P()
        base = tuple(trailing[-nd:]) if nd <= len(trailing) else (
            (None,) * (nd - len(trailing)) + tuple(trailing)
        )
        spec = P(*base)
        return validate_divisible(mesh, shape, spec, notes, f"cache.{name}")

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)
