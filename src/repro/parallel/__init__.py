"""Distribution: mesh-axis sharding rules (DP/FSDP/TP/EP/SP) for params,
optimizer state, activations and decode caches."""
from repro.parallel.sharding import (
    batch_specs,
    cache_specs,
    named,
    param_specs,
    validate_divisible,
)

__all__ = ["batch_specs", "cache_specs", "named", "param_specs",
           "validate_divisible"]
