"""XLA CPU runtime flags + persistent-cache env for the sweep planner.

Set BEFORE jax imports: jax locks the host platform device count and the
CPU runtime choice on first init, so every entry point that wants the
planner's multi-core sharded execution (``benchmarks/run.py``, the test
conftest) must append these to ``XLA_FLAGS`` before anything imports jax.
This module is deliberately import-free of jax (``repro`` is a namespace
package, so importing it pulls in nothing else).

Why the legacy (non-thunk) runtime: the simulator's nested-while program
shape (scout retry -> DFS -> scan chunk -> fori over chunks) is
pathological for XLA's thunk CPU executor — ~10x slower scout steps, ~4x
slower compiles, and 3-4x mutual slowdown of concurrent executions (see
the runtime note in ``repro.ssd.sim``).  Both flags are perf-only;
correctness is runtime-independent and pinned by the parity suite.

Warm-path caches (perf-only as well; see ``repro.ssd.exec_cache``):
``configure`` also opts the process into the two persistent compilation
tiers so a warm run has ``compile_s_total`` ~ 0 —

* tier 1, ``REPRO_XC_DIR`` (default ``results/.xc``): the repo's AOT
  executable store — loading skips tracing, lowering and XLA compilation;
* tier 2, ``JAX_COMPILATION_CACHE_DIR`` (default ``<xc_dir>/jax``): JAX's
  native persistent compilation cache — still re-traces and re-lowers but
  skips the backend compile, catching programs tier 1 doesn't manage.

Both respect values the caller/user already exported; setting
``REPRO_XC_DIR=""`` disables tier 1.
"""
from __future__ import annotations

import os


def configure(device_count: int | str | None = None,
              cache_dir: str | None = None) -> None:
    """Append the planner's XLA flags to ``XLA_FLAGS`` and default the
    persistent-cache env vars (each only if the caller/user hasn't
    already set it).  ``device_count`` defaults to the ``BENCH_DEVICES``
    env var, then the machine's core count; ``cache_dir`` defaults the
    tier-1 store location (``REPRO_XC_DIR``)."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        n = device_count or os.environ.get(
            "BENCH_DEVICES", str(os.cpu_count() or 1)
        )
        flags = f"{flags} --xla_force_host_platform_device_count={n}"
    if "--xla_cpu_use_thunk_runtime" not in flags:
        flags = f"{flags} --xla_cpu_use_thunk_runtime=false"
    os.environ["XLA_FLAGS"] = flags.strip()

    # ---- persistent compile caches (both tiers are opt-out via env) ----
    xc = os.environ.setdefault(
        "REPRO_XC_DIR", cache_dir or os.path.join("results", ".xc")
    )
    if xc and "JAX_COMPILATION_CACHE_DIR" not in os.environ:
        os.environ["JAX_COMPILATION_CACHE_DIR"] = os.path.join(xc, "jax")
        # cache every entry: the simulator's many small executables are
        # individually below jax's default 1s/small-entry thresholds
        os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS",
                              "0")
        os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES",
                              "-1")
