"""XLA CPU runtime flags for the sweep planner — set BEFORE jax imports.

jax locks the host platform device count and the CPU runtime choice on
first init, so every entry point that wants the planner's multi-core
sharded execution (``benchmarks/run.py``, the test conftest) must append
these to ``XLA_FLAGS`` before anything imports jax.  This module is
deliberately import-free of jax (``repro`` is a namespace package, so
importing it pulls in nothing else).

Why the legacy (non-thunk) runtime: the simulator's nested-while program
shape (scout retry -> DFS -> scan chunk -> fori over chunks) is
pathological for XLA's thunk CPU executor — ~10x slower scout steps, ~4x
slower compiles, and 3-4x mutual slowdown of concurrent executions (see
the runtime note in ``repro.ssd.sim``).  Both flags are perf-only;
correctness is runtime-independent and pinned by the parity suite.
"""
from __future__ import annotations

import os


def configure(device_count: int | str | None = None) -> None:
    """Append the planner's XLA flags to ``XLA_FLAGS`` (each only if the
    caller/user hasn't already set it).  ``device_count`` defaults to the
    ``BENCH_DEVICES`` env var, then the machine's core count."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        n = device_count or os.environ.get(
            "BENCH_DEVICES", str(os.cpu_count() or 1)
        )
        flags = f"{flags} --xla_force_host_platform_device_count={n}"
    if "--xla_cpu_use_thunk_runtime" not in flags:
        flags = f"{flags} --xla_cpu_use_thunk_runtime=false"
    os.environ["XLA_FLAGS"] = flags.strip()
