"""SSD substrate: Table-1 configs, FTL, flash-array geometry, and the jitted
discrete-resource simulator for all six evaluated designs (Baseline, pSSD,
pnSSD, NoSSD, Venice, path-conflict-free ideal)."""
from repro.ssd.config import (
    SSDConfig,
    PowerModel,
    cost_optimized,
    perf_optimized,
    TICK_NS,
)
from repro.ssd.sim import DESIGNS, SimResult, simulate
from repro.ssd.ftl import FTL, Transactions, decompose_trace

__all__ = [
    "SSDConfig", "PowerModel", "cost_optimized", "perf_optimized", "TICK_NS",
    "DESIGNS", "SimResult", "simulate", "FTL", "Transactions", "decompose_trace",
]
