"""SSD substrate: Table-1 configs, FTL, flash-array geometry, the declarative
design registry, and the jitted discrete-resource simulator that runs any set
of registered designs (baseline, pSSD, pnSSD, NoSSD, Venice + ablations,
path-conflict-free ideal) as one batched program."""
from repro.ssd.config import (
    SSDConfig,
    PowerModel,
    cost_optimized,
    perf_optimized,
    TICK_NS,
)
from repro.ssd.designs import DesignSpec, LaneTables, REGISTRY, lower_designs
from repro.ssd.sim import DESIGNS, SimResult, simulate, simulate_sweep
from repro.ssd.ftl import FTL, Transactions, decompose_trace
from repro.ssd.ftl_engine import decompose_vectorized

__all__ = [
    "SSDConfig", "PowerModel", "cost_optimized", "perf_optimized", "TICK_NS",
    "DESIGNS", "DesignSpec", "LaneTables", "REGISTRY", "lower_designs",
    "SimResult", "simulate", "simulate_sweep", "FTL", "Transactions",
    "decompose_trace", "decompose_vectorized",
]
