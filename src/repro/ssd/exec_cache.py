"""Persistent AOT executable cache: never pay the same compile twice.

The quick preset spends ~13 s of its ~36 s compiling executables that are
byte-for-byte identical run over run (the programs are design-agnostic and
keyed on geometry/capacity/cost class — see ``sim._build_group_fn``), so a
warm ``benchmarks/run.py`` was still paying the full cold-compile tax every
process.  This module serializes compiled executables with
``jax.experimental.serialize_executable`` (true AOT: loading skips
tracing, lowering AND XLA compilation) into a versioned on-disk store, and
``repro.xla_env`` additionally enables JAX's native persistent compilation
cache as a second tier (that tier still re-traces and re-lowers, but skips
the XLA backend compile — it catches programs this cache does not know
about, e.g. one-off jits in tests).

Store layout
    ``$REPRO_XC_DIR/<digest>.xc`` — one file per executable, written
    atomically (tmp + rename).  The digest is
    ``sha256(version salt || logical key)`` where the *version salt*
    covers everything that can change the lowered HLO or the produced
    machine code without showing up in the logical key:

    * ``jax.__version__`` + ``jaxlib.__version__``,
    * the XLA backend platform and its runtime version,
    * ``XLA_FLAGS`` (device count, thunk-runtime choice, ...),
    * the *source digest* of the modules that define the programs
      (``ssd/sim.py``, ``ssd/designs.py``, ``ssd/config.py``,
      ``core/scout.py``, ``core/topology.py``, ``core/routing.py``),
    * ``REPRO_XC_SALT`` (manual invalidation / tests).

    Keying on the source digest instead of the lowered HLO text is a
    deliberate deviation from "digest the lowering": it is a conservative
    over-approximation (a comment edit invalidates the cache; nothing that
    changes the HLO survives it) and it keeps the warm path free of the
    ~0.1-1 s tracing+lowering cost per program that digesting the HLO
    would re-introduce — the whole point of the AOT tier.

Failure model
    Every disk/deserialize problem — corrupted payload, truncated file,
    version-skewed pickle, missing device topology — degrades to a cache
    miss (the caller compiles) and bumps ``STATS["errors"]``; the broken
    entry is deleted so it cannot fail twice.  The cache is disabled when
    ``REPRO_XC_DIR`` is unset/empty (library default: entry points that
    want persistence — ``benchmarks/run.py``, the test conftest — opt in
    via ``repro.xla_env.configure``).
"""
from __future__ import annotations

import functools
import hashlib
import os
import pickle
import tempfile

__all__ = ["cache_dir", "has", "lookup", "store", "flush", "STATS",
           "reset_stats"]

# process-wide telemetry, mirrored into bench.PERF by the sweep planner.
# ``tombstones``: programs XLA:CPU cannot round-trip (a deserialize bug for
# some program shapes — e.g. "Symbols not found: main.N_spmd"); the store
# verifies every entry by reloading it once at store time and persists a
# tombstone instead, so warm runs take the recompile deterministically
# rather than erroring/deleting/re-storing forever.
STATS = {"hits": 0, "misses": 0, "errors": 0, "stores": 0, "tombstones": 0}

_FORMAT = 2  # bump to orphan every existing entry

# modules whose source participates in the version salt: everything that
# can trace INTO a stored program (see docstring).  Err on the side of
# including — a spurious invalidation costs one recompile, a missing
# module serves stale machine code after an edit.
_PROGRAM_SOURCES = (
    "repro.ssd.sim",
    "repro.ssd.designs",
    "repro.ssd.config",
    "repro.core.scout",
    "repro.core.topology",
    "repro.core.routing",
    "repro.core.rng",
    "repro.kernels.onehot",
)


def reset_stats() -> None:
    for k in STATS:
        STATS[k] = 0


def cache_dir() -> str | None:
    """The store directory, or None when the cache is disabled."""
    d = os.environ.get("REPRO_XC_DIR", "")
    return d or None


@functools.lru_cache(maxsize=None)
def _source_digest() -> str:
    import importlib

    h = hashlib.sha256()
    for mod in _PROGRAM_SOURCES:
        path = importlib.import_module(mod).__file__
        with open(path, "rb") as f:
            h.update(f.read())
    return h.hexdigest()


@functools.lru_cache(maxsize=None)
def _version_salt() -> bytes:
    import jax
    import jaxlib

    dev = jax.devices()[0]
    parts = (
        f"format={_FORMAT}",
        f"jax={jax.__version__}",
        f"jaxlib={jaxlib.__version__}",
        f"platform={dev.platform}",
        f"platform_version={getattr(dev.client, 'platform_version', '')}",
        f"devices={len(jax.devices())}",
        f"xla_flags={os.environ.get('XLA_FLAGS', '')}",
        f"sources={_source_digest()}",
        f"salt={os.environ.get('REPRO_XC_SALT', '')}",
    )
    return "|".join(parts).encode()


def entry_digest(logical_key: tuple) -> str:
    """Stable digest of (version salt, logical executable key)."""
    h = hashlib.sha256(_version_salt())
    h.update(repr(logical_key).encode())
    return h.hexdigest()


def _entry_path(digest: str) -> str:
    return os.path.join(cache_dir(), digest + ".xc")


def has(logical_key: tuple) -> bool:
    """Cheap existence probe (no load, no counters) — the planner uses it
    to decide whether a key needs main-thread lowering or just a worker
    deserialize."""
    return (cache_dir() is not None
            and os.path.exists(_entry_path(entry_digest(logical_key))))


def lookup(logical_key: tuple):
    """Load a compiled executable for ``logical_key``, or None.

    Any failure (absent, corrupted, version-mismatched, wrong topology)
    returns None so the caller falls back to compiling; corruption also
    deletes the entry and counts in ``STATS["errors"]``.
    """
    if cache_dir() is None:
        return None
    path = _entry_path(entry_digest(logical_key))
    if not os.path.exists(path):
        STATS["misses"] += 1
        return None
    try:
        from jax.experimental import serialize_executable as se

        with open(path, "rb") as f:
            entry = pickle.load(f)
        if isinstance(entry, dict) and entry.get("tombstone"):
            # known-unserializable program: deterministic recompile
            STATS["tombstones"] += 1
            STATS["misses"] += 1
            return None
        payload, in_tree, out_tree = entry
        compiled = se.deserialize_and_load(payload, in_tree, out_tree)
    except Exception:  # noqa: BLE001 — any breakage degrades to a miss
        STATS["errors"] += 1
        STATS["misses"] += 1
        try:  # tombstone the entry: if the program is one XLA:CPU cannot
            # round-trip (see STATS docstring), later runs take the
            # recompile deterministically instead of re-erroring; a
            # genuinely corrupted entry loses nothing either way
            _write_entry(path, pickle.dumps({"tombstone": _FORMAT}))
        except OSError:
            pass
        return None
    STATS["hits"] += 1
    return compiled


def _write_entry(path: str, blob: bytes) -> None:
    d = cache_dir()
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise


def _store_now(logical_key: tuple, compiled) -> None:
    try:
        from jax.experimental import serialize_executable as se

        path = _entry_path(entry_digest(logical_key))
        if os.path.exists(path):  # racing store of the same key
            return
        payload, in_tree, out_tree = se.serialize(compiled)
        # verify the round trip BEFORE committing: XLA:CPU serialization
        # is nondeterministically broken for some program/process states
        # ("Symbols not found: main.N[_spmd]" — correlates with the
        # process's module counter; long-lived test sessions hit it).
        # A failing entry becomes a tombstone: every later run recompiles
        # it deterministically instead of erroring.  The compile server
        # (a fresh short-lived process where serialization is reliable)
        # opts out via REPRO_XC_VERIFY=0 — its rare bad entry is caught
        # at load time by the parent's error->tombstone fallback instead.
        if os.environ.get("REPRO_XC_VERIFY", "1") != "0":
            try:
                se.deserialize_and_load(payload, in_tree, out_tree)
            except Exception:  # noqa: BLE001
                _write_entry(path, pickle.dumps({"tombstone": _FORMAT}))
                STATS["tombstones"] += 1
                return
        _write_entry(path, pickle.dumps((payload, in_tree, out_tree)))
    except Exception:  # noqa: BLE001
        STATS["errors"] += 1
        return
    STATS["stores"] += 1


_STORE_POOL = None
_PENDING = []


def store(logical_key: tuple, compiled) -> None:
    """Queue ``compiled`` for serialization under ``logical_key``.

    Stores run on a single background writer (serialize + the round-trip
    verification are not free, and the compile workers should be
    compiling); failures are swallowed — a cache must never take the run
    down with it.  :func:`flush` joins the queue (tests; atexit).
    """
    if cache_dir() is None:
        return
    global _STORE_POOL
    if _STORE_POOL is None:
        import atexit
        import concurrent.futures

        _STORE_POOL = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="xc-store"
        )
        atexit.register(flush)
    _PENDING.append(_STORE_POOL.submit(_store_now, logical_key, compiled))


def flush() -> None:
    """Wait for queued stores to hit disk."""
    while _PENDING:
        _PENDING.pop().result()
