"""Jitted discrete-resource SSD simulator for the six evaluated designs.

Replaces MQSim's event-driven C++ core with a ``lax.scan`` over page-level
transactions in arrival order: each step computes the transaction's start time
from the *free-at* state of every resource it needs (plane, flash controller,
channel or mesh links), commits its occupancy, and emits completion/energy
stats.  Venice's path reservation runs the Algorithm-1 scout engine
(``core/scout.py``) inside the scan, retrying at the next link-free event when
a scout fails — exactly the paper's "retry immediately" policy (§4.2).

Designs
  baseline        multi-channel shared bus (Table 1)
  pssd            Kim+ [15]: packetized, 2x channel bandwidth
  pnssd           Kim+ [15]: row+column shared buses (two paths per chip)
  nossd           Tavakkol+ [38]: 2D mesh, deterministic XY routing
  venice          the paper: scout path reservation + non-minimal adaptive
  venice_minimal  ablation: Venice with minimal-only adaptive routing
  venice_release  beyond-paper: release the circuit during tR, re-scout for
                  the read-data phase (recovers link-hours; §Perf)
  ideal           path-conflict-free: a private channel per chip

Approximations vs MQSim (all documented in DESIGN.md §3): in-order commit per
transaction; single-gap backfill per shared bus (captures CMD-during-tR and
one-deep data backfill — the dominant pipelining in a real channel); NoSSD's
buffered wormhole modeled as transient circuits per packet phase.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scout import make_tables, scout_route
from repro.core.topology import MeshTopology, build_mesh, all_xy_paths
from repro.ssd.config import SSDConfig, TICK_NS

DESIGNS = (
    "baseline",
    "pssd",
    "pnssd",
    "nossd",
    "venice",
    "venice_minimal",
    "venice_hold",
    "venice_kscout",
    "ideal",
)

_BIG = np.int32(2**30)

KIND_READ, KIND_WRITE, KIND_ERASE = 0, 1, 2


class TxnArrays(NamedTuple):
    """Page-level transactions, sorted by arrival (ticks)."""

    arrival: jnp.ndarray  # int32 [n]
    kind: jnp.ndarray  # int32 [n] 0=read 1=write 2=erase
    plane: jnp.ndarray  # int32 [n] global plane id
    node: jnp.ndarray  # int32 [n] chip / mesh node id
    row: jnp.ndarray  # int32 [n] channel id
    nbytes: jnp.ndarray  # int32 [n]
    op_ticks: jnp.ndarray  # int32 [n] tR/tPROG/tBERS by kind
    valid: jnp.ndarray  # bool  [n] padding mask


class StepOut(NamedTuple):
    completion: jnp.ndarray  # int32 ticks
    wait: jnp.ndarray  # int32 ticks spent waiting on the path (conflict time)
    conflict: jnp.ndarray  # bool — experienced a path conflict (fig. 13)
    hops: jnp.ndarray  # int32 (mesh designs; 0 for bus designs)
    tries: jnp.ndarray  # int32 scout attempts (venice)
    scout_steps: jnp.ndarray  # int32 DFS steps (venice)
    misroutes: jnp.ndarray  # int32 non-minimal hops on final path (venice)
    bus_hold: jnp.ndarray  # int32 ticks a shared bus was held
    link_hold: jnp.ndarray  # int32 link-ticks (sum over links held)


# ---------------------------------------------------------------------------
# resource scheduling primitives
#
# Every time-shared resource (bus channel, mesh link, flash controller) is a
# triple of arrays (free_at, gap_s, gap_e): busy through ``free_at`` except
# one remembered idle gap [gap_s, gap_e).  The in-order scan can commit
# transfers far in the future (e.g. a write waiting on a 100 us tPROG), and
# the remembered gap keeps the resource's *current* idle capacity usable by
# later transactions instead of ratcheting free_at forward — the one-gap
# interval model is what keeps this O(1)-state simulator faithful to an
# event-driven scheduler to first order.
# ---------------------------------------------------------------------------


def _gap_avail(gs, ge, fa, e, d):
    """Earliest start >= e where a d-tick usage fits (gap or tail)."""
    s_gap = jnp.maximum(e, gs)
    fits = (s_gap + d) <= ge
    return jnp.where(fits, s_gap, jnp.maximum(e, fa))


def _gap_commit(gs, ge, fa, s, e2):
    """Carve the interval [s, e2) out; remember the larger leftover gap."""
    in_gap = (s >= gs) & (e2 <= ge)
    # inside the gap: keep the larger of the two leftover sides
    left_bigger = (s - gs) >= (ge - e2)
    g_gs = jnp.where(left_bigger, gs, e2)
    g_ge = jnp.where(left_bigger, s, ge)
    # appended at/after free_at: keep the larger of (old gap, new idle span)
    new_idle = jnp.maximum(s, fa) - fa
    keep_old = (ge - gs) >= new_idle
    a_gs = jnp.where(keep_old, gs, fa)
    a_ge = jnp.where(keep_old, ge, jnp.maximum(s, fa))
    a_fa = jnp.maximum(fa, e2)
    return (
        jnp.where(in_gap, g_gs, a_gs),
        jnp.where(in_gap, g_ge, a_ge),
        jnp.where(in_gap, fa, a_fa),
    )


def _avail1(res, i, e, d):
    free, gap_s, gap_e = res
    return _gap_avail(gap_s[i], gap_e[i], free[i], e, d)


def _commit1(res, i, s, e2, enable):
    free, gap_s, gap_e = res
    gs, ge, fa = _gap_commit(gap_s[i], gap_e[i], free[i], s, e2)
    return (
        free.at[i].set(jnp.where(enable, fa, free[i])),
        gap_s.at[i].set(jnp.where(enable, gs, gap_s[i])),
        gap_e.at[i].set(jnp.where(enable, ge, gap_e[i])),
    )


def _avail_all(res, e, d):
    """Vectorized earliest-start for every resource in the triple."""
    free, gap_s, gap_e = res
    return _gap_avail(gap_s, gap_e, free, e, d)


def _busy_at(res, t, d):
    """bool per resource: cannot host a d-tick usage starting exactly at t."""
    free, gap_s, gap_e = res
    free_ok = t >= free
    gap_ok = (t >= gap_s) & ((t + d) <= gap_e)
    return ~(free_ok | gap_ok)


def _commit_mask(res, mask, s, e2, enable):
    free, gap_s, gap_e = res
    gs, ge, fa = _gap_commit(gap_s, gap_e, free, s, e2)
    take = mask & enable
    return (
        jnp.where(take, fa, free),
        jnp.where(take, gs, gap_s),
        jnp.where(take, ge, gap_e),
    )


def _sched_gap(chan, ch, e, d, enable):
    """Schedule a d-tick usage of resource ``ch`` at the earliest time >= e."""
    s = _avail1(chan, ch, e, d)
    s = jnp.where(enable, s, e)
    chan = _commit1(chan, ch, s, s + d, enable)
    return s, chan


def _triple(n: int):
    z = jnp.zeros((n,), jnp.int32)
    return (z, z, z)


# ---------------------------------------------------------------------------
# shared-bus designs
# ---------------------------------------------------------------------------


def _bus_step(cfg: SSDConfig, chan_of_tx, xfer_of_tx, ovh: int):
    """Build the scan step for a pure shared-bus design.

    ``ovh``: per-bus-phase protocol overhead (legacy ONFI bus only)."""

    def step(state, tx: TxnArrays):
        plane_free, chan = state
        ch = chan_of_tx(tx)
        xfer = xfer_of_tx(tx)
        is_read = tx.kind == KIND_READ
        d0 = ovh + cfg.t_cmd + jnp.where(is_read, 0, xfer)
        e0 = jnp.maximum(tx.arrival, plane_free[tx.plane])
        s0, chan = _sched_gap(chan, ch, e0, d0, tx.valid)
        phase0_end = s0 + d0
        op_end = phase0_end + tx.op_ticks
        # read data phase (zero-length & disabled otherwise)
        d1 = ovh + xfer
        s1, chan = _sched_gap(chan, ch, op_end, d1, tx.valid & is_read)
        done = jnp.where(is_read, s1 + d1, op_end)
        plane_free = plane_free.at[tx.plane].set(
            jnp.where(tx.valid, done, plane_free[tx.plane])
        )
        wait = (s0 - e0) + jnp.where(is_read, s1 - op_end, 0)
        out = StepOut(
            completion=done,
            wait=wait,
            conflict=wait > 0,
            hops=jnp.int32(0),
            tries=jnp.int32(1),
            scout_steps=jnp.int32(0),
            misroutes=jnp.int32(0),
            bus_hold=d0 + jnp.where(is_read, d1, 0),
            link_hold=jnp.int32(0),
        )
        return (plane_free, chan), out

    return step


def _pnssd_step(cfg: SSDConfig, topo: MeshTopology):
    """pnSSD: each chip reachable over its row bus or its column bus.

    The controller keeps the baseline's 8 flash controllers: FC ``i`` drives
    horizontal channel ``i`` and vertical channel ``i``, one transfer at a
    time — pnSSD adds *path diversity*, not transfer engines [15]."""

    rows = topo.rows

    def xfer_of(tx):
        return _xfer_bus(cfg, tx.nbytes, 1.0)

    def step(state, tx: TxnArrays):
        plane_free, chan, chips, fcs = state
        col = tx.node % topo.cols
        ch_row = tx.row
        ch_col = rows + col
        xfer = xfer_of(tx)
        is_read = tx.kind == KIND_READ
        d0 = cfg.t_cmd + jnp.where(is_read, 0, xfer)  # packetized: no bus ovh
        e0 = jnp.maximum(tx.arrival, plane_free[tx.plane])

        def sched_on(ch, fc):
            # the chip's single I/O interface gates both of its buses, and
            # the owning FC must be free to drive the transfer
            e0c = jnp.maximum(e0, _avail1(chips, tx.node, e0, d0))
            e0c = jnp.maximum(e0c, _avail1(fcs, fc, e0c, d0))
            s0, chan1 = _sched_gap(chan, ch, e0c, d0, tx.valid)
            chips1 = _commit1(chips, tx.node, s0, s0 + d0, tx.valid)
            fcs1 = _commit1(fcs, fc, s0, s0 + d0, tx.valid)
            op_end = s0 + d0 + tx.op_ticks
            e1 = jnp.maximum(op_end, _avail1(chips1, tx.node, op_end, xfer))
            e1 = jnp.maximum(e1, _avail1(fcs1, fc, e1, xfer))
            s1, chan1 = _sched_gap(chan1, ch, e1, xfer, tx.valid & is_read)
            chips1 = _commit1(chips1, tx.node, s1, s1 + xfer, tx.valid & is_read)
            fcs1 = _commit1(fcs1, fc, s1, s1 + xfer, tx.valid & is_read)
            done = jnp.where(is_read, s1 + xfer, op_end)
            wait = (s0 - e0) + jnp.where(is_read, s1 - op_end, 0)
            return done, wait, chan1, chips1, fcs1

        done_r, wait_r, chan_r, chips_r, fcs_r = sched_on(ch_row, ch_row)
        done_c, wait_c, chan_c, chips_c, fcs_c = sched_on(ch_col, col)
        use_row = done_r <= done_c
        done = jnp.where(use_row, done_r, done_c)
        wait = jnp.where(use_row, wait_r, wait_c)
        chan = jax.tree_util.tree_map(
            lambda a, b: jnp.where(use_row, a, b), chan_r, chan_c
        )
        chips = jax.tree_util.tree_map(
            lambda a, b: jnp.where(use_row, a, b), chips_r, chips_c
        )
        fcs = jax.tree_util.tree_map(
            lambda a, b: jnp.where(use_row, a, b), fcs_r, fcs_c
        )
        plane_free = plane_free.at[tx.plane].set(
            jnp.where(tx.valid, done, plane_free[tx.plane])
        )
        out = StepOut(
            completion=done,
            wait=wait,
            conflict=wait > 0,
            hops=jnp.int32(0),
            tries=jnp.int32(1),
            scout_steps=jnp.int32(0),
            misroutes=jnp.int32(0),
            bus_hold=d0 + jnp.where(is_read, xfer, 0),
            link_hold=jnp.int32(0),
        )
        return (plane_free, chan, chips, fcs), out

    return step


# ---------------------------------------------------------------------------
# mesh designs (NoSSD / Venice)
# ---------------------------------------------------------------------------


def _ceil_div(a, b):
    return (a + b - 1) // b


def _xfer_bus(cfg: SSDConfig, nbytes, mult):
    """Shared-channel transfer ticks (rational arithmetic in ns)."""
    ns_num = nbytes.astype(jnp.int32) * 1000  # fits: nbytes <= ~1 MB
    ns_den = jnp.int32(round(cfg.chan_gbps * mult * 1000))  # B/ns * 1000
    ns = _ceil_div(ns_num, ns_den)
    return _ceil_div(ns, TICK_NS).astype(jnp.int32)


def _xfer_link(cfg: SSDConfig, nbytes, hops):
    """Eq. (1): (distance + size/width) * link_lat, in ticks."""
    ns = (nbytes + hops).astype(jnp.int32)  # 1 B/ns, 1 hop = 1 ns pipeline fill
    return _ceil_div(ns, TICK_NS).astype(jnp.int32)


def _cmd_link(cfg: SSDConfig, hops):
    ns = jnp.int32(8) + hops  # 8-byte command packet
    return jnp.maximum(_ceil_div(ns, TICK_NS).astype(jnp.int32), 1)


def _fc_select(fcs, dist_to_dst, tcand, d_est):
    """Paper §4.2: closest FC *available now*, else the earliest-available FC
    (availability = can host a d_est-tick transfer, gap-aware)."""
    avail = _avail_all(fcs, tcand, d_est)  # [n_fcs]
    free = avail <= tcand
    any_free = jnp.any(free)
    by_dist = jnp.argmin(jnp.where(free, dist_to_dst, _BIG))
    by_time = jnp.argmin(avail)
    fc = jnp.where(any_free, by_dist, by_time).astype(jnp.int32)
    t0 = jnp.maximum(tcand, avail[fc])
    return fc, t0, any_free


def _nossd_step(cfg: SSDConfig, topo: MeshTopology):
    """NoSSD [38]: packet-switched mesh, deterministic XY routing.

    Each packet phase (command forward; data back) occupies the XY path as a
    transient circuit.  FCs are pipelined processors like baseline channel
    controllers: busy only while a packet of theirs is in flight (single-gap
    backfill lets the FC interleave other requests during tR)."""
    paths_np, hops_np = all_xy_paths(topo)
    # [n_fcs, n_nodes, n_links] bool path masks
    masks = np.zeros((topo.n_fcs, topo.n_nodes, topo.n_links), dtype=bool)
    for f in range(topo.n_fcs):
        for n in range(topo.n_nodes):
            lk = paths_np[f, n]
            masks[f, n, lk[lk >= 0]] = True
    masks = jnp.asarray(masks)
    hops_t = jnp.asarray(hops_np, dtype=jnp.int32)
    dist = jnp.asarray(hops_np, dtype=jnp.int32)  # XY dist == manhattan here

    def path_sched(links, mask, e, d):
        """Earliest common start >= e for a d-tick transient circuit on the
        masked path.  Per-link availability first; if the joint candidate
        doesn't fit everywhere, fall back to the path's free_at tail."""
        avail = _avail_all(links, e, d)
        s1 = jnp.max(jnp.where(mask, avail, 0))
        s1 = jnp.maximum(s1, e)
        ok = ~jnp.any(_busy_at(links, s1, d) & mask)
        s_tail = jnp.maximum(e, jnp.max(jnp.where(mask, links[0], 0)))
        return jnp.where(ok, s1, s_tail)

    def step(state, tx: TxnArrays):
        plane_free, fcs, links, chips = state
        tcand = jnp.maximum(tx.arrival, plane_free[tx.plane])
        is_read = tx.kind == KIND_READ
        d_est = _xfer_link(cfg, tx.nbytes, 6)
        fc, t0, any_free = _fc_select(fcs, dist[:, tx.node], tcand, d_est)
        mask = masks[fc, tx.node]
        hops = hops_t[fc, tx.node]
        cmd = _cmd_link(cfg, hops)
        xfer = _xfer_link(cfg, tx.nbytes, hops)

        # phase 0: command (reads) / command+data (writes, erases) forward
        d0 = cmd + jnp.where(is_read, 0, xfer)
        e0 = jnp.maximum(t0, _avail1(chips, tx.node, t0, d0))
        s0 = path_sched(links, mask, e0, d0)
        s0 = jnp.maximum(s0, _avail1(fcs, fc, s0, d0))  # FC must drive it
        p0_end = s0 + d0
        links = _commit_mask(links, mask, s0, p0_end, tx.valid)
        fcs = _commit1(fcs, fc, s0, p0_end, tx.valid)
        chips = _commit1(chips, tx.node, s0, p0_end, tx.valid)
        op_end = p0_end + tx.op_ticks
        # phase 1: read-data packet back over the same XY path
        e1 = jnp.maximum(op_end, _avail1(chips, tx.node, op_end, xfer))
        s1 = path_sched(links, mask, e1, xfer)
        s1 = jnp.maximum(s1, _avail1(fcs, fc, s1, xfer))
        p1_end = s1 + xfer
        links = _commit_mask(links, mask, s1, p1_end, tx.valid & is_read)
        fcs = _commit1(fcs, fc, s1, p1_end, tx.valid & is_read)
        chips = _commit1(chips, tx.node, s1, p1_end, tx.valid & is_read)
        done = jnp.where(is_read, p1_end, op_end)
        plane_free = plane_free.at[tx.plane].set(
            jnp.where(tx.valid, done, plane_free[tx.plane])
        )
        wait = (s0 - t0) + jnp.where(is_read, s1 - op_end, 0)
        out = StepOut(
            completion=done,
            wait=wait,
            conflict=wait > 0,
            hops=hops,
            tries=jnp.int32(1),
            scout_steps=jnp.int32(0),
            misroutes=jnp.int32(0),
            bus_hold=jnp.int32(0),
            link_hold=hops * (d0 + jnp.where(is_read, xfer, 0)),
        )
        return (plane_free, fcs, links, chips), out

    return step


def _venice_step(
    cfg: SSDConfig,
    topo: MeshTopology,
    allow_nonminimal: bool = True,
    hold_during_op: bool = False,
    max_tries: int = 64,
    n_scouts: int = 1,
):
    """Venice (§4): per-*transfer* path reservation via Algorithm-1 scouts.

    The reserved bidirectional circuit serves the data transfer — forward for
    writes (command+data), backward for reads (§4.2).  A read's command is a
    scout-sized packet delivered without a standing reservation (transient
    per-hop occupancy, like the scout itself); the data-phase scout is sent
    when tR completes, so links and the FC are never parked across tR.
    ``hold_during_op=True`` gives the conservative variant that keeps one
    circuit across CMD+tR+transfer (ablation: wastes link-hours).
    FCs are pipelined processors (single-gap backfill), busy only while
    scouting/transferring; §6.3's "all FCs busy" gate is preserved.
    """
    tables = make_tables(topo)
    fc_node = jnp.asarray(topo.fc_node, dtype=jnp.int32)
    r = np.arange(topo.n_nodes) // topo.cols
    c = np.arange(topo.n_nodes) % topo.cols
    dist_np = np.abs(np.arange(topo.rows)[:, None] - r[None, :]) + c[None, :]
    dist = jnp.asarray(dist_np, dtype=jnp.int32)
    scout_hop_ticks_num = int(round(cfg.scout_flit_ns))  # ns per hop per direction

    def scout_until_success(links, src, dst, t0, rng, d_hold):
        """Retry the scout at successive link-free events until it reserves.

        A link is busy for the scout if it cannot host a ``d_hold``-tick
        reservation starting now (gap-aware: a link with a large enough idle
        window before its next commitment still accepts the circuit)."""

        def try_once(t, rng):
            # beyond-paper k-scout (paper fn. 3 hints at resend policies):
            # launch n_scouts with independent tie-break streams and commit
            # the successful path with the FEWEST hops — shorter circuits
            # hold fewer link-hours, raising sustainable throughput.
            busy = _busy_at(links, t, d_hold)
            best = None
            for _ in range(n_scouts):
                rng = (rng * jnp.uint32(747796405)
                       + jnp.uint32(2891336453)) | jnp.uint32(1)
                res = scout_route(tables, src, dst, busy, rng, allow_nonminimal)
                if best is None:
                    best = res
                else:
                    take = res.success & (
                        (~best.success) | (res.hops < best.hops)
                    )
                    best = jax.tree_util.tree_map(
                        lambda a, b: jnp.where(take, a, b), res, best
                    )
            return best, rng

        res0, rng = try_once(t0, rng)

        def cond(carry):
            res, t, rng, tries = carry
            return (~res.success) & (tries < max_tries)

        def body(carry):
            res, t, rng, tries = carry
            # advance to the next potential link-state change:
            # a free_at passing, or an idle gap opening
            free, gap_s, _ = links
            ev = jnp.minimum(
                jnp.min(jnp.where(free > t, free, _BIG)),
                jnp.min(jnp.where(gap_s > t, gap_s, _BIG)),
            )
            t_next = jnp.maximum(ev, t + 1)
            t_next = jnp.where(tries + 1 >= max_tries, jnp.max(free), t_next)
            res, rng = try_once(t_next, rng)
            return res, t_next, rng, tries + 1

        res, t, rng, tries = jax.lax.while_loop(
            cond, body, (res0, t0, rng, jnp.int32(1))
        )
        return res, t, rng, tries

    def step(state, tx: TxnArrays):
        plane_free, fcs, links, chips, rng = state
        tcand = jnp.maximum(tx.arrival, plane_free[tx.plane])
        is_read = tx.kind == KIND_READ
        # duration estimate for availability checks: transfer + scout-RTT margin
        d_est = _xfer_link(cfg, tx.nbytes, 48) + 16
        if hold_during_op:
            d_est = d_est + jnp.where(is_read, tx.op_ticks, 0)
        fc, t0, any_free = _fc_select(fcs, dist[:, tx.node], tcand, d_est)
        src = fc_node[fc]
        min_hops = dist[fc, tx.node]
        cmd_pkt = _cmd_link(cfg, min_hops)  # read command: scout-sized packet

        if hold_during_op:
            # one circuit across CMD + flash op + transfer (conservative)
            res, t_resv, rng, tries = scout_until_success(
                links, src, tx.node, t0, rng, d_est
            )
            hops = res.hops
            rtt = _ceil_div((res.steps + hops) * scout_hop_ticks_num, TICK_NS)
            start = t_resv + rtt.astype(jnp.int32)
            cmd = _cmd_link(cfg, hops)
            xfer = _xfer_link(cfg, tx.nbytes, hops)
            done_r = start + cmd + tx.op_ticks + xfer
            data_end_w = start + cmd + xfer
            circuit_end = jnp.where(is_read, done_r, data_end_w)
            links = _commit_mask(links, res.path_mask, t_resv, circuit_end, tx.valid)
            fcs = _commit1(fcs, fc, t_resv, circuit_end, tx.valid)
            chips = _commit1(chips, tx.node, t_resv, circuit_end, tx.valid)
            done = jnp.where(is_read, done_r, data_end_w + tx.op_ticks)
            out = StepOut(
                completion=done,
                wait=start - t0,
                conflict=tries > 1,
                hops=hops,
                tries=tries,
                scout_steps=res.steps,
                misroutes=res.misroutes,
                bus_hold=jnp.int32(0),
                link_hold=hops * (circuit_end - t_resv),
            )
            plane_free = plane_free.at[tx.plane].set(
                jnp.where(tx.valid, done, plane_free[tx.plane])
            )
            return (plane_free, fcs, links, chips, rng), out

        # ---- paper design: reservation per transfer ----
        # reads: command packet now; data-phase scout at tR completion
        s_cmd, fcs = _sched_gap(fcs, fc, t0, cmd_pkt, tx.valid & is_read)
        ready_r = s_cmd + cmd_pkt + tx.op_ticks  # data ready in page buffer
        # the data-phase transfer additionally needs this FC and the chip's
        # I/O interface to be available (the FC tracks chip status and only
        # scouts when the transfer can actually start)
        t_nonread = jnp.maximum(t0, _avail1(chips, tx.node, t0, d_est))
        t_read = jnp.maximum(
            jnp.maximum(ready_r, _avail1(fcs, fc, ready_r, d_est)),
            _avail1(chips, tx.node, ready_r, d_est),
        )
        t_xfer_req = jnp.where(is_read, t_read, t_nonread)

        res, t_resv, rng, tries = scout_until_success(
            links, src, tx.node, t_xfer_req, rng, d_est
        )
        hops = res.hops
        rtt = _ceil_div((res.steps + hops) * scout_hop_ticks_num, TICK_NS)
        start = t_resv + rtt.astype(jnp.int32)
        cmd = _cmd_link(cfg, hops)
        xfer = _xfer_link(cfg, tx.nbytes, hops)
        # read: backward data transfer; write/erase: forward command+data
        dur = jnp.where(is_read, xfer, cmd + xfer)
        end = start + dur
        links = _commit_mask(links, res.path_mask, t_resv, end, tx.valid)
        fcs = _commit1(fcs, fc, t_resv, end, tx.valid)
        chips = _commit1(chips, tx.node, t_resv, end, tx.valid)
        done = jnp.where(is_read, end, end + tx.op_ticks)
        plane_free = plane_free.at[tx.plane].set(
            jnp.where(tx.valid, done, plane_free[tx.plane])
        )
        out = StepOut(
            completion=done,
            wait=(s_cmd - t0) + (start - t_xfer_req),
            conflict=tries > 1,
            hops=hops,
            tries=tries,
            scout_steps=res.steps,
            misroutes=res.misroutes,
            bus_hold=jnp.int32(0),
            link_hold=hops * (end - t_resv),
        )
        return (plane_free, fcs, links, chips, rng), out

    return step


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _build_sim(cfg: SSDConfig, design: str, n_pad: int):
    """Compile one scan program per (config, design, padded length)."""
    topo = build_mesh(cfg.rows, cfg.cols)

    if design in ("baseline", "pssd"):
        mult = 2.0 if design == "pssd" else 1.0
        ovh = 0 if design == "pssd" else cfg.t_bus_ovh  # pSSD is packetized
        step = _bus_step(
            cfg, lambda tx: tx.row, lambda tx: _xfer_bus(cfg, tx.nbytes, mult), ovh
        )
        n_chan = cfg.rows
    elif design == "ideal":
        step = _bus_step(
            cfg,
            lambda tx: tx.node,
            lambda tx: _xfer_bus(cfg, tx.nbytes, 1.0),
            cfg.t_bus_ovh,
        )
        n_chan = topo.n_nodes
    elif design == "pnssd":
        step = _pnssd_step(cfg, topo)
        n_chan = topo.rows + topo.cols
    elif design == "nossd":
        step = _nossd_step(cfg, topo)
        n_chan = 0
    elif design in ("venice", "venice_minimal", "venice_hold",
                    "venice_kscout"):
        step = _venice_step(
            cfg,
            topo,
            allow_nonminimal=design != "venice_minimal",
            hold_during_op=design == "venice_hold",
            n_scouts=3 if design == "venice_kscout" else 1,
        )
        n_chan = 0
    else:
        raise ValueError(f"unknown design {design!r}; one of {DESIGNS}")

    is_bus = design in ("baseline", "pssd", "pnssd", "ideal")

    def run(txns: TxnArrays, seed):
        plane_free = jnp.zeros((cfg.n_planes,), jnp.int32)
        if design == "pnssd":
            state = (
                plane_free,
                _triple(n_chan),
                _triple(topo.n_nodes),
                _triple(topo.rows),
            )
        elif is_bus:
            state = (plane_free, _triple(n_chan))
        elif design == "nossd":
            state = (
                plane_free,
                _triple(topo.n_fcs),
                _triple(topo.n_links),
                _triple(topo.n_nodes),
            )
        else:
            state = (
                plane_free,
                _triple(topo.n_fcs),
                _triple(topo.n_links),
                _triple(topo.n_nodes),
                jnp.asarray(seed, jnp.uint32),
            )

        def scan_step(st, tx):
            def real(st):
                return step(st, tx)

            def skip(st):
                out = StepOut(
                    completion=tx.arrival,
                    wait=jnp.int32(0),
                    conflict=jnp.bool_(False),
                    hops=jnp.int32(0),
                    tries=jnp.int32(0),
                    scout_steps=jnp.int32(0),
                    misroutes=jnp.int32(0),
                    bus_hold=jnp.int32(0),
                    link_hold=jnp.int32(0),
                )
                return st, out

            return jax.lax.cond(tx.valid, real, skip, st)

        _, outs = jax.lax.scan(scan_step, state, txns)
        return outs

    return jax.jit(run), topo


class SimResult(NamedTuple):
    design: str
    completion: np.ndarray  # ticks, per txn (valid only)
    latency: np.ndarray  # ticks, per txn
    req_latency: np.ndarray  # ticks, per host request (GC excluded)
    wait: np.ndarray
    conflict: np.ndarray
    hops: np.ndarray
    tries: np.ndarray
    misroutes: np.ndarray
    exec_ticks: int
    bus_hold_ticks: int
    link_hold_ticks: int
    flash_energy_j: float
    transfer_energy_j: float
    static_energy_j: float

    @property
    def exec_s(self) -> float:
        return self.exec_ticks * TICK_NS * 1e-9

    @property
    def energy_j(self) -> float:
        return self.flash_energy_j + self.transfer_energy_j + self.static_energy_j

    @property
    def avg_power_w(self) -> float:
        return self.energy_j / max(self.exec_s, 1e-12)

    def iops(self, n_requests: int | None = None) -> float:
        n = len(self.req_latency) if n_requests is None else n_requests
        return n / max(self.exec_s, 1e-12)

    def p99_latency_us(self) -> float:
        return float(np.percentile(self.req_latency, 99)) * TICK_NS * 1e-3

    def latency_cdf_us(self):
        lat = np.sort(self.req_latency) * (TICK_NS * 1e-3)
        return lat, np.arange(1, len(lat) + 1) / len(lat)

    def conflict_rate(self) -> float:
        return float(np.mean(self.conflict))


def _pad_to(n: int) -> int:
    """Bucket pad lengths to limit recompiles."""
    size = 1024
    while size < n:
        size *= 2
    return size


def _nominal_order(cfg: SSDConfig, txns) -> np.ndarray:
    """Order transactions by *nominal network-transfer time* (FIFO per plane,
    zero network contention).  The scan commits resources in this order, so
    commitments are near-chronological — the property that makes the in-order
    O(1)-state commit faithful to an event-driven simulator.  A write stuck
    behind a 100 us tPROG no longer reserves links/buses ahead of thousands
    of transfers that really happen first."""
    arrival = np.asarray(txns["arrival"], dtype=np.int64)
    kind = np.asarray(txns["kind"])
    plane = np.asarray(txns["plane"])
    nbytes = np.asarray(txns["nbytes"], dtype=np.int64)
    arr_order = np.argsort(arrival, kind="stable")
    plane_avail = np.zeros((cfg.n_planes,), dtype=np.int64)
    xfer_est = nbytes // TICK_NS  # ~1 B/ns
    nominal = np.zeros_like(arrival)
    t_r, t_w, t_e = cfg.t_read, cfg.t_prog, cfg.t_erase
    for i in arr_order:
        p = plane[i]
        s = max(arrival[i], plane_avail[p])
        k = kind[i]
        if k == KIND_READ:
            ready = s + 1 + t_r
            nominal[i] = ready
            plane_avail[p] = ready + xfer_est[i]
        elif k == KIND_WRITE:
            nominal[i] = s
            plane_avail[p] = s + xfer_est[i] + t_w
        else:
            nominal[i] = s
            plane_avail[p] = s + t_e
    return np.argsort(nominal, kind="stable")


def simulate(cfg: SSDConfig, txns, design: str, seed: int = 0) -> SimResult:
    """Run one (config, design) simulation over numpy transaction arrays.

    ``txns`` is a dict/namespace with numpy fields: arrival (ticks int), kind,
    plane, node, row, nbytes (see ``repro.ssd.ftl.decompose_trace``).
    """
    n = len(txns["arrival"])
    n_pad = _pad_to(n)
    order = _nominal_order(cfg, txns)

    def f(name, dtype, fill=0):
        a = np.full((n_pad,), fill, dtype=dtype)
        a[:n] = np.asarray(txns[name])[order].astype(dtype)
        return jnp.asarray(a)

    kind = np.asarray(txns["kind"])[order].astype(np.int32)
    op = np.where(
        kind == KIND_READ,
        cfg.t_read,
        np.where(kind == KIND_WRITE, cfg.t_prog, cfg.t_erase),
    ).astype(np.int32)
    op_pad = np.zeros((n_pad,), np.int32)
    op_pad[:n] = op
    valid = np.zeros((n_pad,), bool)
    valid[:n] = True

    arrs = TxnArrays(
        arrival=f("arrival", np.int32),
        kind=f("kind", np.int32),
        plane=f("plane", np.int32),
        node=f("node", np.int32),
        row=f("row", np.int32),
        nbytes=f("nbytes", np.int32),
        op_ticks=jnp.asarray(op_pad),
        valid=jnp.asarray(valid),
    )

    run, topo = _build_sim(cfg, design, n_pad)
    outs = jax.device_get(run(arrs, np.uint32(seed | 1)))

    completion = outs.completion[:n]
    arrival = np.asarray(txns["arrival"])[order]
    latency = completion - arrival
    exec_ticks = int(completion.max() - arrival.min()) if n else 0

    # host-request latency: completion of a request = max over its page txns
    req = np.asarray(txns["req"])[order]
    n_req = int(req.max()) + 1 if len(req) and req.max() >= 0 else 0
    req_done = np.zeros((n_req,), np.int64)
    req_arr = np.full((n_req,), np.iinfo(np.int64).max)
    host = req >= 0
    np.maximum.at(req_done, req[host], completion[host].astype(np.int64))
    np.minimum.at(req_arr, req[host], arrival[host].astype(np.int64))
    seen = req_arr < np.iinfo(np.int64).max
    req_latency = (req_done - req_arr)[seen]

    pm = cfg.power
    tick_s = TICK_NS * 1e-9
    die_w = np.where(
        kind == KIND_READ,
        pm.die_read_w,
        np.where(kind == KIND_WRITE, pm.die_prog_w, pm.die_erase_w),
    )
    flash_energy = float(np.sum(op.astype(np.float64) * tick_s * die_w))
    bus_hold = int(outs.bus_hold[:n].astype(np.int64).sum())
    link_hold = int(outs.link_hold[:n].astype(np.int64).sum())
    transfer_energy = (
        bus_hold * tick_s * pm.bus_active_w + link_hold * tick_s * pm.link_active_w
    )
    n_routers = topo.n_nodes if design.startswith(("venice", "nossd")) else 0
    static_energy = (pm.static_w + n_routers * pm.router_w) * exec_ticks * tick_s

    return SimResult(
        design=design,
        completion=completion,
        latency=latency,
        req_latency=req_latency,
        wait=outs.wait[:n],
        conflict=outs.conflict[:n],
        hops=outs.hops[:n],
        tries=outs.tries[:n],
        misroutes=outs.misroutes[:n],
        exec_ticks=exec_ticks,
        bus_hold_ticks=bus_hold,
        link_hold_ticks=link_hold,
        flash_energy_j=flash_energy,
        transfer_energy_j=float(transfer_energy),
        static_energy_j=float(static_energy),
    )
