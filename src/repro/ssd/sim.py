"""Jitted discrete-resource SSD simulator over the table-driven design substrate.

Replaces MQSim's event-driven C++ core with a ``lax.scan`` over page-level
transactions in arrival order: each step computes the transaction's start time
from the *free-at* state of every resource it needs (plane, flash controller,
channel or mesh links), commits its occupancy, and emits completion/energy
stats.  Venice's path reservation runs the Algorithm-1 scout engine
(``core/scout.py``) inside the scan, retrying at the next link-free event when
a scout fails — exactly the paper's "retry immediately" policy (§4.2).

There is exactly ONE scan step function.  Designs are not code paths: each
design in ``repro.ssd.designs.REGISTRY`` lowers to padded tables
(``LaneTables``) over a unified resource vector ``[links | FCs | chips]``,
and the step consumes only those arrays — shared buses are 1-link "meshes"
with routing disabled (the scout degenerates to a zero-length path), pnSSD
is two candidate 1-link masks, NoSSD is a static XY-path mask, Venice builds
its mask with the scout at runtime.  ``simulate_sweep`` routes every lane
through the sweep planner (``repro.ssd.sweep_plan``): lanes are pooled per
cost class (statically-routed vs scout-routed), row-confined static lanes
are channel-decomposed, and lanes run as unbatched chunk-trimmed scans
dispatched asynchronously across the host CPU devices — all bit-identical
to the flat scan of ``simulate``.  Executables take the design tables as
*arguments*, so they are design-agnostic: changing the design set never
recompiles; one executable per (geometry, capacity bucket, cost class,
promotions, device) serves every lane, workload, config and phase.

Designs (see ``designs.REGISTRY`` for the spec + ablation docs of each)
  baseline        multi-channel shared bus (Table 1)
  pssd            Kim+ [15]: packetized, 2x channel bandwidth
  pnssd           Kim+ [15]: row+column shared buses (two paths per chip)
  nossd           Tavakkol+ [38]: 2D mesh, deterministic XY routing
  venice          the paper: scout path reservation + non-minimal adaptive
  venice_minimal  ablation: Venice with minimal-only adaptive routing
  venice_hold     ablation: circuit held across CMD+tR+transfer (the paper's
                  per-transfer reservation recovers these link-hours)
  venice_kscout   beyond-paper: race 3 scouts, commit the fewest-hop success
  ideal           path-conflict-free: a private channel per chip

Approximations vs MQSim (all documented in DESIGN.md §3): in-order commit per
transaction; single-gap backfill per shared resource (captures CMD-during-tR
and one-deep data backfill — the dominant pipelining in a real channel);
NoSSD's buffered wormhole modeled as transient circuits per packet phase.
"""
from __future__ import annotations

import functools
import os
import threading
import time
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.scout import make_tables, scout_route
from repro.core.topology import build_mesh
from repro.obs import spans as obs_spans
from repro.kernels import onehot
from repro.kernels.ops import route_dfs
from repro.kernels.scout_step import pack_tables, scout_step_pallas, step_math
from repro.ssd.config import SSDConfig, TICK_NS
from repro.ssd.designs import (
    DESIGNS,
    REGISTRY,
    LaneTables,
    resolve_specs,
    sweep_layout_geom,
)

__all__ = [
    "DESIGNS", "TxnArrays", "StepOut", "SimResult", "simulate",
    "simulate_sweep",
]

_BIG = np.int32(2**30)
_MAX_TRIES = 64  # scout retry bound per reservation

# Reservation-failure timeout (ISSUE 8): a transaction whose every candidate
# path crosses a dead resource (``LaneTables.res_dead``) can never reserve.
# Statically-routed designs have no alternative to retry, so the bounded
# timeout-and-retry budget collapses to this one constant; scout designs
# first burn their real retry schedule (``_MAX_TRIES`` event-driven retries
# — the backoff is the advance to the next link-state change) and only a
# scout that still cannot reach the chip gives up.  Either way the
# transaction completes at ``t + FAIL_TIMEOUT`` with ``failed=True``, holds
# no path resources, and frees its plane at the timeout — permanent-failure
# accounting, not silent loss.  ~10.5 ms at the 10 ns tick.
FAIL_TIMEOUT = np.int32(1 << 20)

# Lane-step kernel backend for the batched static runner.  "xla" keeps
# the one-hot XLA step (the CPU default — interpret-mode Pallas lowers
# to the same ops plus per-step call scaffolding, so on CPU it is pure
# overhead); "pallas" compiles the lane-tiled pallas_call from
# ``kernels.batched_step`` (GPU/TPU), degrading honestly to
# "pallas-interpret" on CPU where Pallas has no compiler; "auto" picks
# pallas on an accelerator and xla on CPU.  Settable via the
# REPRO_LANE_BACKEND env var or ``benchmarks/run.py --lane-backend``.
LANE_BACKEND = os.environ.get("REPRO_LANE_BACKEND", "xla")
_LANE_BACKENDS = ("xla", "pallas", "pallas-interpret", "auto")
_ACCEL_BACKENDS = ("gpu", "tpu", "cuda", "rocm")


def resolve_lane_backend(setting: str | None = None) -> str:
    """Resolve ``setting`` (default: module ``LANE_BACKEND``) to a concrete
    backend name — "xla", "pallas" (compiled) or "pallas-interpret" —
    for the JAX backend actually in use."""
    s = setting if setting is not None else LANE_BACKEND
    if s not in _LANE_BACKENDS:
        raise ValueError(
            f"unknown lane backend {s!r}; pick from {_LANE_BACKENDS}")
    on_accel = jax.default_backend() in _ACCEL_BACKENDS
    if s == "auto":
        return "pallas" if on_accel else "xla"
    if s == "pallas" and not on_accel:
        return "pallas-interpret"
    return s

KIND_READ, KIND_WRITE, KIND_ERASE = 0, 1, 2


class TxnArrays(NamedTuple):
    """Page-level transactions, sorted by arrival (ticks)."""

    arrival: jnp.ndarray  # int32 [n]
    kind: jnp.ndarray  # int32 [n] 0=read 1=write 2=erase
    plane: jnp.ndarray  # int32 [n] global plane id
    node: jnp.ndarray  # int32 [n] chip / mesh node id
    row: jnp.ndarray  # int32 [n] channel id
    nbytes: jnp.ndarray  # int32 [n]
    op_ticks: jnp.ndarray  # int32 [n] tR/tPROG/tBERS by kind
    valid: jnp.ndarray  # bool  [n] padding mask


class StepOut(NamedTuple):
    completion: jnp.ndarray  # int32 ticks
    wait: jnp.ndarray  # int32 ticks spent waiting on the path (conflict time)
    conflict: jnp.ndarray  # bool — experienced a path conflict (fig. 13)
    hops: jnp.ndarray  # int32 (mesh designs; 0 for bus designs)
    tries: jnp.ndarray  # int32 scout attempts (venice)
    scout_steps: jnp.ndarray  # int32 DFS steps (venice)
    misroutes: jnp.ndarray  # int32 non-minimal hops on final path (venice)
    bus_hold: jnp.ndarray  # int32 ticks a shared bus was held
    link_hold: jnp.ndarray  # int32 link-ticks (sum over links held)
    failed: jnp.ndarray  # bool — permanent reservation failure (dead path)


# ---------------------------------------------------------------------------
# resource scheduling primitives
#
# Every time-shared resource (bus channel, mesh link, flash controller, chip
# I/O interface) is a triple of arrays (free_at, gap_s, gap_e): busy through
# ``free_at`` except one remembered idle gap [gap_s, gap_e).  The in-order
# scan can commit transfers far in the future (e.g. a write waiting on a
# 100 us tPROG), and the remembered gap keeps the resource's *current* idle
# capacity usable by later transactions instead of ratcheting free_at
# forward — the one-gap interval model is what keeps this O(1)-state
# simulator faithful to an event-driven scheduler to first order.
# ---------------------------------------------------------------------------


def _gap_avail(gs, ge, fa, e, d):
    """Earliest start >= e where a d-tick usage fits (gap or tail)."""
    s_gap = jnp.maximum(e, gs)
    fits = (s_gap + d) <= ge
    return jnp.where(fits, s_gap, jnp.maximum(e, fa))


def _gap_commit(gs, ge, fa, s, e2):
    """Carve the interval [s, e2) out; remember the larger leftover gap."""
    in_gap = (s >= gs) & (e2 <= ge)
    # inside the gap: keep the larger of the two leftover sides
    left_bigger = (s - gs) >= (ge - e2)
    g_gs = jnp.where(left_bigger, gs, e2)
    g_ge = jnp.where(left_bigger, s, ge)
    # appended at/after free_at: keep the larger of (old gap, new idle span)
    new_idle = jnp.maximum(s, fa) - fa
    keep_old = (ge - gs) >= new_idle
    a_gs = jnp.where(keep_old, gs, fa)
    a_ge = jnp.where(keep_old, ge, jnp.maximum(s, fa))
    a_fa = jnp.maximum(fa, e2)
    return (
        jnp.where(in_gap, g_gs, a_gs),
        jnp.where(in_gap, g_ge, a_ge),
        jnp.where(in_gap, fa, a_fa),
    )


def _avail1(res, i, e, d):
    free, gap_s, gap_e = res
    return _gap_avail(gap_s[i], gap_e[i], free[i], e, d)


def _commit1(res, i, s, e2, enable):
    free, gap_s, gap_e = res
    gs, ge, fa = _gap_commit(gap_s[i], gap_e[i], free[i], s, e2)
    return (
        free.at[i].set(jnp.where(enable, fa, free[i])),
        gap_s.at[i].set(jnp.where(enable, gs, gap_s[i])),
        gap_e.at[i].set(jnp.where(enable, ge, gap_e[i])),
    )


def _avail_all(res, e, d):
    """Vectorized earliest-start for every resource in the triple."""
    free, gap_s, gap_e = res
    return _gap_avail(gap_s, gap_e, free, e, d)


def _busy_at(res, t, d):
    """bool per resource: cannot host a d-tick usage starting exactly at t."""
    free, gap_s, gap_e = res
    free_ok = t >= free
    gap_ok = (t >= gap_s) & ((t + d) <= gap_e)
    return ~(free_ok | gap_ok)


def _commit_mask(res, mask, s, e2, enable):
    free, gap_s, gap_e = res
    gs, ge, fa = _gap_commit(gap_s, gap_e, free, s, e2)
    take = mask & enable
    return (
        jnp.where(take, fa, free),
        jnp.where(take, gs, gap_s),
        jnp.where(take, ge, gap_e),
    )


def _sched_gap(res, i, e, d, enable):
    """Schedule a d-tick usage of resource ``i`` at the earliest time >= e."""
    s = _avail1(res, i, e, d)
    s = jnp.where(enable, s, e)
    res = _commit1(res, i, s, s + d, enable)
    return s, res


def _triple(n: int):
    z = jnp.zeros((n,), jnp.int32)
    return (z, z, z)


def _ceil_div(a, b):
    return (a + b - 1) // b


# ---------------------------------------------------------------------------
# the one scan step — consumes only LaneTables arrays
# ---------------------------------------------------------------------------


# Per-design scalars that are promoted to compile-time constants when every
# lane of a sweep group agrees on the value (always true for 1-lane
# ``simulate`` and the common homogeneous sweeps).  XLA then folds the
# selects/arithmetic and dead-code-eliminates the untaken design variant's
# subgraph, so a homogeneous program is as lean as a hand-written one,
# while heterogeneous sweeps keep the scalars traced and stay fully generic.
_PROMOTABLE = (
    "hold", "allow_nonmin", "n_scouts", "fc_nearest", "count_bus",
    "ovh", "cmd_base_ns", "xfer_num", "xfer_den", "hop_ns",
    "d_est_hops", "d_est_pad",
)


def _make_step(lay, stables, scout_hop_ns: int, n_planes: int, k_max: int,
               has_static: bool, fixed: tuple):
    """Build the design-agnostic scan step.

    ``sp`` below is one lane's view of :class:`LaneTables` (the design axis
    is handled by ``vmap`` in ``_build_sweep``); everything the step knows
    about the design comes from those arrays.  The only static knobs are
    ``k_max`` (max scouts raced), the cost-class flag ``has_static`` (a
    statically-routed group compiles no scout machinery and a scout group
    no candidate scheduling), and ``fixed`` (values of ``_PROMOTABLE``
    scalars shared by every lane, or None when mixed) — each class's
    program is as lean as the seed's hand-written per-design steps.

    Returns ``(init_state, step)``; the two classes carry different scan
    state (the static class schedules over one unified resource vector, the
    scout class over separate link/FC/chip pools, narrow like the original
    hand-written Venice step).
    """
    L0, F0, R_pad = lay.L_pad, lay.F_pad, lay.R_pad
    n_fcs = lay.rows
    fixed = dict(zip(_PROMOTABLE, fixed))

    def fx(sp, name):
        v = fixed[name]
        return getattr(sp, name) if v is None else v

    def cmd_ticks(sp, hops):
        ns = fx(sp, "cmd_base_ns") + hops * fx(sp, "hop_ns")
        return jnp.maximum(_ceil_div(ns, TICK_NS), 1).astype(jnp.int32)

    def xfer_ticks(sp, nbytes, hops):
        ns = _ceil_div(nbytes * fx(sp, "xfer_num"), fx(sp, "xfer_den"))
        ns = ns + hops * fx(sp, "hop_ns")
        return _ceil_div(ns, TICK_NS).astype(jnp.int32)

    def path_sched(res, mask, e, d):
        """Earliest common start >= e for a d-tick usage of every masked
        resource.  Per-resource availability first; if the joint candidate
        doesn't fit everywhere, fall back to the masked free_at tail."""
        avail = _avail_all(res, e, d)
        s1 = jnp.max(jnp.where(mask, avail, 0))
        s1 = jnp.maximum(s1, e)
        ok = ~jnp.any(_busy_at(res, s1, d) & mask)
        s_tail = jnp.maximum(e, jnp.max(jnp.where(mask, res[0], 0)))
        return jnp.where(ok, s1, s_tail)

    def fc_select(avail, dist_row, tcand):
        """Paper §4.2: closest FC *available now*, else earliest-available
        (availability = can host a d_est-tick transfer, gap-aware)."""
        free_now = avail <= tcand
        any_free = jnp.any(free_now)
        by_dist = jnp.argmin(jnp.where(free_now, dist_row, _BIG))
        by_time = jnp.argmin(avail)
        fc = jnp.where(any_free, by_dist, by_time).astype(jnp.int32)
        t0 = jnp.maximum(tcand, avail[fc])
        return fc, t0

    def eval_static_cand(sp, res, tx, is_read, t0, fc, cand, enable):
        """One statically-routed candidate: phase 0 (command, +data for
        writes), flash op, phase 1 (read data) on one combined mask.
        A candidate whose mask touches a dead resource is value-dead:
        its commits are disabled and ``dead`` is returned for selection."""
        mask = sp.cmask[fc, tx.node, cand]
        dead = jnp.any(mask & sp.res_dead)
        enable = enable & ~dead
        hops = sp.hops[fc, tx.node, cand]
        cmd = cmd_ticks(sp, hops)
        xfer = xfer_ticks(sp, tx.nbytes, hops)
        ovh = fx(sp, "ovh")
        d0 = ovh + cmd + jnp.where(is_read, 0, xfer)
        s0 = path_sched(res, mask, t0, d0)
        res = _commit_mask(res, mask, s0, s0 + d0, enable)
        op_end = s0 + d0 + tx.op_ticks
        d1 = ovh + xfer
        s1 = path_sched(res, mask, op_end, d1)
        res = _commit_mask(res, mask, s1, s1 + d1, enable & is_read)
        done = jnp.where(is_read, s1 + d1, op_end)
        wait = (s0 - t0) + jnp.where(is_read, s1 - op_end, 0)
        occ = d0 + jnp.where(is_read, d1, 0)  # resource-held ticks
        return res, done, wait, occ, hops, dead

    def scout_until_success(links3, sp, src, dst, t0, rng, d_hold):
        """Retry the scout at successive link-free events until it reserves.

        A link is busy for the scout if it cannot host a ``d_hold``-tick
        reservation starting now (gap-aware).  ``k_max`` scouts race per
        try with independent tie-break streams; scouts beyond the lane's
        ``n_scouts`` are masked out (their rng is not advanced), so a
        1-scout lane in a k-scout sweep is bit-identical to a 1-scout
        program."""
        n_scouts = fx(sp, "n_scouts")
        allow = fx(sp, "allow_nonmin")
        # dead links look permanently busy to the DFS, so the scout routes
        # AROUND faults (the whole point of path diversity); an all-False
        # res_dead makes this OR a no-op — fault-free bit-identity
        dead_links = sp.res_dead[:L0]

        def try_once(t, rng):
            busy = _busy_at(links3, t, d_hold) | dead_links
            best = None
            for k in range(k_max):
                rng_adv = (
                    rng * jnp.uint32(747796405) + jnp.uint32(2891336453)
                ) | jnp.uint32(1)
                active = k < n_scouts  # bool or traced bool
                rng = jnp.where(active, rng_adv, rng)
                res = scout_route(stables, src, dst, busy, rng, allow)
                if best is None:
                    best = res
                else:
                    take = res.success & active & (
                        (~best.success) | (res.hops < best.hops)
                    )
                    best = jax.tree_util.tree_map(
                        lambda a, b: jnp.where(take, a, b), res, best
                    )
            return best, rng

        res0, rng = try_once(t0, rng)

        def cond(carry):
            res, t, rng, tries = carry
            return (~res.success) & (tries < _MAX_TRIES)

        def body(carry):
            res, t, rng, tries = carry
            # advance to the next potential link-state change:
            # a free_at passing, or an idle gap opening
            free, gap_s, _ = links3
            ev = jnp.minimum(
                jnp.min(jnp.where(free > t, free, _BIG)),
                jnp.min(jnp.where(gap_s > t, gap_s, _BIG)),
            )
            t_next = jnp.maximum(ev, t + 1)
            t_next = jnp.where(tries + 1 >= _MAX_TRIES, jnp.max(free), t_next)
            res, rng = try_once(t_next, rng)
            return res, t_next, rng, tries + 1

        res, t, rng, tries = jax.lax.while_loop(
            cond, body, (res0, t0, rng, jnp.int32(1))
        )
        return res, t, rng, tries

    def d_est_of(sp, tx, is_read, hold):
        """Duration estimate for availability checks (FC selection + scout)."""
        d_est = (xfer_ticks(sp, tx.nbytes, fx(sp, "d_est_hops"))
                 + fx(sp, "d_est_pad"))
        if hold is not False:  # hold lanes park the circuit across reads' tR
            d_est = d_est + jnp.where(
                jnp.logical_and(hold, is_read), tx.op_ticks, 0
            )
        return d_est

    def static_step(sp, state, tx: TxnArrays):
        # ---- statically-routed lanes: <=2 candidate combined masks over
        # the unified [links | FCs | chips] resource vector ----
        plane_free, res = state
        is_read = tx.kind == KIND_READ
        tcand = jnp.maximum(tx.arrival, plane_free[tx.plane])
        fc_nearest = fx(sp, "fc_nearest")
        count_bus = fx(sp, "count_bus")

        d_est = d_est_of(sp, tx, is_read, fx(sp, "hold"))
        free, gs, ge = res
        sl = slice(L0, L0 + F0)
        avail = _gap_avail(gs[sl], ge[sl], free[sl], tcand, d_est)
        avail = jnp.where(sp.fc_valid, avail, _BIG)
        fc_near, t0_near = fc_select(avail, sp.dist[:, tx.node], tcand)
        t0 = jnp.where(fc_nearest, t0_near, tcand)

        fcA = jnp.where(fc_nearest, fc_near, sp.fc_fixed[tx.node, 0])
        fcB = jnp.where(fc_nearest, fc_near, sp.fc_fixed[tx.node, 1])
        cand2 = sp.cand2_ok[tx.node]
        resA, doneA, waitA, occA, hopsA, deadA = eval_static_cand(
            sp, res, tx, is_read, t0, fcA, 0, tx.valid
        )
        resB, doneB, waitB, occB, hopsB, deadB = eval_static_cand(
            sp, res, tx, is_read, t0, fcB, 1, tx.valid & cand2
        )
        # a dead candidate never wins selection; when every candidate is
        # dead, the reservation fails permanently (FAIL_TIMEOUT accounting).
        # With all-False res_dead this reduces exactly to the fault-free
        # ``doneA <= where(cand2, doneB, _BIG)`` — bit-identical outputs.
        useA = jnp.where(deadA, _BIG, doneA) <= jnp.where(
            cand2 & ~deadB, doneB, _BIG
        )
        failed = deadA & (deadB | ~cand2)
        res = jax.tree_util.tree_map(
            lambda a, b: jnp.where(useA, a, b), resA, resB
        )
        done = jnp.where(useA, doneA, doneB)
        wait = jnp.where(useA, waitA, waitB)
        occ = jnp.where(useA, occA, occB)
        hops_o = jnp.where(useA, hopsA, hopsB)
        done = jnp.where(failed, tcand + FAIL_TIMEOUT, done)
        wait = jnp.where(failed, FAIL_TIMEOUT, wait)
        occ = jnp.where(failed, 0, occ)
        hops_o = jnp.where(failed, 0, hops_o)
        plane_free = plane_free.at[tx.plane].set(
            jnp.where(tx.valid, done, plane_free[tx.plane])
        )
        out = StepOut(
            completion=done,
            wait=wait,
            conflict=wait > 0,
            hops=hops_o,
            tries=jnp.int32(1),
            scout_steps=jnp.int32(0),
            misroutes=jnp.int32(0),
            bus_hold=jnp.where(count_bus, occ, 0),
            link_hold=jnp.where(count_bus, 0, hops_o * occ),
            failed=failed,
        )
        return (plane_free, res), out

    def scout_step(sp, state, tx: TxnArrays):
        # ---- scout-routed lanes (Venice §4): per-transfer circuit over
        # separate link/FC/chip pools (narrow state keeps the hot scan as
        # lean as a hand-written Venice step) ----
        plane_free, links, fcs, chips, rng = state
        is_read = tx.kind == KIND_READ
        tcand = jnp.maximum(tx.arrival, plane_free[tx.plane])
        hold = fx(sp, "hold")

        d_est = d_est_of(sp, tx, is_read, hold)
        avail = _avail_all(fcs, tcand, d_est)
        # dead FCs (fc_valid lowered False by the FaultSpec) are never
        # selected; all-valid lanes see ``where(True, avail, _BIG)`` — a
        # no-op, so the fault-free program output is unchanged
        avail = jnp.where(sp.fc_valid[:n_fcs], avail, _BIG)
        fc, t0 = fc_select(avail, sp.dist[:n_fcs, tx.node], tcand)
        src = sp.fc_node[fc]
        min_hops = sp.dist[fc, tx.node]
        cmd_pkt = cmd_ticks(sp, min_hops)  # read cmd: scout-sized packet
        # reads: command packet now; data-phase scout at tR completion
        # (paper mode only — hold mode keeps one circuit for everything)
        en_cmd = tx.valid & is_read & jnp.logical_not(hold)
        s_cmd, fcs = _sched_gap(fcs, fc, t0, cmd_pkt, en_cmd)
        ready_r = s_cmd + cmd_pkt + tx.op_ticks  # data in page buffer
        # the data transfer additionally needs this FC and the chip's I/O
        # interface (the FC tracks chip status and only scouts when the
        # transfer can actually start)
        t_nonread = jnp.maximum(t0, _avail1(chips, tx.node, t0, d_est))
        t_read = jnp.maximum(
            jnp.maximum(ready_r, _avail1(fcs, fc, ready_r, d_est)),
            _avail1(chips, tx.node, ready_r, d_est),
        )
        t_xfer_req = jnp.where(is_read, t_read, t_nonread)
        t_scout = jnp.where(hold, t0, t_xfer_req)
        sres, t_resv, rng, tries = scout_until_success(
            links, sp, src, tx.node, t_scout, rng, d_est
        )
        hops_o = sres.hops
        rtt = _ceil_div((sres.steps + hops_o) * scout_hop_ns, TICK_NS)
        start = t_resv + rtt.astype(jnp.int32)
        cmd_v = cmd_ticks(sp, hops_o)
        xfer_v = xfer_ticks(sp, tx.nbytes, hops_o)
        # paper mode: read = backward data; write/erase = fwd cmd+data
        dur_p = jnp.where(is_read, xfer_v, cmd_v + xfer_v)
        end_p = start + dur_p
        done_p = jnp.where(is_read, end_p, end_p + tx.op_ticks)
        wait_p = (s_cmd - t0) + (start - t_xfer_req)
        # hold mode: one circuit across CMD + flash op + transfer
        done_r_h = start + cmd_v + tx.op_ticks + xfer_v
        data_end_w = start + cmd_v + xfer_v
        circuit_end = jnp.where(is_read, done_r_h, data_end_w)
        done_h = jnp.where(is_read, done_r_h, data_end_w + tx.op_ticks)
        commit_end = jnp.where(hold, circuit_end, end_p)
        done = jnp.where(hold, done_h, done_p)
        wait = jnp.where(hold, start - t0, wait_p)
        # permanent failure: the scout burned its whole retry schedule (the
        # final try runs against an otherwise-idle mesh, so a fault-free
        # lane can never get here) — no circuit is committed, the txn
        # times out, and its plane frees at the timeout
        fail = ~sres.success
        ok = tx.valid & sres.success
        done = jnp.where(fail, tcand + FAIL_TIMEOUT, done)
        wait = jnp.where(fail, FAIL_TIMEOUT, wait)
        links = _commit_mask(links, sres.path_mask, t_resv, commit_end, ok)
        fcs = _commit1(fcs, fc, t_resv, commit_end, ok)
        chips = _commit1(chips, tx.node, t_resv, commit_end, ok)
        plane_free = plane_free.at[tx.plane].set(
            jnp.where(tx.valid, done, plane_free[tx.plane])
        )
        out = StepOut(
            completion=done,
            wait=wait,
            conflict=(tries > 1) | fail,
            hops=hops_o,
            tries=tries,
            scout_steps=sres.steps,
            misroutes=sres.misroutes,
            bus_hold=jnp.int32(0),
            link_hold=jnp.where(fail, 0, hops_o * (commit_end - t_resv)),
            failed=fail,
        )
        return (plane_free, links, fcs, chips, rng), out

    if has_static:
        def init_state(seed):
            return (jnp.zeros((n_planes,), jnp.int32), _triple(R_pad))

        return init_state, static_step

    def init_state(seed):
        return (
            jnp.zeros((n_planes,), jnp.int32),
            _triple(L0),
            _triple(n_fcs),
            _triple(lay.n_nodes),
            jnp.asarray(seed, jnp.uint32),
        )

    return init_state, scout_step


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------


def _geom_sig(cfg: SSDConfig) -> tuple:
    """The slice of the config the compiled program actually depends on.

    Latencies, page size and channel rates reach the program as traced data
    (txn arrays / LaneTables), so perf- and cost-optimized configs of the
    same geometry share every executable."""
    return (cfg.rows, cfg.cols, cfg.dies_per_chip, cfg.planes_per_die,
            int(round(cfg.scout_flit_ns)))


def _promotions(tables) -> tuple:
    """Common value of each _PROMOTABLE scalar across the group's lanes
    (read from the lowered tables), else None."""
    out = []
    for name in _PROMOTABLE:
        vals = np.asarray(getattr(tables, name))
        if np.all(vals == vals.flat[0]):
            out.append(vals.flat[0].item())  # hashable python bool/int
        else:
            out.append(None)
    return tuple(out)


def _skip_out(tx: TxnArrays) -> StepOut:
    """StepOut emitted for padded (invalid) transactions."""
    return StepOut(
        completion=tx.arrival,
        wait=jnp.int32(0),
        conflict=jnp.bool_(False),
        hops=jnp.int32(0),
        tries=jnp.int32(0),
        scout_steps=jnp.int32(0),
        misroutes=jnp.int32(0),
        bus_hold=jnp.int32(0),
        link_hold=jnp.int32(0),
        failed=jnp.bool_(False),
    )


# ---------------------------------------------------------------------------
# chunked, trimmed, shardable runners
#
# Transactions are packed into *capacity*-sized buffers (few coarse
# power-of-4 buckets, to bound the number of distinct executables) but the
# scan itself is a ``fori_loop`` over CHUNK-step ``lax.scan`` chunks with a
# *traced* trip count: one compiled program serves every trace length, and
# execute time scales with the valid length rounded up to CHUNK — not with
# the capacity bucket.  Each lane (its tables, seed and transaction stream
# are all arguments) runs UNBATCHED inside its device shard of a
# ``shard_map`` group — one lane per host CPU device; the sweep planner
# sorts lanes from many workloads/configs/channel-rows by length so the
# lanes sharing a group's barrier are of similar cost.
#
# NOTE on the XLA CPU runtime: this program shape — nested while-loops
# (scout retry -> DFS -> scan chunk -> fori over chunks) — is pathological
# for XLA's *thunk* CPU runtime: per-iteration executor synchronization
# makes a scout step ~10x slower single-threaded, compiles ~4x slower,
# and concurrent executions contend (measured 3-4x mutual slowdown).
# ``benchmarks/run.py`` and the test conftest therefore force
# ``--xla_cpu_use_thunk_runtime=false`` (the legacy runtime) alongside the
# host device count; both are no-ops for correctness, which the parity
# suite pins either way.
# ---------------------------------------------------------------------------

CHUNK = 1024  # scan-chunk granularity; trims pad waste to < one chunk


def host_device_count() -> int:
    """Lane shards available (== --xla_force_host_platform_device_count)."""
    return len(jax.devices())


@functools.lru_cache(maxsize=None)
def _lane_mesh(n_shards: int) -> Mesh:
    return Mesh(np.array(jax.devices()[:n_shards]), ("lanes",))


def _zero_out(capacity: int) -> StepOut:
    z = jnp.zeros((capacity,), jnp.int32)
    return StepOut(
        completion=z, wait=z, conflict=jnp.zeros((capacity,), jnp.bool_),
        hops=z, tries=z, scout_steps=z, misroutes=z, bus_hold=z, link_hold=z,
        failed=jnp.zeros((capacity,), jnp.bool_),
    )


def _make_lane_run(init_state, step, capacity: int):
    """One lane: chunked scan with a dynamic (traced) chunk count."""

    def lane_run(sp, seed, txns: TxnArrays, n_chunks):
        state = init_state(seed)

        def scan_step(st, tx):
            def real(st):
                return step(sp, st, tx)

            def skip(st):
                return st, _skip_out(tx)

            return jax.lax.cond(tx.valid, real, skip, st)

        def chunk_body(c, carry):
            st, buf = carry
            off = c * CHUNK
            txc = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, off, CHUNK, 0),
                txns,
            )
            st, outs = jax.lax.scan(scan_step, st, txc)
            buf = jax.tree_util.tree_map(
                lambda b, o: jax.lax.dynamic_update_slice_in_dim(b, o, off, 0),
                buf, outs,
            )
            return st, buf

        _, buf = jax.lax.fori_loop(
            0, n_chunks, chunk_body, (state, _zero_out(capacity))
        )
        return buf

    return lane_run


def _make_lane_run_carry(step, capacity: int):
    """State-carrying lane runner (the streaming engine's variant).

    Identical scan body to :func:`_make_lane_run`, but the scan state is an
    *argument* and is returned alongside the output buffer — the streaming
    engine (``repro.ssd.stream``) threads it across window boundaries
    (rebased host-side by the window span).  A window run with the zero
    initial state is bit-identical to the plain runner: same step, same
    chunking, same skip semantics.
    """

    def lane_run(sp, state, txns: TxnArrays, n_chunks):
        def scan_step(st, tx):
            def real(st):
                return step(sp, st, tx)

            def skip(st):
                return st, _skip_out(tx)

            return jax.lax.cond(tx.valid, real, skip, st)

        def chunk_body(c, carry):
            st, buf = carry
            off = c * CHUNK
            txc = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, off, CHUNK, 0),
                txns,
            )
            st, outs = jax.lax.scan(scan_step, st, txc)
            buf = jax.tree_util.tree_map(
                lambda b, o: jax.lax.dynamic_update_slice_in_dim(b, o, off, 0),
                buf, outs,
            )
            return st, buf

        return jax.lax.fori_loop(
            0, n_chunks, chunk_body, (state, _zero_out(capacity))
        )

    return lane_run


def _step_for(sig: tuple, k_max: int, has_scout: bool, fixed: tuple):
    rows, cols, dies, planes_per_die, scout_hop_ns = sig
    topo = build_mesh(rows, cols)
    n_planes = rows * cols * dies * planes_per_die
    lay = sweep_layout_geom(rows, cols)
    stables = make_tables(topo)
    return _make_step(lay, stables, scout_hop_ns, n_planes, k_max,
                      not has_scout, fixed)


@functools.lru_cache(maxsize=None)
def _build_group_fn(sig: tuple, capacity: int, k_max: int,
                    has_scout: bool, fixed: tuple, n_shards: int):
    """One design-agnostic SPMD program per (geometry, capacity bucket,
    cost class, promotions, shard count).  Tables/seeds/txns/chunk-counts
    are all per-lane *arguments*, so every group of the pool — any designs,
    any workloads, any configs of the geometry, any phase — reuses it.

    A group carries exactly one lane per device shard, and the shard body
    SQUEEZES its lane axis before running the scan: the lane stays
    unbatched, which is load-bearing for CPU performance — a real
    ``lax.cond`` skip (never a batched select that executes both branches)
    and dynamic-slice resource indexing (``vmap`` would lower the per-step
    state updates to generic batched gather/scatter kernels, measured ~50x
    slower per scout step).  Multi-core parallelism comes from the shards
    executing in parallel inside the one program, not from batching; each
    shard's ``fori_loop`` trip count is its own lane's."""
    init_state, step = _step_for(sig, k_max, has_scout, fixed)
    lane_run = _make_lane_run(init_state, step, capacity)

    def one(sp, seed, txns, n_chunks):
        take0 = lambda a: a[0]
        out = lane_run(
            jax.tree_util.tree_map(take0, sp), seed[0],
            jax.tree_util.tree_map(take0, txns), n_chunks[0],
        )
        return jax.tree_util.tree_map(lambda a: a[None], out)

    if n_shards > 1:
        spec = (P("lanes"),) * 4
        fn = shard_map(one, mesh=_lane_mesh(n_shards), in_specs=spec,
                       out_specs=P("lanes"), check_rep=False)
    else:
        fn = one
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _build_group_fn_carry(sig: tuple, capacity: int, k_max: int,
                          has_scout: bool, fixed: tuple, n_shards: int):
    """State-carrying variant of :func:`_build_group_fn` (``"lanec"``).

    The scan state rides as a per-lane argument and comes back with the
    outputs, so one executable serves every window of a streamed replay:
    the streaming engine rebases the returned state host-side and feeds it
    to the next window's dispatch.  Same shard/squeeze discipline as the
    plain group fn — the lane stays unbatched inside its shard."""
    _, step = _step_for(sig, k_max, has_scout, fixed)
    lane_run = _make_lane_run_carry(step, capacity)

    def one(sp, state, txns, n_chunks):
        take0 = lambda a: a[0]
        st, out = lane_run(
            jax.tree_util.tree_map(take0, sp),
            jax.tree_util.tree_map(take0, state),
            jax.tree_util.tree_map(take0, txns), n_chunks[0],
        )
        add = lambda a: a[None]
        return (
            jax.tree_util.tree_map(add, st),
            jax.tree_util.tree_map(add, out),
        )

    if n_shards > 1:
        spec = (P("lanes"),) * 4
        fn = shard_map(one, mesh=_lane_mesh(n_shards), in_specs=spec,
                       out_specs=P("lanes"), check_rep=False)
    else:
        fn = one
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# stacked small-lane variant: K lanes per shard, executed SEQUENTIALLY
#
# A pool of many tiny lanes (the QoS tail phase: hundreds of 1-2 chunk
# scans) used to pay one dispatch barrier per n_shards lanes.  ``lax.map``
# runs K lanes per shard one after another *inside* one program: the inner
# scan stays unbatched (``lax.map`` is a scan, not a vmap — no batched
# gather/scatter lowering), so per-step cost is identical; only the
# dispatch count drops K-fold.  Used for scout-routed small lanes; the
# statically-routed ones get the truly batched runner below.
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _build_stack_fn(sig: tuple, capacity: int, K: int, k_max: int,
                    has_scout: bool, fixed: tuple, n_shards: int):
    init_state, step = _step_for(sig, k_max, has_scout, fixed)
    lane_run = _make_lane_run(init_state, step, capacity)

    def one(sp, seed, txns, n_chunks):  # leading axis [K] per shard
        def run1(args):
            sp1, s1, t1, n1 = args
            return lane_run(sp1, s1, t1, n1)

        return jax.lax.map(run1, (sp, seed, txns, n_chunks))

    if n_shards > 1:
        spec = (P("lanes"),) * 4
        fn = shard_map(one, mesh=_lane_mesh(n_shards), in_specs=spec,
                       out_specs=P("lanes"), check_rep=False)
    else:
        fn = one
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# gather-free batched small-lane runner (statically-routed lanes)
#
# PR 3's negative result — vmap-batched lanes ~50x slower per step on CPU —
# was a property of the *lowering*, not of batching: under vmap the
# per-step table lookups become generic batched gathers, the state updates
# batched scatters, and the validity ``cond`` a both-branches select.  The
# batched step below contains none of those:
#
#   * node-indexed design tables (cmask/hops/dist/cand2/fc_fixed) are
#     resolved per transaction HOST-SIDE (``designs.pregather_node_tables``
#     — the stream is known before the scan) and ride the scan as sliced
#     inputs, bit-packed along the resource axis;
#   * the two state-dependent lookups (plane free-at, live FC choice) are
#     one-hot compare-and-reduce (``repro.kernels.onehot``, the scout-
#     kernel trick) — exact for int32, no gather;
#   * validity is masked arithmetic: commits/updates already carry an
#     ``enable`` lane, and the skip-output substitution is a ``where`` —
#     bit-identical to the unbatched ``lax.cond`` skip because an invalid
#     step's state writes are all disabled.
#
# One dispatch now serves a whole batch of small lanes (the dispatch-bound
# tail phase collapses ~10x), while every per-lane result stays bit-exact
# vs the unbatched scan (pinned for every statically-routed design in
# tests/test_batched_lanes.py).  Scout lanes are excluded: their DFS
# while-loop diverges per lane (use the stacked variant above).
# ---------------------------------------------------------------------------


class BatchScalars(NamedTuple):
    """Per-lane design scalars of a batched group ([B], order of
    ``_PROMOTABLE``) plus the FC validity row ([B, F_pad]) and the
    failed-resource mask ([B, R_pad], all-False when fault-free)."""

    hold: jnp.ndarray
    allow_nonmin: jnp.ndarray
    n_scouts: jnp.ndarray
    fc_nearest: jnp.ndarray
    count_bus: jnp.ndarray
    ovh: jnp.ndarray
    cmd_base_ns: jnp.ndarray
    xfer_num: jnp.ndarray
    xfer_den: jnp.ndarray
    hop_ns: jnp.ndarray
    d_est_hops: jnp.ndarray
    d_est_pad: jnp.ndarray
    fc_valid: jnp.ndarray
    res_dead: jnp.ndarray


class BatchTxnTables(NamedTuple):
    """Per-transaction pre-gathered node tables, time-major [cap, B, ...]
    (see ``designs.pregather_node_tables``)."""

    mask_words: jnp.ndarray  # uint8 [cap, B, F_pad, 2, ceil(R_pad/8)]
    hops: jnp.ndarray  # int32 [cap, B, F_pad, 2]
    dist: jnp.ndarray  # int32 [cap, B, F_pad]
    cand2: jnp.ndarray  # bool  [cap, B]
    fc_fixed: jnp.ndarray  # int32 [cap, B, 2]


def _make_batched_static_step(lay, n_planes: int, fixed: tuple):
    """The statically-routed scan step over a lane batch [B].

    Mirrors ``static_step`` in ``_make_step`` operation for operation
    (all int32 — the one-hot reductions and masked selects are exact, so
    batched == unbatched bit-for-bit); consult that function for the
    modeling semantics.  ``xs`` is ``(TxnArrays, BatchTxnTables)`` with
    every field carrying a leading [B] axis for this step.
    """
    L0, F0, R = lay.L_pad, lay.F_pad, lay.R_pad
    fixed = dict(zip(_PROMOTABLE, fixed))

    def fx(sp, name):
        v = fixed[name]
        return getattr(sp, name) if v is None else v

    def cmd_ticks(sp, hops):
        ns = fx(sp, "cmd_base_ns") + hops * fx(sp, "hop_ns")
        return jnp.maximum(_ceil_div(ns, TICK_NS), 1).astype(jnp.int32)

    def xfer_ticks(sp, nbytes, hops):
        ns = _ceil_div(nbytes * fx(sp, "xfer_num"), fx(sp, "xfer_den"))
        ns = ns + hops * fx(sp, "hop_ns")
        return _ceil_div(ns, TICK_NS).astype(jnp.int32)

    def path_sched(res, mask, e, d):
        free, gap_s, gap_e = res
        avail = _gap_avail(gap_s, gap_e, free, e[:, None], d[:, None])
        s1 = jnp.max(jnp.where(mask, avail, 0), axis=1)
        s1 = jnp.maximum(s1, e)
        busy = _busy_at(res, s1[:, None], d[:, None])
        ok = ~jnp.any(busy & mask, axis=1)
        s_tail = jnp.maximum(e, jnp.max(jnp.where(mask, free, 0), axis=1))
        return jnp.where(ok, s1, s_tail)

    def commit_mask(res, mask, s, e2, enable):
        free, gap_s, gap_e = res
        gs, ge, fa = _gap_commit(gap_s, gap_e, free, s[:, None], e2[:, None])
        take = mask & enable[:, None]
        return (
            jnp.where(take, fa, free),
            jnp.where(take, gs, gap_s),
            jnp.where(take, ge, gap_e),
        )

    def step(sp: BatchScalars, state, xs):
        tx, tt = xs
        plane_free, res = state
        valid = tx.valid
        is_read = tx.kind == KIND_READ
        tcand = jnp.maximum(tx.arrival, onehot.take(plane_free, tx.plane))
        fc_nearest = fx(sp, "fc_nearest")
        hold = fx(sp, "hold")

        d_est = (xfer_ticks(sp, tx.nbytes, fx(sp, "d_est_hops"))
                 + fx(sp, "d_est_pad"))
        if hold is not False:
            d_est = d_est + jnp.where(
                jnp.logical_and(hold, is_read), tx.op_ticks, 0
            )
        free, gs, ge = res
        sl = slice(L0, L0 + F0)
        avail = _gap_avail(gs[:, sl], ge[:, sl], free[:, sl],
                           tcand[:, None], d_est[:, None])
        avail = jnp.where(sp.fc_valid, avail, _BIG)
        free_now = avail <= tcand[:, None]
        any_free = jnp.any(free_now, axis=1)
        by_dist = jnp.argmin(jnp.where(free_now, tt.dist, _BIG), axis=1)
        by_time = jnp.argmin(avail, axis=1)
        fc_near = jnp.where(any_free, by_dist, by_time).astype(jnp.int32)
        t0_near = jnp.maximum(tcand, onehot.take(avail, fc_near))
        t0 = jnp.where(fc_nearest, t0_near, tcand)

        fcA = jnp.where(fc_nearest, fc_near, tt.fc_fixed[:, 0])
        fcB = jnp.where(fc_nearest, fc_near, tt.fc_fixed[:, 1])
        cand2 = tt.cand2

        def eval_cand(res, cand, fc, enable):
            words = onehot.take(
                tt.mask_words[:, :, cand, :].astype(jnp.int32), fc
            )
            mask = onehot.unpack_bits(words, R)
            dead = jnp.any(mask & sp.res_dead, axis=1)
            enable = enable & ~dead
            hops = onehot.take(tt.hops[:, :, cand], fc)
            cmd = cmd_ticks(sp, hops)
            xfer = xfer_ticks(sp, tx.nbytes, hops)
            ovh = fx(sp, "ovh")
            d0 = ovh + cmd + jnp.where(is_read, 0, xfer)
            s0 = path_sched(res, mask, t0, d0)
            res = commit_mask(res, mask, s0, s0 + d0, enable)
            op_end = s0 + d0 + tx.op_ticks
            d1 = ovh + xfer
            s1 = path_sched(res, mask, op_end, d1)
            res = commit_mask(res, mask, s1, s1 + d1, enable & is_read)
            done = jnp.where(is_read, s1 + d1, op_end)
            wait = (s0 - t0) + jnp.where(is_read, s1 - op_end, 0)
            occ = d0 + jnp.where(is_read, d1, 0)
            return res, done, wait, occ, hops, dead

        resA, doneA, waitA, occA, hopsA, deadA = eval_cand(res, 0, fcA, valid)
        resB, doneB, waitB, occB, hopsB, deadB = eval_cand(res, 1, fcB,
                                                           valid & cand2)
        # mirrors the unbatched static step's dead-candidate selection
        useA = jnp.where(deadA, _BIG, doneA) <= jnp.where(
            cand2 & ~deadB, doneB, _BIG
        )
        failed = deadA & (deadB | ~cand2)
        res = jax.tree_util.tree_map(
            lambda a, b: jnp.where(useA[:, None], a, b), resA, resB
        )
        done = jnp.where(useA, doneA, doneB)
        wait = jnp.where(useA, waitA, waitB)
        occ = jnp.where(useA, occA, occB)
        hops_o = jnp.where(useA, hopsA, hopsB)
        done = jnp.where(failed, tcand + FAIL_TIMEOUT, done)
        wait = jnp.where(failed, FAIL_TIMEOUT, wait)
        occ = jnp.where(failed, 0, occ)
        hops_o = jnp.where(failed, 0, hops_o)
        upd = onehot.onehot(tx.plane, n_planes) & valid[:, None]
        plane_free = jnp.where(upd, done[:, None], plane_free)
        cb = jnp.logical_and(fx(sp, "count_bus"), True)
        zero = jnp.zeros_like(done)
        out = StepOut(
            completion=jnp.where(valid, done, tx.arrival),
            wait=jnp.where(valid, wait, 0),
            conflict=valid & (wait > 0),
            hops=jnp.where(valid, hops_o, 0),
            tries=jnp.where(valid, 1, 0).astype(jnp.int32),
            scout_steps=zero,
            misroutes=zero,
            bus_hold=jnp.where(valid & cb, occ, 0),
            link_hold=jnp.where(valid & jnp.logical_not(cb),
                                hops_o * occ, 0),
            failed=valid & failed,
        )
        return (plane_free, res), out

    return step


def _zero_out_tm(capacity: int, B: int) -> StepOut:
    z = jnp.zeros((capacity, B), jnp.int32)
    return StepOut(
        completion=z, wait=z,
        conflict=jnp.zeros((capacity, B), jnp.bool_),
        hops=z, tries=z, scout_steps=z, misroutes=z, bus_hold=z, link_hold=z,
        failed=jnp.zeros((capacity, B), jnp.bool_),
    )


def _make_batched_run(step, capacity: int, n_planes: int, R: int):
    """Chunked batched scan: trip count = the batch's max chunk count
    (shorter lanes' excess steps are masked — valid=False leaves state and
    outputs exactly as the unbatched skip does)."""

    def batch_run(sp, txns: TxnArrays, tt: BatchTxnTables, n_chunks):
        B = n_chunks.shape[0]
        state = (
            jnp.zeros((B, n_planes), jnp.int32),
            tuple(jnp.zeros((B, R), jnp.int32) for _ in range(3)),
        )

        def chunk_body(c, carry):
            st, buf = carry
            off = c * CHUNK
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, off, CHUNK, 0)
            xs = (jax.tree_util.tree_map(sl, txns),
                  jax.tree_util.tree_map(sl, tt))
            st, outs = jax.lax.scan(lambda s, x: step(sp, s, x), st, xs)
            buf = jax.tree_util.tree_map(
                lambda b, o: jax.lax.dynamic_update_slice_in_dim(b, o, off, 0),
                buf, outs,
            )
            return st, buf

        _, buf = jax.lax.fori_loop(
            0, jnp.max(n_chunks), chunk_body,
            (state, _zero_out_tm(capacity, B)),
        )
        return buf  # StepOut, time-major [capacity, B]

    return batch_run


@functools.lru_cache(maxsize=None)
def _build_batched_fn(sig: tuple, capacity: int, fixed: tuple,
                      n_shards: int, per_shard: int,
                      backend: str = "xla"):
    rows, cols, dies, planes_per_die, _ = sig
    lay = sweep_layout_geom(rows, cols)
    n_planes = rows * cols * dies * planes_per_die
    step = _make_batched_static_step(lay, n_planes, fixed)
    if backend != "xla":
        # lane-tiled Pallas wrapper around the SAME step closure: the
        # kernel body is the step itself, so the pallas path is bit-exact
        # by construction (and pinned so by tests/test_batched_pallas.py)
        from repro.kernels.batched_step import lane_tiled_step

        step = lane_tiled_step(step, interpret=(backend != "pallas"))
    brun = _make_batched_run(step, capacity, n_planes, lay.R_pad)

    if n_shards > 1:
        spec = (P("lanes"), P(None, "lanes"), P(None, "lanes"), P("lanes"))
        fn = shard_map(brun, mesh=_lane_mesh(n_shards), in_specs=spec,
                       out_specs=P(None, "lanes"), check_rep=False)
    else:
        fn = brun
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# gather-free batched SCOUT runner (venice-family lanes)
#
# The batched static runner above left the paper's own designs on the flat
# per-lane scan: the scout DFS (a while_loop whose trip count diverges per
# lane) was the blocker.  The batched formulation here steps [B] scout DFS
# machines in lockstep — the per-step decision is ``kernels.scout_step``'s
# one-hot compare-and-reduce math (the [B,N]x[N,4] port-table matmul,
# lane-aligned busy/tried bitmaps), the backtracking memory is
# ``kernels.ops.route_dfs``'s driver-resident stacks, and each lane routes
# against its OWN link-occupancy map (one [B, L0] busy row per lane — the
# lanes are independent simulations, not one mesh).  Divergence cost is
# max-over-B steps per retry, which amortizes the per-op XLA CPU dispatch
# overhead exactly like the static batch; every decision, rng draw, retry
# schedule and k-scout race stays bit-exact vs the flat scan (pinned in
# tests/test_batched_scout.py against both ``simulate`` and
# ``scalar_ref``).  ``backend`` promotes ``scout_step_pallas`` into the
# DFS inner loop (compiled on GPU/TPU, interpret on CPU) — same math, so
# bit-exact by construction.
# ---------------------------------------------------------------------------


def _avail1_b(res, i, e, d):
    """Batched ``_avail1``: per-lane resource index ``i`` [B] into a
    [B, K] triple, gather-free (one-hot take)."""
    free, gap_s, gap_e = res
    return _gap_avail(onehot.take(gap_s, i), onehot.take(gap_e, i),
                      onehot.take(free, i), e, d)


def _commit1_b(res, i, s, e2, enable):
    """Batched ``_commit1``: one-hot scatter of the per-lane commit."""
    free, gap_s, gap_e = res
    gs, ge, fa = _gap_commit(onehot.take(gap_s, i), onehot.take(gap_e, i),
                             onehot.take(free, i), s, e2)
    upd = onehot.onehot(i, free.shape[1]) & enable[:, None]
    return (
        jnp.where(upd, fa[:, None], free),
        jnp.where(upd, gs[:, None], gap_s),
        jnp.where(upd, ge[:, None], gap_e),
    )


def _sched_gap_b(res, i, e, d, enable):
    s = _avail1_b(res, i, e, d)
    s = jnp.where(enable, s, e)
    res = _commit1_b(res, i, s, s + d, enable)
    return s, res


class ScoutBatchScalars(NamedTuple):
    """Per-lane design scalars of a batched scout group (same layout
    contract as :class:`BatchScalars`) plus the FC node map the scout
    source lookup needs."""

    hold: jnp.ndarray
    allow_nonmin: jnp.ndarray
    n_scouts: jnp.ndarray
    fc_nearest: jnp.ndarray
    count_bus: jnp.ndarray
    ovh: jnp.ndarray
    cmd_base_ns: jnp.ndarray
    xfer_num: jnp.ndarray
    xfer_den: jnp.ndarray
    hop_ns: jnp.ndarray
    d_est_hops: jnp.ndarray
    d_est_pad: jnp.ndarray
    fc_valid: jnp.ndarray  # bool [B, F_pad]
    fc_node: jnp.ndarray  # int32 [B, F_pad]
    res_dead: jnp.ndarray  # bool [B, R_pad]


class ScoutBatchTxnTables(NamedTuple):
    """Per-transaction pre-gathered tables for the scout step, time-major
    (see ``designs.pregather_scout_tables``) — the scout path only ever
    indexes ``dist`` by the transaction's node."""

    dist: jnp.ndarray  # int32 [cap, B, F_pad]


def _make_batched_scout_step(lay, topo, scout_hop_ns: int, n_planes: int,
                             k_max: int, fixed: tuple, backend: str):
    """The scout-routed scan step over a lane batch [B].

    Mirrors ``scout_step`` + ``scout_until_success`` in ``_make_step``
    operation for operation with a leading lane axis (consult those for
    the modeling semantics); all arithmetic is int32 one-hot/masked-select
    work, so batched == unbatched bit-for-bit.  The flat ``scout_route``
    DFS is replaced by ``kernels.ops.route_dfs`` around the batched
    ``step_math`` decision (XLA) or ``scout_step_pallas`` (the promoted
    kernel) — the same Algorithm-1 decision procedure, pinned equivalent.
    """
    L0 = lay.L_pad
    n_fcs = lay.rows
    n_nodes = lay.n_nodes
    fixed = dict(zip(_PROMOTABLE, fixed))
    tables_dev = jnp.asarray(pack_tables(topo))
    n_pad = tables_dev.shape[0]
    pl_, pn_ = tables_dev[:n_nodes, 0:4], tables_dev[:n_nodes, 4:8]
    port_link_dev = jnp.asarray(topo.port_link, jnp.int32)
    cols = topo.cols

    def fx(sp, name):
        v = fixed[name]
        return getattr(sp, name) if v is None else v

    def cmd_ticks(sp, hops):
        ns = fx(sp, "cmd_base_ns") + hops * fx(sp, "hop_ns")
        return jnp.maximum(_ceil_div(ns, TICK_NS), 1).astype(jnp.int32)

    def xfer_ticks(sp, nbytes, hops):
        ns = _ceil_div(nbytes * fx(sp, "xfer_num"), fx(sp, "xfer_den"))
        ns = ns + hops * fx(sp, "hop_ns")
        return _ceil_div(ns, TICK_NS).astype(jnp.int32)

    def commit_mask_b(res, mask, s, e2, enable):
        free, gap_s, gap_e = res
        gs, ge, fa = _gap_commit(gap_s, gap_e, free, s[:, None], e2[:, None])
        take = mask & enable[:, None]
        return (
            jnp.where(take, fa, free),
            jnp.where(take, gs, gap_s),
            jnp.where(take, ge, gap_e),
        )

    def _merge_b(take, a, b):
        return jax.tree_util.tree_map(
            lambda x, y: jnp.where(
                take.reshape(take.shape + (1,) * (x.ndim - 1)), x, y),
            a, b,
        )

    def make_step_fn(sp, B):
        """The per-DFS-iteration decision step for this batch, honoring a
        promoted-static or per-lane-traced ``allow_nonmin``."""
        allow = fx(sp, "allow_nonmin")
        if backend == "xla":
            b_tile = B

            def step_fn(state, busy, tried):
                return step_math(state, busy, tried, pl_, pn_, cols, allow)

            return step_fn, b_tile
        b_tile = 256 if B % 256 == 0 else -(-B // 8) * 8
        interpret = backend != "pallas"
        if isinstance(allow, (bool, np.bool_)):
            def step_fn(state, busy, tried):
                return scout_step_pallas(
                    state, busy, tried, tables_dev,
                    cols=cols, n_nodes=n_nodes,
                    allow_nonminimal=bool(allow),
                    interpret=interpret, b_tile=b_tile,
                )
        else:
            Bp = B + ((-B) % b_tile)
            allow_p = jnp.zeros((Bp,), jnp.int32).at[:B].set(
                jnp.asarray(allow).astype(jnp.int32))

            def step_fn(state, busy, tried):
                return scout_step_pallas(
                    state, busy, tried, tables_dev, allow_p,
                    cols=cols, n_nodes=n_nodes,
                    interpret=interpret, b_tile=b_tile,
                )
        return step_fn, b_tile

    def scout_until_success_b(links3, sp, src, dst, t0, rng, d_hold, valid):
        """Batched ``scout_until_success``: every lane follows its own
        retry schedule (its links triple is lane-local), frozen lanes'
        (res, t, rng, tries) ride through the joint while_loop untouched —
        per-lane bit-identity with the flat loop."""
        n_scouts = fx(sp, "n_scouts")
        dead_links = sp.res_dead[:, :L0]
        B = src.shape[0]
        step_fn, b_tile = make_step_fn(sp, B)

        def route(busy, rngs, act):
            # non-participating lanes route a src==dst==0 dummy scout
            # (finishes in one step); their results are never merged
            src_e = jnp.where(act, src, 0)
            dst_e = jnp.where(act, dst, 0)
            return route_dfs(step_fn, port_link_dev, src_e, dst_e, busy,
                             rngs, n_pad=n_pad, b_tile=b_tile)

        def try_once(t, rng, act):
            busy = _busy_at(links3, t[:, None], d_hold[:, None]) | dead_links
            best = None
            for k in range(k_max):
                rng_adv = (
                    rng * jnp.uint32(747796405) + jnp.uint32(2891336453)
                ) | jnp.uint32(1)
                active = jnp.asarray(k < n_scouts)  # bool or traced [B]
                rng = jnp.where(jnp.logical_and(act, active), rng_adv, rng)
                res = route(busy, rng, act)
                res = res._replace(path_mask=res.path_mask[:, :L0])
                if best is None:
                    best = res
                else:
                    take = res.success & active & (
                        (~best.success) | (res.hops < best.hops)
                    )
                    best = _merge_b(take, res, best)
            return best, rng

        res0, rng = try_once(t0, rng, valid)

        def cond(carry):
            res, t, rng, tries = carry
            return jnp.any(valid & (~res.success) & (tries < _MAX_TRIES))

        def body(carry):
            res, t, rng, tries = carry
            live = valid & (~res.success) & (tries < _MAX_TRIES)
            free, gap_s, _ = links3
            ev = jnp.minimum(
                jnp.min(jnp.where(free > t[:, None], free, _BIG), axis=1),
                jnp.min(jnp.where(gap_s > t[:, None], gap_s, _BIG), axis=1),
            )
            t_next = jnp.maximum(ev, t + 1)
            t_next = jnp.where(tries + 1 >= _MAX_TRIES,
                               jnp.max(free, axis=1), t_next)
            t_next = jnp.where(live, t_next, t)
            res2, rng2 = try_once(t_next, rng, live)
            res = _merge_b(live, res2, res)
            rng = jnp.where(live, rng2, rng)
            return res, t_next, rng, tries + live.astype(jnp.int32)

        res, t, rng, tries = jax.lax.while_loop(
            cond, body, (res0, t0, rng, jnp.ones((B,), jnp.int32))
        )
        return res, t, rng, tries

    def step(sp: ScoutBatchScalars, state, xs):
        tx, tt = xs
        plane_free, links, fcs, chips, rng = state
        valid = tx.valid
        is_read = tx.kind == KIND_READ
        tcand = jnp.maximum(tx.arrival, onehot.take(plane_free, tx.plane))
        hold = fx(sp, "hold")

        d_est = (xfer_ticks(sp, tx.nbytes, fx(sp, "d_est_hops"))
                 + fx(sp, "d_est_pad"))
        if hold is not False:
            d_est = d_est + jnp.where(
                jnp.logical_and(hold, is_read), tx.op_ticks, 0
            )
        avail = _avail_all(fcs, tcand[:, None], d_est[:, None])
        avail = jnp.where(sp.fc_valid[:, :n_fcs], avail, _BIG)
        dist_row = tt.dist[:, :n_fcs]
        free_now = avail <= tcand[:, None]
        any_free = jnp.any(free_now, axis=1)
        by_dist = jnp.argmin(jnp.where(free_now, dist_row, _BIG), axis=1)
        by_time = jnp.argmin(avail, axis=1)
        fc = jnp.where(any_free, by_dist, by_time).astype(jnp.int32)
        t0 = jnp.maximum(tcand, onehot.take(avail, fc))
        src = onehot.take(sp.fc_node[:, :n_fcs], fc)
        min_hops = onehot.take(dist_row, fc)
        cmd_pkt = cmd_ticks(sp, min_hops)
        en_cmd = valid & is_read & jnp.logical_not(hold)
        s_cmd, fcs = _sched_gap_b(fcs, fc, t0, cmd_pkt, en_cmd)
        ready_r = s_cmd + cmd_pkt + tx.op_ticks
        t_nonread = jnp.maximum(t0, _avail1_b(chips, tx.node, t0, d_est))
        t_read = jnp.maximum(
            jnp.maximum(ready_r, _avail1_b(fcs, fc, ready_r, d_est)),
            _avail1_b(chips, tx.node, ready_r, d_est),
        )
        t_xfer_req = jnp.where(is_read, t_read, t_nonread)
        t_scout = jnp.where(hold, t0, t_xfer_req)
        sres, t_resv, rng, tries = scout_until_success_b(
            links, sp, src, tx.node, t_scout, rng, d_est, valid
        )
        hops_o = sres.hops
        rtt = _ceil_div((sres.steps + hops_o) * scout_hop_ns, TICK_NS)
        start = t_resv + rtt.astype(jnp.int32)
        cmd_v = cmd_ticks(sp, hops_o)
        xfer_v = xfer_ticks(sp, tx.nbytes, hops_o)
        dur_p = jnp.where(is_read, xfer_v, cmd_v + xfer_v)
        end_p = start + dur_p
        done_p = jnp.where(is_read, end_p, end_p + tx.op_ticks)
        wait_p = (s_cmd - t0) + (start - t_xfer_req)
        done_r_h = start + cmd_v + tx.op_ticks + xfer_v
        data_end_w = start + cmd_v + xfer_v
        circuit_end = jnp.where(is_read, done_r_h, data_end_w)
        done_h = jnp.where(is_read, done_r_h, data_end_w + tx.op_ticks)
        commit_end = jnp.where(hold, circuit_end, end_p)
        done = jnp.where(hold, done_h, done_p)
        wait = jnp.where(hold, start - t0, wait_p)
        fail = ~sres.success
        ok = valid & sres.success
        done = jnp.where(fail, tcand + FAIL_TIMEOUT, done)
        wait = jnp.where(fail, FAIL_TIMEOUT, wait)
        links = commit_mask_b(links, sres.path_mask, t_resv, commit_end, ok)
        fcs = _commit1_b(fcs, fc, t_resv, commit_end, ok)
        chips = _commit1_b(chips, tx.node, t_resv, commit_end, ok)
        upd = onehot.onehot(tx.plane, n_planes) & valid[:, None]
        plane_free = jnp.where(upd, done[:, None], plane_free)
        zero = jnp.zeros_like(done)
        out = StepOut(
            completion=jnp.where(valid, done, tx.arrival),
            wait=jnp.where(valid, wait, 0),
            conflict=valid & ((tries > 1) | fail),
            hops=jnp.where(valid, hops_o, 0),
            tries=jnp.where(valid, tries, 0),
            scout_steps=jnp.where(valid, sres.steps, 0),
            misroutes=jnp.where(valid, sres.misroutes, 0),
            bus_hold=zero,
            link_hold=jnp.where(valid & jnp.logical_not(fail),
                                hops_o * (commit_end - t_resv), 0),
            failed=valid & fail,
        )
        return (plane_free, links, fcs, chips, rng), out

    return step


def _make_batched_scout_run(step, capacity: int, n_planes: int, L0: int,
                            n_fcs: int, n_nodes: int):
    """Chunked batched scout scan — the scout-state analogue of
    :func:`_make_batched_run` (seeds ride as an argument; the scan state
    mirrors the flat scout ``init_state`` with a leading lane axis)."""

    def batch_run(scal, seeds, txns: TxnArrays, tt: ScoutBatchTxnTables,
                  n_chunks):
        B = n_chunks.shape[0]
        trip = lambda n: tuple(
            jnp.zeros((B, n), jnp.int32) for _ in range(3))
        state = (
            jnp.zeros((B, n_planes), jnp.int32),
            trip(L0),
            trip(n_fcs),
            trip(n_nodes),
            jnp.asarray(seeds, jnp.uint32),
        )

        def chunk_body(c, carry):
            st, buf = carry
            off = c * CHUNK
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, off, CHUNK, 0)
            xs = (jax.tree_util.tree_map(sl, txns),
                  jax.tree_util.tree_map(sl, tt))
            st, outs = jax.lax.scan(lambda s, x: step(scal, s, x), st, xs)
            buf = jax.tree_util.tree_map(
                lambda b, o: jax.lax.dynamic_update_slice_in_dim(b, o, off, 0),
                buf, outs,
            )
            return st, buf

        _, buf = jax.lax.fori_loop(
            0, jnp.max(n_chunks), chunk_body,
            (state, _zero_out_tm(capacity, B)),
        )
        return buf  # StepOut, time-major [capacity, B]

    return batch_run


@functools.lru_cache(maxsize=None)
def _build_batched_scout_fn(sig: tuple, capacity: int, k_max: int,
                            fixed: tuple, n_shards: int, per_shard: int,
                            backend: str = "xla"):
    rows, cols, dies, planes_per_die, scout_hop_ns = sig
    lay = sweep_layout_geom(rows, cols)
    topo = build_mesh(rows, cols)
    n_planes = rows * cols * dies * planes_per_die
    step = _make_batched_scout_step(lay, topo, scout_hop_ns, n_planes,
                                    k_max, fixed, backend)
    brun = _make_batched_scout_run(step, capacity, n_planes, lay.L_pad,
                                   lay.rows, lay.n_nodes)

    if n_shards > 1:
        spec = (P("lanes"), P("lanes"), P(None, "lanes"), P(None, "lanes"),
                P("lanes"))
        fn = shard_map(brun, mesh=_lane_mesh(n_shards), in_specs=spec,
                       out_specs=P(None, "lanes"), check_rep=False)
    else:
        fn = brun
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# executable store: logical keys, shape avatars, compile-or-load
#
# Every program variant has a *logical key* — everything its machine code
# depends on besides the source (geometry sig, capacity bucket, lane
# layout, cost class, promotions, shard count).  Keys index three tiers:
# the in-process ``_EXEC_CACHE``, the on-disk AOT store
# (``repro.ssd.exec_cache`` — loading skips tracing+lowering+compile), and
# a fresh compile.  Compilation happens from ShapeDtypeStruct avatars, so
# the sweep planner can compile executables on a background thread before
# the group's data is even stacked (the overlapped compile/execute
# pipeline in ``sweep_plan``).
# ---------------------------------------------------------------------------

_EXEC_CACHE: dict = {}
_TALLY_LOCK = threading.Lock()


def clear_exec_cache() -> None:
    """Drop in-process compiled executables (tests)."""
    _EXEC_CACHE.clear()


def lane_group_key(sig, capacity, G, k_max, has_scout, fixed, n_shards):
    return ("lane", sig, capacity, G, k_max, has_scout, fixed, n_shards)


def lanec_group_key(sig, capacity, G, k_max, has_scout, fixed, n_shards):
    """State-carrying lane group (the streaming engine's windows)."""
    return ("lanec", sig, capacity, G, k_max, has_scout, fixed, n_shards)


def stack_group_key(sig, capacity, K, k_max, has_scout, fixed, n_shards):
    return ("stack", sig, capacity, K, k_max, has_scout, fixed, n_shards)


def batched_group_key(sig, capacity, per_shard, fixed, n_shards,
                      backend: str = "xla"):
    # the default XLA backend keeps the historical 6-tuple so warm-path
    # store entries stay stable; pallas variants are distinct programs
    # and carry the backend as a 7th element
    if backend == "xla":
        return ("batched", sig, capacity, per_shard, fixed, n_shards)
    return ("batched", sig, capacity, per_shard, fixed, n_shards, backend)


def bscout_group_key(sig, capacity, per_shard, k_max, fixed, n_shards,
                     backend: str = "xla"):
    """Batched scout group.  Same convention as ``batched_group_key``:
    the default XLA backend key is the plain tuple (byte-stable in the
    AOT store), pallas variants append the backend."""
    if backend == "xla":
        return ("bscout", sig, capacity, per_shard, k_max, fixed, n_shards)
    return ("bscout", sig, capacity, per_shard, k_max, fixed, n_shards,
            backend)


def kernel_backend_of_key(key: tuple) -> str:
    """Which lane-step kernel a group key dispatches to: "xla" for all
    unbatched variants and the default batched programs, else the pallas
    flavor recorded in the key ("pallas-compiled" / "pallas-interpret")."""
    if key[0] == "batched" and len(key) > 6:
        return "pallas-compiled" if key[6] == "pallas" else key[6]
    if key[0] == "bscout" and len(key) > 7:
        return "pallas-compiled" if key[7] == "pallas" else key[7]
    return "xla"


_TABLE_SCALAR_DTYPES = dict(
    is_scout=bool, fc_nearest=bool, ovh=np.int32, cmd_base_ns=np.int32,
    xfer_num=np.int32, xfer_den=np.int32, hop_ns=np.int32,
    allow_nonmin=bool, hold=bool, n_scouts=np.int32, d_est_hops=np.int32,
    d_est_pad=np.int32, count_bus=bool,
)


def _sds(shape, dtype, spec, n_shards):
    if n_shards <= 1:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(_lane_mesh(n_shards), spec)
    )


def _tables_avatar(lay, G: int, n_shards: int) -> LaneTables:
    L = P("lanes")
    F0, N, R = lay.F_pad, lay.n_nodes, lay.R_pad
    f = {name: _sds((G,), dt, L, n_shards)
         for name, dt in _TABLE_SCALAR_DTYPES.items()}
    f.update(
        cmask=_sds((G, F0, N, 2, R), bool, L, n_shards),
        hops=_sds((G, F0, N, 2), np.int32, L, n_shards),
        cand2_ok=_sds((G, N), bool, L, n_shards),
        fc_fixed=_sds((G, N, 2), np.int32, L, n_shards),
        dist=_sds((G, F0, N), np.int32, L, n_shards),
        fc_valid=_sds((G, F0), bool, L, n_shards),
        fc_node=_sds((G, F0), np.int32, L, n_shards),
        res_dead=_sds((G, R), bool, L, n_shards),
    )
    return LaneTables(**f)


def _txns_avatar(G: int, capacity: int, n_shards: int,
                 time_major: bool = False) -> TxnArrays:
    shape = (capacity, G) if time_major else (G, capacity)
    spec = P(None, "lanes") if time_major else P("lanes")
    mk = lambda dt: _sds(shape, dt, spec, n_shards)
    return TxnArrays(
        arrival=mk(np.int32), kind=mk(np.int32), plane=mk(np.int32),
        node=mk(np.int32), row=mk(np.int32), nbytes=mk(np.int32),
        op_ticks=mk(np.int32), valid=mk(bool),
    )


def _state_avatar(sig, G: int, has_scout: bool, n_shards: int):
    """Shape avatar of the carried scan state (mirrors ``init_state`` in
    ``_make_step``, with a leading lane axis)."""
    rows, cols, dies, planes_per_die, _ = sig
    n_planes = rows * cols * dies * planes_per_die
    lay = sweep_layout_geom(rows, cols)
    L = P("lanes")
    mk = lambda n: _sds((G, n), np.int32, L, n_shards)
    trip = lambda n: (mk(n), mk(n), mk(n))
    if not has_scout:
        return (mk(n_planes), trip(lay.R_pad))
    return (
        mk(n_planes),
        trip(lay.L_pad),
        trip(lay.rows),
        trip(lay.n_nodes),
        _sds((G,), np.uint32, L, n_shards),
    )


def _avatars_for_key(key: tuple):
    kind = key[0]
    if kind in ("lane", "stack", "lanec"):
        _, sig, capacity, n, k_max, has_scout, fixed, n_shards = key
        G = n * n_shards if kind == "stack" else n
        lay = sweep_layout_geom(sig[0], sig[1])
        second = (
            _state_avatar(sig, G, has_scout, n_shards)
            if kind == "lanec"
            else _sds((G,), np.uint32, P("lanes"), n_shards)
        )
        return (
            _tables_avatar(lay, G, n_shards),
            second,
            _txns_avatar(G, capacity, n_shards),
            _sds((G,), np.int32, P("lanes"), n_shards),
        )
    if kind == "bscout":
        _, sig, capacity, per_shard, k_max, fixed, n_shards = key[:7]
        B = per_shard * n_shards
        lay = sweep_layout_geom(sig[0], sig[1])
        F0, R = lay.F_pad, lay.R_pad
        L, T = P("lanes"), P(None, "lanes")
        scal = ScoutBatchScalars(
            *(_sds((B,), _TABLE_SCALAR_DTYPES[name], L, n_shards)
              for name in _PROMOTABLE),
            fc_valid=_sds((B, F0), bool, L, n_shards),
            fc_node=_sds((B, F0), np.int32, L, n_shards),
            res_dead=_sds((B, R), bool, L, n_shards),
        )
        return (
            scal,
            _sds((B,), np.uint32, L, n_shards),
            _txns_avatar(B, capacity, n_shards, time_major=True),
            ScoutBatchTxnTables(
                dist=_sds((capacity, B, F0), np.int32, T, n_shards)),
            _sds((B,), np.int32, L, n_shards),
        )
    _, sig, capacity, per_shard, fixed, n_shards = key[:6]
    B = per_shard * n_shards
    lay = sweep_layout_geom(sig[0], sig[1])
    F0, R = lay.F_pad, lay.R_pad
    W = -(-R // 8)
    L, T = P("lanes"), P(None, "lanes")
    scal = BatchScalars(
        *(_sds((B,), _TABLE_SCALAR_DTYPES[name], L, n_shards)
          for name in _PROMOTABLE),
        fc_valid=_sds((B, F0), bool, L, n_shards),
        res_dead=_sds((B, R), bool, L, n_shards),
    )
    bt = BatchTxnTables(
        mask_words=_sds((capacity, B, F0, 2, W), np.uint8, T, n_shards),
        hops=_sds((capacity, B, F0, 2), np.int32, T, n_shards),
        dist=_sds((capacity, B, F0), np.int32, T, n_shards),
        cand2=_sds((capacity, B), bool, T, n_shards),
        fc_fixed=_sds((capacity, B, 2), np.int32, T, n_shards),
    )
    return (
        scal,
        _txns_avatar(B, capacity, n_shards, time_major=True),
        bt,
        _sds((B,), np.int32, L, n_shards),
    )


def _fn_for_key(key: tuple):
    kind = key[0]
    if kind == "lane":
        _, sig, capacity, G, k_max, has_scout, fixed, n_shards = key
        return _build_group_fn(sig, capacity, k_max, has_scout, fixed,
                               n_shards)
    if kind == "lanec":
        _, sig, capacity, G, k_max, has_scout, fixed, n_shards = key
        return _build_group_fn_carry(sig, capacity, k_max, has_scout, fixed,
                                     n_shards)
    if kind == "stack":
        _, sig, capacity, K, k_max, has_scout, fixed, n_shards = key
        return _build_stack_fn(sig, capacity, K, k_max, has_scout, fixed,
                               n_shards)
    if kind == "bscout":
        _, sig, capacity, per_shard, k_max, fixed, n_shards = key[:7]
        backend = key[7] if len(key) > 7 else "xla"
        return _build_batched_scout_fn(sig, capacity, k_max, fixed,
                                       n_shards, per_shard, backend)
    _, sig, capacity, per_shard, fixed, n_shards = key[:6]
    backend = key[6] if len(key) > 6 else "xla"
    return _build_batched_fn(sig, capacity, fixed, n_shards, per_shard,
                             backend)


def lower_for_key(key: tuple):
    """Trace + lower the program for ``key`` (no backend compile).

    Tracing/lowering is Python-heavy (GIL-bound), so the overlapped
    pipeline runs it on the MAIN thread during planning; the XLA backend
    compile (``.compile()``, releases the GIL) is what goes to the worker
    threads.  Returns None when a lowering isn't needed (already in the
    in-process cache, or the persistent store has the executable)."""
    if key in _EXEC_CACHE:
        return None
    return _fn_for_key(key).lower(*_avatars_for_key(key))


def ensure_compiled(key: tuple, lowered=None):
    """Resolve ``key`` to a loaded executable: in-process cache, then the
    persistent AOT store, then compile (persisting the result).

    Returns ``(compiled, seconds, source)`` with source in
    ``{"mem", "disk", "build"}`` — ``seconds`` is the load or compile
    wall-clock (0 for "mem").  Thread-safe for distinct keys (the
    overlapped pipeline compiles on worker threads); ``lowered`` is the
    optional pre-traced module from :func:`lower_for_key`.
    """
    hit = _EXEC_CACHE.get(key)
    if hit is not None:
        return hit, 0.0, "mem"
    from repro.ssd import bench, exec_cache

    t0 = time.perf_counter()
    compiled = exec_cache.lookup(key)
    if compiled is not None:
        _EXEC_CACHE[key] = compiled
        dt = time.perf_counter() - t0
        # tallied here, not from dispatched groups: background
        # compiles/loads kicked off by ``sweep_plan.precompile`` count
        # even when they finish before any group adopts them (the lock:
        # compile-pool workers tally concurrently)
        with _TALLY_LOCK:
            bench.PERF["xc_load_s"] += dt
        return compiled, dt, "disk"
    if lowered is None:
        lowered = _fn_for_key(key).lower(*_avatars_for_key(key))
    t0 = time.perf_counter()
    # tier separation: planner programs are tier-1-managed, so they
    # compile with JAX's native persistent cache (tier 2) DISABLED — an
    # executable deserialized from tier 2 serializes with stale symbol
    # names and the stored tier-1 entry fails to reload ("Symbols not
    # found"); bypassing tier 2 here also avoids writing every big
    # program to disk twice.  Tier 2 keeps serving everything that
    # doesn't go through this function.  The bypass is perf-only, so a
    # jax that moved the (private) config state just compiles without it.
    try:
        from jax._src.config import enable_compilation_cache as _no_t2
        ctx = _no_t2(False)
    except ImportError:
        import contextlib

        ctx = contextlib.nullcontext()
    with ctx:
        compiled = lowered.compile()
    dt = time.perf_counter() - t0
    with _TALLY_LOCK:
        bench.PERF["compile_s"] += dt
    tr = obs_spans.TRACER
    if tr is not None:
        tr.complete("compile", f"compile:{key[0]}", tr.now_us() - dt * 1e6,
                    dt * 1e6, {"source": "build"})
    exec_cache.store(key, compiled)
    _EXEC_CACHE[key] = compiled
    return compiled, dt, "build"


def _put_args(args, specs, n_shards: int):
    if n_shards <= 1:
        return jax.tree_util.tree_map(jnp.asarray, args)
    mesh = _lane_mesh(n_shards)
    return tuple(
        jax.tree_util.tree_map(
            lambda a: jax.device_put(a, NamedSharding(mesh, spec)), arg
        )
        for arg, spec in zip(args, specs)
    )


def _run_compiled(key: tuple, args: tuple, specs: tuple, *, lanes: int,
                  capacity: int, n_shards: int, has_scout: bool,
                  steps: int) -> tuple:
    """Shared execute-and-report body of the three group runners:
    resolve the executable, place the arguments, dispatch, and record the
    per-group attribution (variant/cache source/compile-load-exec split;
    ``steps`` is the executed-step count incl. padding waste)."""
    compiled, dt, src = ensure_compiled(key)
    args = _put_args(args, specs, n_shards)
    t0 = time.perf_counter()
    with obs_spans.span("exec", f"exec:{key[0]}", lanes=lanes,
                        shards=n_shards, steps=steps * CHUNK):
        outs = jax.device_get(compiled(*args))
    exec_s = time.perf_counter() - t0
    kb = kernel_backend_of_key(key)
    perf = {
        "variant": key[0], "lanes": lanes, "capacity": capacity,
        "shards": n_shards, "scout": has_scout,
        "steps": steps * CHUNK, "cache": src,
        "kernel_backend": kb,
        "compile_s": round(dt if src == "build" else 0.0, 3),
        "load_s": round(dt if src == "disk" else 0.0, 3),
        "exec_s": round(exec_s, 3),
    }
    from repro.ssd import bench

    # kernel-dispatch scoreboard: which backend ran, and how many
    # lane-steps went through the batched step vs the unbatched scan —
    # split per cost class so the scout promotion is attributable
    # (the lock: the streaming engine executes groups off-thread)
    with _TALLY_LOCK:
        bench.PERF["kernel_backends"][kb] = (
            bench.PERF["kernel_backends"].get(kb, 0) + 1)
        if has_scout:
            share_key = ("steps_scout_batched" if key[0] == "bscout"
                         else "steps_scout_unbatched")
        else:
            share_key = ("steps_batched" if key[0] == "batched"
                         else "steps_unbatched")
        bench.PERF[share_key] += steps * CHUNK
    return outs, perf


def run_group(sig: tuple, tables, seeds, txns: TxnArrays, n_chunks,
              k_max: int, has_scout: bool, fixed: tuple,
              n_shards: int, K: int = 0) -> tuple:
    """Execute one lane group; returns (StepOut [G, cap], perf).

    ``tables``/``txns`` carry a leading lane axis [G] (numpy trees);
    ``seeds``/``n_chunks`` are [G] arrays.  ``K == 0``: one unbatched
    lane per shard (G == n_shards); ``K > 0``: the stacked layout, K
    sequential lanes per shard (G == n_shards*K).
    """
    G = int(len(seeds))
    capacity = int(np.asarray(txns.arrival).shape[1])
    ncs = np.asarray(n_chunks, np.int32)
    if K:
        key = stack_group_key(sig, capacity, K, k_max, has_scout, fixed,
                              n_shards)
    else:
        key = lane_group_key(sig, capacity, G, k_max, has_scout, fixed,
                             n_shards)
    return _run_compiled(
        key, (tables, np.asarray(seeds, np.uint32), txns, ncs),
        (P("lanes"),) * 4, lanes=G, capacity=capacity, n_shards=n_shards,
        has_scout=has_scout, steps=int(ncs.sum()),
    )


def initial_lane_state(cfg: SSDConfig, has_scout: bool, seed: int):
    """Host (numpy) zero scan state for one lane — what ``init_state``
    inside ``_make_step`` builds device-side.  The streaming engine seeds
    window 0 with this, so window 0 of a streamed replay is bit-identical
    to the same prefix under :func:`simulate`."""
    sig = _geom_sig(cfg)
    lay = sweep_layout_geom(sig[0], sig[1])
    z = lambda n: np.zeros((n,), np.int32)
    trip = lambda n: (z(n), z(n), z(n))
    if not has_scout:
        return (z(cfg.n_planes), trip(lay.R_pad))
    return (
        z(cfg.n_planes),
        trip(lay.L_pad),
        trip(lay.rows),
        trip(lay.n_nodes),
        np.uint32(seed),
    )


# floor for rebased timestamps: streamed windows re-inject deferred
# transactions with their original (now negative) frame-shifted arrivals,
# so the rebase must NOT clamp at 0 — but idle windows would otherwise
# drift state toward int32 underflow.  Anything at or below the floor is
# "deep past": the bookkeeping only ever compares such values against
# candidate starts >= arrivals >= the same floor (the streaming engine
# guards deferred arrivals against it), and for those comparisons every
# deep-past value behaves identically.
REBASE_FLOOR = -(1 << 30)


def rebase_lane_state(state, delta_ticks: int):
    """Shift every timestamp in a carried scan state back by ``delta_ticks``
    (the window span) — a pure frame change, floored at ``REBASE_FLOOR``.

    The unclamped shift is what makes window-boundary carry bit-exact: the
    scan's resource bookkeeping (``_gap_avail`` / ``_busy_at`` / commits)
    is purely relative, so state that reads exactly ``monolithic value
    minus the accumulated window spans`` — including negative entries for
    resources still busy from a previous window — reproduces the
    monolithic run's comparisons verbatim, even for deferred transactions
    whose rebased arrivals are themselves negative.  The scout RNG word is
    not a timestamp and rides through untouched."""

    def f(a):
        a = np.asarray(a)
        if a.dtype != np.int32:
            return a  # uint32 rng state
        return np.maximum(a.astype(np.int64) - int(delta_ticks),
                          REBASE_FLOOR).astype(np.int32)

    return jax.tree_util.tree_map(f, state)


def run_group_carry(sig: tuple, tables, state, txns: TxnArrays, n_chunks,
                    k_max: int, has_scout: bool, fixed: tuple,
                    n_shards: int) -> tuple:
    """Execute one state-carrying lane group (streaming window); returns
    ``(state' [G, ...], StepOut [G, cap], perf)``.

    Same layout contract as :func:`run_group`, except the per-lane scan
    state replaces the seeds argument (the scout RNG seed lives inside the
    state) and comes back rebased-ready for the next window."""
    ncs = np.asarray(n_chunks, np.int32)
    G = int(ncs.shape[0])
    capacity = int(np.asarray(txns.arrival).shape[1])
    key = lanec_group_key(sig, capacity, G, k_max, has_scout, fixed,
                          n_shards)
    outs, perf = _run_compiled(
        key, (tables, state, txns, ncs), (P("lanes"),) * 4,
        lanes=G, capacity=capacity, n_shards=n_shards,
        has_scout=has_scout, steps=int(ncs.sum()),
    )
    st, buf = outs
    return st, buf, perf


def run_batched_group(sig: tuple, scal: BatchScalars, txns: TxnArrays,
                      bt: BatchTxnTables, n_chunks, fixed: tuple,
                      n_shards: int, per_shard: int,
                      backend: str = "xla") -> tuple:
    """Execute one batched static group; returns (StepOut [cap, B], perf).

    ``txns``/``bt`` are time-major numpy trees [cap, B, ...]; ``scal`` and
    ``n_chunks`` carry the [B] lane axis.  Executed steps are charged at
    the per-shard max chunk count (the masked tail of shorter lanes is the
    batch's padding waste, kept visible in ``steps``).  ``backend`` picks
    the lane-step kernel (a resolved name from
    :func:`resolve_lane_backend`); every backend is bit-exact.
    """
    B = int(np.asarray(n_chunks).shape[0])
    capacity = int(np.asarray(txns.arrival).shape[0])
    ncs = np.asarray(n_chunks, np.int32)
    shard_steps = sum(
        int(ncs[s * per_shard:(s + 1) * per_shard].max(initial=0))
        * per_shard for s in range(max(1, n_shards))
    )
    return _run_compiled(
        batched_group_key(sig, capacity, per_shard, fixed, n_shards,
                          backend),
        (scal, txns, bt, ncs),
        (P("lanes"), P(None, "lanes"), P(None, "lanes"), P("lanes")),
        lanes=B, capacity=capacity, n_shards=n_shards, has_scout=False,
        steps=shard_steps,
    )


def run_batched_scout_group(sig: tuple, scal: ScoutBatchScalars, seeds,
                            txns: TxnArrays, tt: ScoutBatchTxnTables,
                            n_chunks, k_max: int, fixed: tuple,
                            n_shards: int, per_shard: int,
                            backend: str = "xla") -> tuple:
    """Execute one batched scout group; returns (StepOut [cap, B], perf).

    Same layout contract as :func:`run_batched_group` plus the per-lane
    rng ``seeds`` [B] (the scout state's fifth leg) and ``k_max`` (the
    group's raced-scout ceiling — lanes below it are masked per their
    ``n_scouts``).  Every backend is bit-exact.
    """
    B = int(np.asarray(n_chunks).shape[0])
    capacity = int(np.asarray(txns.arrival).shape[0])
    ncs = np.asarray(n_chunks, np.int32)
    shard_steps = sum(
        int(ncs[s * per_shard:(s + 1) * per_shard].max(initial=0))
        * per_shard for s in range(max(1, n_shards))
    )
    return _run_compiled(
        bscout_group_key(sig, capacity, per_shard, k_max, fixed, n_shards,
                         backend),
        (scal, np.asarray(seeds, np.uint32), txns, tt, ncs),
        (P("lanes"), P("lanes"), P(None, "lanes"), P(None, "lanes"),
         P("lanes")),
        lanes=B, capacity=capacity, n_shards=n_shards, has_scout=True,
        steps=shard_steps,
    )


class SimResult(NamedTuple):
    design: str
    completion: np.ndarray  # ticks, per txn (valid only)
    latency: np.ndarray  # ticks, per txn
    req_latency: np.ndarray  # ticks, per host request (GC excluded)
    wait: np.ndarray
    conflict: np.ndarray
    hops: np.ndarray
    tries: np.ndarray
    misroutes: np.ndarray
    exec_ticks: int
    bus_hold_ticks: int
    link_hold_ticks: int
    flash_energy_j: float
    transfer_energy_j: float
    static_energy_j: float
    # --- host-request surface (aligned with req_latency, request order) ---
    req_completion: np.ndarray | None = None  # ticks, max over request's txns
    req_tenant: np.ndarray | None = None  # tenant id per request, or None
    # --- fault surface (ISSUE 8; None on results predating the model) ---
    failed: np.ndarray | None = None  # bool per txn — permanent path failure
    req_failed: np.ndarray | None = None  # bool per request (any txn failed)

    @property
    def exec_s(self) -> float:
        return self.exec_ticks * TICK_NS * 1e-9

    @property
    def energy_j(self) -> float:
        return self.flash_energy_j + self.transfer_energy_j + self.static_energy_j

    @property
    def avg_power_w(self) -> float:
        return self.energy_j / max(self.exec_s, 1e-12)

    def iops(self, n_requests: int | None = None) -> float:
        n = len(self.req_latency) if n_requests is None else n_requests
        return n / max(self.exec_s, 1e-12)

    def latency_percentiles_us(self, qs=(50, 95, 99)) -> dict:
        """Host-request latency percentiles, us (GC excluded)."""
        if len(self.req_latency) == 0:
            return {f"p{q:g}": 0.0 for q in qs}
        v = np.percentile(self.req_latency, qs) * (TICK_NS * 1e-3)
        return {f"p{q:g}": float(x) for q, x in zip(qs, v)}

    def p99_latency_us(self) -> float:
        return float(np.percentile(self.req_latency, 99)) * TICK_NS * 1e-3

    def latency_cdf_us(self):
        lat = np.sort(self.req_latency) * (TICK_NS * 1e-3)
        return lat, np.arange(1, len(lat) + 1) / len(lat)

    def tenant_latencies(self) -> dict:
        """Per-tenant host-request latency arrays (ticks).  The concatenation
        over tenants is a permutation of ``req_latency`` — per-tenant
        metrics merge back to the untagged aggregate bit-exactly."""
        if self.req_tenant is None:
            return {0: self.req_latency}
        return {int(t): self.req_latency[self.req_tenant == t]
                for t in np.unique(self.req_tenant)}

    def conflict_rate(self) -> float:
        return float(np.mean(self.conflict))

    def failure_rate(self) -> float:
        """Fraction of transactions that permanently failed (dead path)."""
        if self.failed is None or len(self.failed) == 0:
            return 0.0
        return float(np.mean(self.failed))

    def iops_ok(self, n_requests: int | None = None) -> float:
        """Throughput counting only requests with NO failed transaction —
        the degraded-mode retention metric (a timed-out request is not
        service)."""
        if self.req_failed is None:
            return self.iops(n_requests)
        n_all = len(self.req_latency) if n_requests is None else n_requests
        n_ok = n_all - int(np.sum(self.req_failed))
        return n_ok / max(self.exec_s, 1e-12)


def _pad_to(n: int) -> int:
    """Bucket pad lengths to limit recompiles.

    Powers of 4: compile cost per program dwarfs the cost of scanning the
    extra padded (cond-skipped) steps, so fewer/coarser buckets win."""
    size = 1024
    while size < n:
        size *= 4
    return size


def _nominal_order_ref(cfg: SSDConfig, txns) -> np.ndarray:
    """Reference (per-transaction loop) for :func:`_nominal_order` — kept as
    the parity oracle for the vectorized grouped-cumsum pass below."""
    arrival = np.asarray(txns["arrival"], dtype=np.int64)
    kind = np.asarray(txns["kind"])
    plane = np.asarray(txns["plane"])
    nbytes = np.asarray(txns["nbytes"], dtype=np.int64)
    arr_order = np.argsort(arrival, kind="stable")
    plane_avail = np.zeros((cfg.n_planes,), dtype=np.int64)
    xfer_est = nbytes // TICK_NS  # ~1 B/ns
    nominal = np.zeros_like(arrival)
    t_r, t_w, t_e = cfg.t_read, cfg.t_prog, cfg.t_erase
    for i in arr_order:
        p = plane[i]
        s = max(arrival[i], plane_avail[p])
        k = kind[i]
        if k == KIND_READ:
            ready = s + 1 + t_r
            nominal[i] = ready
            plane_avail[p] = ready + xfer_est[i]
        elif k == KIND_WRITE:
            nominal[i] = s
            plane_avail[p] = s + xfer_est[i] + t_w
        else:
            nominal[i] = s
            plane_avail[p] = s + t_e
    return np.argsort(nominal, kind="stable")


def _nominal_times(cfg: SSDConfig, txns, avail0: np.ndarray | None = None):
    """Nominal per-txn readiness times plus the post-stream per-plane FIFO
    availability — the carry the streaming engine threads across windows.

    Vectorized as a grouped-cumsum pass (bit-exact to
    :func:`_nominal_order_ref` when ``avail0`` is None/zero): per plane, the
    FIFO recurrence ``avail' = max(arrival, avail) + d`` unrolls to
    ``avail_k = max(avail0_p, max_{j<k}(arrival_j - D_j)) + D_k`` with ``D``
    the in-plane exclusive prefix sum of the durations ``d`` — a segmented
    cumsum plus a segmented running max over plane groups.  ``avail0``
    generalizes the 0 floor to a carried initial plane availability (>= 0).

    Returns ``(nominal int64 [n], avail_out int64 [n_planes])``.
    """
    arrival = np.asarray(txns["arrival"], dtype=np.int64)
    n = len(arrival)
    out_avail = (np.zeros((cfg.n_planes,), dtype=np.int64)
                 if avail0 is None else np.asarray(avail0, np.int64).copy())
    if n == 0:
        return np.empty((0,), dtype=np.int64), out_avail
    kind = np.asarray(txns["kind"])
    plane = np.asarray(txns["plane"])
    nbytes = np.asarray(txns["nbytes"], dtype=np.int64)
    xfer_est = nbytes // TICK_NS  # ~1 B/ns
    t_r, t_w, t_e = cfg.t_read, cfg.t_prog, cfg.t_erase
    d = np.where(
        kind == KIND_READ, 1 + t_r + xfer_est,
        np.where(kind == KIND_WRITE, xfer_est + t_w, np.int64(t_e)),
    ).astype(np.int64)
    # contiguous plane groups, (arrival, original index)-ordered within each
    o = np.lexsort((np.arange(n), arrival, plane))
    p_s, a_s, d_s = plane[o], arrival[o], d[o]
    start = np.empty(n, dtype=bool)
    start[0] = True
    start[1:] = p_s[1:] != p_s[:-1]
    excl = np.cumsum(d_s) - d_s
    # in-group exclusive prefix sum: subtract each group's start value
    # (``excl`` is nondecreasing, so a running max forward-fills the starts)
    D = excl - np.maximum.accumulate(np.where(start, excl, -1))
    v = a_s - D
    # segmented running max via the monotone-offset trick: group ranks are
    # nondecreasing along the sort, so adding rank*span makes accumulation
    # never cross a group boundary
    gid = np.cumsum(start) - 1
    span = np.int64(v.max()) - np.int64(v.min()) + 1
    m = np.maximum.accumulate(v + gid * span) - gid * span
    # exclusive shift within the group; floor = the initial plane_avail
    m_excl = np.empty(n, dtype=np.int64)
    m_excl[1:] = m[:-1]
    m_excl[start] = 0
    avail = np.maximum(m_excl, out_avail[p_s]) + D
    s = np.maximum(a_s, avail)
    nom_s = s + np.where(kind[o] == KIND_READ, np.int64(1 + t_r), 0)
    nominal = np.empty(n, dtype=np.int64)
    nominal[o] = nom_s
    # each plane group's last element carries the whole group's FIFO end
    ends = np.flatnonzero(np.concatenate((start[1:], [True])))
    out_avail[p_s[ends]] = np.maximum(a_s[ends], avail[ends]) + d_s[ends]
    return nominal, out_avail


def _nominal_order(cfg: SSDConfig, txns) -> np.ndarray:
    """Order transactions by *nominal network-transfer time* (FIFO per plane,
    zero network contention).  The scan commits resources in this order, so
    commitments are near-chronological — the property that makes the in-order
    O(1)-state commit faithful to an event-driven simulator.  A write stuck
    behind a 100 us tPROG no longer reserves links/buses ahead of thousands
    of transfers that really happen first.
    """
    nominal, _ = _nominal_times(cfg, txns)
    return np.argsort(nominal, kind="stable")


def _nominal_order_carry(cfg: SSDConfig, txns, avail0: np.ndarray):
    """Streaming variant: order the window's transactions with the carried
    per-plane FIFO availability as the floor; returns ``(order, avail_out)``
    with ``avail_out`` in the window's (rebased) tick frame."""
    nominal, avail_out = _nominal_times(cfg, txns, avail0)
    return np.argsort(nominal, kind="stable"), avail_out


_M64 = np.uint64(0xFFFFFFFFFFFFFFFF)


def _read_retry_extra(faults, kind: np.ndarray, node: np.ndarray,
                      arrival: np.ndarray, plane: np.ndarray) -> np.ndarray:
    """Deterministic read-retry latency-ladder extension (ticks, int32).

    Chip-level read-retry (DDR-NAND tail model): each read on an afflicted
    chip independently fails its sense with probability ``retry_prob`` per
    ladder rung, paying that rung's extra ticks, until a rung succeeds or
    the ladder is exhausted.  The draw is a splitmix64 hash of the
    transaction's (arrival, plane) and the FaultSpec's ``retry_seed`` —
    design-independent, so every lane of a sweep sees the identical
    extended reads and the sweep stays an apples-to-apples comparison.
    """
    sel = kind == KIND_READ
    if faults.retry_chips:  # () = every chip afflicted
        sel &= np.isin(node, np.asarray(faults.retry_chips))
    extra = np.zeros((len(kind),), np.int64)
    if not sel.any():
        return extra
    with np.errstate(over="ignore"):  # wraparound is the hash
        base = (arrival.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
                + plane.astype(np.uint64) * np.uint64(0xBF58476D1CE4E5B9)
                + np.uint64(faults.retry_seed & 0xFFFFFFFF))
    alive = sel.copy()
    for i, rung in enumerate(faults.retry_ladder):
        inc = np.uint64(((i + 1) * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF)
        z = (base + inc) & _M64
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9) & _M64
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB) & _M64
        z = z ^ (z >> np.uint64(31))
        u = (z >> np.uint64(11)).astype(np.float64) * (2.0 ** -53)
        alive = alive & (u < faults.retry_prob)
        if not alive.any():
            break
        extra = np.where(alive, extra + int(rung), extra)
    return extra


def _pack_txns(cfg: SSDConfig, txns, order: np.ndarray, faults=None):
    """Reorder numpy transaction fields into (host) TxnArrays, unpadded.

    Capacity padding happens at group-stack time (the planner pads each
    lane to its pool's capacity bucket), so the packed arrays here are the
    natural length and can be re-sliced per channel row without copies of
    the padding.  ``faults`` (a ``designs.FaultSpec``) applies the
    read-retry latency ladder to ``op_ticks`` host-side — the scan steps
    stay RNG-free and every design lane shares the extension."""
    n = len(order)

    def f(name, dtype):
        return np.asarray(txns[name])[order].astype(dtype)

    kind = f("kind", np.int32)
    op = np.where(
        kind == KIND_READ,
        cfg.t_read,
        np.where(kind == KIND_WRITE, cfg.t_prog, cfg.t_erase),
    ).astype(np.int32)
    if faults is not None and faults.retry_active:
        op = (op + _read_retry_extra(
            faults, kind, f("node", np.int64), f("arrival", np.int64),
            f("plane", np.int64),
        )).astype(np.int32)

    arrs = TxnArrays(
        arrival=f("arrival", np.int32),
        kind=kind,
        plane=f("plane", np.int32),
        node=f("node", np.int32),
        row=f("row", np.int32),
        nbytes=f("nbytes", np.int32),
        op_ticks=op,
        valid=np.ones((n,), dtype=bool),
    )
    return arrs, op


def _finish_result(cfg: SSDConfig, design: str, txns, order,
                   op: np.ndarray, outs: StepOut, n: int) -> SimResult:
    """Numpy post-processing of one lane's scan outputs into a SimResult.

    ``outs`` holds this lane's per-transaction numpy arrays in scan
    (ordered) space, length >= n (the planner merges channel-decomposed
    rows back into that space before calling)."""
    completion = outs.completion[:n]
    arrival = np.asarray(txns["arrival"])[order]
    latency = completion - arrival
    exec_ticks = int(completion.max() - arrival.min()) if n else 0

    # host-request latency: completion of a request = max over its page txns
    req = np.asarray(txns["req"])[order]
    n_req = int(req.max()) + 1 if len(req) and req.max() >= 0 else 0
    req_done = np.zeros((n_req,), np.int64)
    req_arr = np.full((n_req,), np.iinfo(np.int64).max)
    host = req >= 0
    np.maximum.at(req_done, req[host], completion[host].astype(np.int64))
    np.minimum.at(req_arr, req[host], arrival[host].astype(np.int64))
    seen = req_arr < np.iinfo(np.int64).max
    req_latency = (req_done - req_arr)[seen]
    req_completion = req_done[seen]
    failed = (np.asarray(outs.failed[:n], bool)
              if getattr(outs, "failed", None) is not None
              else np.zeros((n,), bool))
    req_fail = np.zeros((n_req,), bool)
    np.logical_or.at(req_fail, req[host], failed[host])
    req_failed = req_fail[seen]
    tenant = getattr(txns, "tenant_of_req", None)
    req_tenant = None
    if tenant is not None and len(tenant) >= n_req:
        req_tenant = np.asarray(tenant, np.int32)[:n_req][seen]

    pm = cfg.power
    tick_s = TICK_NS * 1e-9
    kind = np.asarray(txns["kind"])[order].astype(np.int32)
    die_w = np.where(
        kind == KIND_READ,
        pm.die_read_w,
        np.where(kind == KIND_WRITE, pm.die_prog_w, pm.die_erase_w),
    )
    flash_energy = float(np.sum(op.astype(np.float64) * tick_s * die_w))
    bus_hold = int(outs.bus_hold[:n].astype(np.int64).sum())
    link_hold = int(outs.link_hold[:n].astype(np.int64).sum())
    transfer_energy = (
        bus_hold * tick_s * pm.bus_active_w + link_hold * tick_s * pm.link_active_w
    )
    n_routers = REGISTRY[design].n_routers(build_mesh(cfg.rows, cfg.cols))
    static_energy = (pm.static_w + n_routers * pm.router_w) * exec_ticks * tick_s

    return SimResult(
        design=design,
        completion=completion,
        latency=latency,
        req_latency=req_latency,
        wait=outs.wait[:n],
        conflict=outs.conflict[:n],
        hops=outs.hops[:n],
        tries=outs.tries[:n],
        misroutes=outs.misroutes[:n],
        exec_ticks=exec_ticks,
        bus_hold_ticks=bus_hold,
        link_hold_ticks=link_hold,
        flash_energy_j=flash_energy,
        transfer_energy_j=float(transfer_energy),
        static_energy_j=float(static_energy),
        req_completion=req_completion,
        req_tenant=req_tenant,
        failed=failed,
        req_failed=req_failed,
    )


def simulate_sweep(
    cfg: SSDConfig,
    txns,
    designs: Sequence[str] = DESIGNS,
    seeds: int | Sequence[int] = 0,
    decompose: bool | str = "auto",
    faults=None,
) -> list[SimResult]:
    """Run the whole design sweep as batched, sharded jitted programs.

    ``txns`` is a dict/namespace with numpy fields: arrival (ticks int),
    kind, plane, node, row, nbytes, req (see ``repro.ssd.ftl``).
    ``designs`` are registry names (a name may repeat, e.g. to sweep seeds
    for one design); ``seeds`` is one int for every lane or a per-lane
    sequence.  Returns SimResults in lane order.

    Execution is delegated to the sweep planner (``repro.ssd.sweep_plan``):
    lanes are grouped per cost class, statically-routed lanes whose lowered
    masks are provably row-confined are decomposed by channel row
    (``decompose``: True / False / "auto" — all three are bit-identical;
    the flag only gates the perf transformation), and lane groups are
    sharded across host CPU devices.  Results are bit-identical to the flat
    single-lane scan for every design.

    ``faults`` (a ``designs.FaultSpec`` or None) injects hardware faults —
    lowered into per-design availability masks — plus the read-retry
    ladder.  ``None`` and an empty FaultSpec run the identical (bit-exact)
    fault-free program; the executables and their cache keys are shared
    either way, since the fault data rides the tables as arguments.
    """
    from repro.ssd.sweep_plan import execute_sim_runs

    designs = tuple(designs)
    resolve_specs(designs)
    if isinstance(seeds, (int, np.integer)):
        seeds = (int(seeds),) * len(designs)
    seeds = tuple(int(s) for s in seeds)
    if len(seeds) != len(designs):
        raise ValueError(
            f"got {len(seeds)} seeds for {len(designs)} design lanes"
        )
    run = (cfg, txns, designs, seeds, decompose)
    if faults is not None:
        run = run + (faults,)
    return execute_sim_runs([run])[0]


def simulate(cfg: SSDConfig, txns, design: str, seed: int = 0,
             faults=None) -> SimResult:
    """Run one (config, design) simulation — a 1-lane design sweep.

    This is the flat-scan parity oracle for the decomposed/sharded paths:
    it never channel-decomposes.  Like every lane, it runs the shared
    design-agnostic executable of its (geometry, capacity, cost class,
    promotions) — only the 1-lane pool's *promotions* specialize it."""
    return simulate_sweep(cfg, txns, (design,), (seed,), decompose=False,
                          faults=faults)[0]
