"""Flash Translation Layer (§2.2): L2P mapping, out-of-place writes, GC,
wear-leveling — and the decomposition of host I/O requests into the page-level
transactions consumed by the simulator.

This module is the **scalar oracle**: one page per Python iteration, written
for obviousness, it defines the FTL's semantics.  The production path is the
array-native engine in ``repro.ssd.ftl_engine`` (``decompose_trace``'s
default for preconditioned traces), which is bit-identical by construction
and by test (``tests/test_ftl.py``); this module stays the parity reference
and still owns GC/victim selection, which the engine calls into at trigger
points.

The FTL runs *ahead of* the timing simulation (numpy, sequential): physical
placement uses static channel-first striping (CWDP order), which is standard
practice and — per the paper §7 — no allocation policy can lay data out to
avoid path conflicts under random access + multi-tenant interference, so
placement is identical across all simulated designs (fair comparison).

GC valid-page moves use in-plane copyback (read + program on the same plane,
no channel/network transfer — commodity NAND supports copyback), plus the
block erase.  GC transactions are injected at the arrival time of the write
that triggered collection.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.ssd.config import SSDConfig, us_to_ticks

KIND_READ, KIND_WRITE, KIND_ERASE = 0, 1, 2


class Transactions(dict):
    """dict of numpy arrays: arrival(ticks), kind, plane, node, row, nbytes, req.

    Carries side metadata as attributes (``ftl``, ``n_requests``, and — for
    multi-tenant traces — ``tenant_of_req``/``tenant_names``, the
    per-request tenant attribution threaded through to
    :class:`repro.ssd.sim.SimResult`).  Attribution is pure metadata: it
    never reaches the scan, so tagged and untagged decompositions of the
    same trace simulate bit-identically.
    """


def stripe_plane(cfg: SSDConfig, idx):
    """Chunked W-C-D-P striping: plane for allocation index ``idx``.

    Works elementwise on ints and numpy arrays — the single source of
    truth for both the scalar FTL and the array-native engine.
    Consecutive allocations fill one plane for ``cfg.chunk_pages`` pages
    (superpage allocation), then stripe *way (chip) first within the
    channel*, then across channels.  Die-first fill is the standard
    write-path layout — it pipelines a sequential write's bus transfers on
    one channel while neighbours' tPROGs overlap.  The flip side (the
    paper's motivation): sequentially-written / hot data ranges end up on
    many chips of ONE channel, so reading them back serializes on that
    channel in the shared-bus baseline while a path-diverse interconnect
    can reach all its chips concurrently."""
    idx = idx // max(1, cfg.chunk_pages)
    way = idx % cfg.cols
    idx = idx // cfg.cols
    ch = idx % cfg.rows
    idx = idx // cfg.rows
    die = idx % cfg.dies_per_chip
    idx = idx // cfg.dies_per_chip
    pl = idx % cfg.planes_per_die
    chip = ch * cfg.cols + way
    return (chip * cfg.dies_per_chip + die) * cfg.planes_per_die + pl


@dataclasses.dataclass
class FTL:
    """Page-mapping FTL over a footprint-scaled physical geometry."""

    cfg: SSDConfig
    n_lpns: int
    overprovision: float = 1.28
    gc_threshold: int = 2

    def __post_init__(self) -> None:
        cfg = self.cfg
        self.n_planes = cfg.n_planes
        phys_pages = int(self.n_lpns * self.overprovision)
        self.pages_per_block = cfg.pages_per_block
        bpp = -(-phys_pages // (self.n_planes * self.pages_per_block))
        self.blocks_per_plane = max(bpp, self.gc_threshold + 2)
        self.pages_per_plane = self.blocks_per_plane * self.pages_per_block

        self.l2p = np.full((self.n_lpns,), -1, dtype=np.int64)
        self.p2l = np.full((self.n_planes * self.pages_per_plane,), -1, dtype=np.int64)
        self.valid = np.zeros((self.n_planes, self.blocks_per_plane), dtype=np.int32)
        self.written = np.zeros((self.n_planes, self.blocks_per_plane), dtype=np.int32)
        self.erase_count = np.zeros((self.n_planes, self.blocks_per_plane), dtype=np.int64)
        # free-block stacks (wear-aware: pop the least-erased free block)
        self.is_free = np.ones((self.n_planes, self.blocks_per_plane), dtype=bool)
        self.open_block = np.zeros((self.n_planes,), dtype=np.int64)
        for p in range(self.n_planes):
            self.is_free[p, 0] = False  # block 0 starts open
        self.next_page = np.zeros((self.n_planes,), dtype=np.int64)
        self._stripe = 0  # global plane round-robin pointer
        self.gc_events = 0
        self.gc_page_moves = 0
        # read-before-write preconditioning (DESIGN.md §3): pages mapped on
        # demand by reads, and the GC transactions that mapping triggered —
        # those transactions are *dropped* from the stream (the read is
        # served as if the page were already resident), so we count them.
        self.read_precond_pages = 0
        self.read_precond_gc_txns = 0

    # --- geometry helpers -------------------------------------------------
    def plane_of_ppn(self, ppn: int) -> int:
        return int(ppn // self.pages_per_plane)

    def chip_of_plane(self, plane: int) -> int:
        cfg = self.cfg
        return plane // (cfg.dies_per_chip * cfg.planes_per_die)

    # --- allocation -------------------------------------------------------
    def _alloc_in_plane(
        self, plane: int, out: list | None, t: int, during_gc: bool = False
    ) -> int:
        """Allocate the next free page in ``plane``'s open block (GC as needed)."""
        if self.next_page[plane] >= self.pages_per_block:
            self._open_new_block(plane, out, t, during_gc)
        block = self.open_block[plane]
        off = self.next_page[plane]
        self.next_page[plane] += 1
        self.written[plane, block] += 1
        ppn = plane * self.pages_per_plane + block * self.pages_per_block + off
        return int(ppn)

    def _open_new_block(
        self, plane: int, out: list | None, t: int, during_gc: bool = False
    ) -> None:
        # GC runs only for host allocations; GC's own copyback writes draw
        # from the gc_threshold blocks of reserved headroom (no reentrancy)
        if not during_gc:
            # steady-state GC: one victim per triggering allocation (classic
            # greedy foreground GC), plus an emergency loop that defends the
            # 2-block headroom copyback draws from
            if (
                np.count_nonzero(self.is_free[plane]) <= self.gc_threshold
                and self._has_victim(plane)
            ):
                self._collect(plane, out, t)
            guard = 0
            while np.count_nonzero(self.is_free[plane]) < 2:
                if not self._has_victim(plane) or guard > 8:  # pragma: no cover
                    raise RuntimeError("GC cannot reclaim space")
                self._collect(plane, out, t)
                guard += 1
            if self.next_page[plane] < self.pages_per_block:
                # GC's copyback writes re-opened a block with room left —
                # keep filling it instead of abandoning a partial block
                return
        free_ids = np.flatnonzero(self.is_free[plane])
        if len(free_ids) == 0:  # pragma: no cover
            raise RuntimeError(f"plane {plane} out of blocks during GC")
        # wear leveling: open the least-erased free block
        nxt = free_ids[np.argmin(self.erase_count[plane, free_ids])]
        self.is_free[plane, nxt] = False
        self.open_block[plane] = nxt
        self.next_page[plane] = 0

    def _victim_mask(self, plane: int) -> np.ndarray:
        full = (self.written[plane] >= self.pages_per_block) & ~self.is_free[plane]
        full[self.open_block[plane]] = False
        return full

    def _has_victim(self, plane: int) -> bool:
        return bool(self._victim_mask(plane).any())

    def _collect(self, plane: int, out: list | None, t: int) -> None:
        """Greedy GC: victim = fully-written block with fewest valid pages."""
        cand = np.flatnonzero(self._victim_mask(plane))
        if len(cand) == 0:
            raise RuntimeError(
                f"plane {plane} has no GC victim — overprovision too small"
            )
        victim = cand[np.argmin(self.valid[plane, cand])]
        self.gc_events += 1
        base = plane * self.pages_per_plane + victim * self.pages_per_block
        for off in range(self.pages_per_block):
            lpn = self.p2l[base + off]
            if lpn < 0:
                continue
            # copyback: read + program in-plane, no network transfer
            self.gc_page_moves += 1
            new_ppn = self._alloc_in_plane(plane, out, t, during_gc=True)
            self.l2p[lpn] = new_ppn
            self.p2l[new_ppn] = lpn
            self.p2l[base + off] = -1
            self.valid[plane, victim] -= 1
            blk = new_ppn // self.pages_per_block % self.blocks_per_plane
            self.valid[plane, blk] += 1
            if out is not None:
                out.append((t, KIND_READ, plane, 0, -1))
                out.append((t, KIND_WRITE, plane, 0, -1))
        self.valid[plane, victim] = 0
        self.written[plane, victim] = 0
        self.is_free[plane, victim] = True
        self.erase_count[plane, victim] += 1
        if out is not None:
            out.append((t, KIND_ERASE, plane, 0, -1))

    def _stripe_plane(self, idx: int) -> int:
        """Chunked W-C-D-P striping (see module-level ``stripe_plane``)."""
        return int(stripe_plane(self.cfg, idx))

    # --- host ops ----------------------------------------------------------
    def write_page(self, lpn: int, out: list | None, t: int) -> int:
        old = self.l2p[lpn]
        if old >= 0:  # out-of-place: invalidate the overwritten physical page
            pl = self.plane_of_ppn(old)
            blk = (old % self.pages_per_plane) // self.pages_per_block
            self.valid[pl, blk] -= 1
            self.p2l[old] = -1
        plane = self._stripe_plane(self._stripe)  # CWDP page striping
        self._stripe += 1
        ppn = self._alloc_in_plane(plane, out, t)
        self.l2p[lpn] = ppn
        self.p2l[ppn] = lpn
        blk = (ppn % self.pages_per_plane) // self.pages_per_block
        self.valid[plane, blk] += 1
        return ppn

    def read_page(self, lpn: int) -> int:
        ppn = self.l2p[lpn]
        if ppn < 0:  # read-before-write: precondition instantly
            # The mapping write (and any GC it triggers) mutates FTL state
            # but emits no transactions — the read is modeled as hitting
            # already-resident data.  Count the dropped work (DESIGN.md §3).
            dropped: list = []
            self.read_precond_pages += 1
            ppn = self.write_page(lpn, dropped, 0)
            self.read_precond_gc_txns += len(dropped)
        return int(ppn)


def to_transactions(
    cfg: SSDConfig, arr: np.ndarray, ftl: FTL, n_requests: int
) -> Transactions:
    """Insertion-ordered (tick, kind, plane, nbytes, req) rows → Transactions.

    Shared tail of both decomposition engines: the *stable* sort by arrival
    tick is what makes "same rows in the same insertion order" imply
    bit-identical output arrays.
    """
    if arr.size == 0:
        arr = np.zeros((0, 5), dtype=np.int64)
    if arr.size and int(arr[:, 0].max()) > np.iinfo(np.int32).max:
        raise ValueError(
            "transaction arrival ticks exceed the int32 budget — replay the "
            "trace windowed instead (repro.ssd.stream.stream_simulate slices "
            "it into tick-rebased windows)"
        )
    order = np.argsort(arr[:, 0], kind="stable")
    arr = arr[order]
    plane = arr[:, 2]
    chip = plane // (cfg.dies_per_chip * cfg.planes_per_die)
    txns = Transactions(
        arrival=arr[:, 0].astype(np.int32),
        kind=arr[:, 1].astype(np.int32),
        plane=plane.astype(np.int32),
        node=chip.astype(np.int32),
        row=(chip // cfg.cols).astype(np.int32),
        nbytes=arr[:, 3].astype(np.int32),
        req=arr[:, 4].astype(np.int32),
    )
    txns.ftl = ftl  # expose for tests / stats
    txns.n_requests = n_requests
    # read-before-write preconditioning work (dropped from the stream but
    # counted — DESIGN.md §3); zero whenever ``precondition=True``
    txns.read_precond_pages = ftl.read_precond_pages
    txns.read_precond_gc_txns = ftl.read_precond_gc_txns
    return txns


def decompose_trace(
    cfg: SSDConfig,
    trace: Dict[str, np.ndarray],
    footprint_pages: int,
    overprovision: float = 1.28,
    precondition: bool = True,
    seed: int = 0,
    engine: str = "auto",
    resume: "FTL | None" = None,
    arrival_ticks: np.ndarray | None = None,
) -> Transactions:
    """Host trace → page-level transaction arrays for ``repro.ssd.sim``.

    ``trace``: arrival_us (f64), is_read (bool), offset_page (int64, in cfg
    pages), n_pages (int).  Offsets are taken modulo ``footprint_pages``.

    ``engine``: ``"vector"`` runs the array-native engine
    (``repro.ssd.ftl_engine``, bit-identical by construction and by test),
    ``"scalar"`` forces this module's page-at-a-time oracle, ``"auto"``
    picks vector whenever it applies (preconditioned traces — the vector
    read path is a pure L2P gather, which requires every read to hit a
    mapped page).

    Streaming (``repro.ssd.stream``): ``resume`` is the carried FTL of the
    previous window — construction *and* preconditioning are skipped, the
    decomposition continues from the carried L2P/free-block/GC state, and
    the same object (mutated in place) is handed back on the result.
    ``arrival_ticks`` overrides the per-request tick computation with
    precomputed (int64, window-rebased) arrival ticks so window splits use
    exactly the ticks a monolithic run would have derived from float
    microseconds.
    """
    if engine not in ("auto", "vector", "scalar"):
        raise ValueError(f"unknown FTL engine {engine!r}")
    if engine == "vector" and not precondition and resume is None:
        raise ValueError(
            "vector FTL engine requires precondition=True "
            "(reads lower to pure L2P gathers)"
        )
    if engine != "scalar" and (precondition or resume is not None):
        from repro.ssd.ftl_engine import decompose_vectorized

        return _attach_tenants(decompose_vectorized(
            cfg,
            trace,
            footprint_pages,
            overprovision=overprovision,
            seed=seed,
            resume=resume,
            arrival_ticks=arrival_ticks,
        ), trace)
    if resume is not None:
        ftl = resume
    else:
        ftl = FTL(cfg, n_lpns=footprint_pages, overprovision=overprovision)
        if precondition:
            # map the whole footprint so reads always hit a valid physical
            # page.  Sequential LPN order preserves spatial locality:
            # consecutive LBAs share a chunk/chip and nearby chunks share a
            # channel (W-C-D-P), as they would after a real sequential fill.
            for lpn in range(footprint_pages):
                ftl.write_page(lpn, None, 0)

    arrival = trace["arrival_us"]
    is_read = trace["is_read"]
    offset = trace["offset_page"]
    n_pages = trace["n_pages"]
    rows = []  # (ticks, kind, plane, nbytes, req)
    for i in range(len(arrival)):
        t = (int(arrival_ticks[i]) if arrival_ticks is not None
             else us_to_ticks(float(arrival[i])))
        base = int(offset[i])
        for k in range(int(n_pages[i])):
            lpn = (base + k) % footprint_pages
            if is_read[i]:
                ppn = ftl.read_page(lpn)
                plane = ftl.plane_of_ppn(ppn)
                rows.append((t, KIND_READ, plane, cfg.page_bytes, i))
            else:
                gc_out: list = []
                ftl.write_page(lpn, gc_out, t)
                # the host write itself
                plane = ftl.plane_of_ppn(ftl.l2p[lpn])
                rows.append((t, KIND_WRITE, plane, cfg.page_bytes, i))
                # GC work occupies resources but is background traffic: it is
                # not part of the triggering request's host-visible latency
                for (tg, kind, pl, nb, _r) in gc_out:
                    rows.append((tg, kind, pl, nb, -1))

    arr = np.asarray(rows, dtype=np.int64)
    return _attach_tenants(
        to_transactions(cfg, arr, ftl, int(len(arrival))), trace
    )


def _attach_tenants(txns: Transactions, trace: Dict) -> Transactions:
    """Thread per-request tenant attribution (if the trace carries any)."""
    tenant = trace.get("tenant")
    if tenant is not None:
        txns.tenant_of_req = np.asarray(tenant, np.int32)
        txns.tenant_names = tuple(trace.get(
            "tenant_names",
            [str(t) for t in range(int(txns.tenant_of_req.max()) + 1)],
        ))
    return txns
