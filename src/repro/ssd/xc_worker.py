"""Out-of-process compile server for the overlapped pipeline.

``sweep_plan.precompile`` measured in-process background compilation at a
~2.3x tax on small hosts: tracing fights the dispatcher for the GIL and
the XLA backend compile fights the executing groups for cores, while the
``compile_s`` critical path is exactly what the pipeline tries to hide.
This worker moves the whole compile stream into its own process: it
receives a pickled list of executable keys (every builder is a pure
function of its key — see ``sim._fn_for_key``), compiles the missing ones
longest-first, and publishes them into the persistent store
(``repro.ssd.exec_cache``), where the parent's dispatch loop adopts them
the moment the atomic rename lands.  The parent polls the store; if this
process dies or lags, it falls back to compiling locally — the server is
a scheduling hint with no correctness surface.

Invoked as ``python -m repro.ssd.xc_worker <keyfile>`` with the parent's
environment (same XLA_FLAGS/device topology, so the store digests match).
"""
from __future__ import annotations

import pickle
import sys


def _start_heartbeat() -> None:
    """Touch the parent's heartbeat file ~1/s from a daemon thread.

    Started before the jax import so the boot window beats too.  A
    SIGKILLed, wedged (GIL-held C loop), or SIGSTOPped worker stops
    beating; the parent's watchdog (``sweep_plan._ServerWatchdog``) then
    reclaims every delegated key for in-process compilation."""
    import os
    import threading
    import time

    path = os.environ.get("REPRO_XC_HEARTBEAT")
    if not path:
        return

    def _beat():
        while True:
            try:
                with open(path, "w") as f:
                    f.write(str(time.time()))
            except OSError:
                return  # parent cleaned up — stop quietly
            time.sleep(1.0)

    threading.Thread(target=_beat, daemon=True,
                     name="xc-heartbeat").start()


def _span_writer():
    """Appender for the parent's span sidecar (``REPRO_XC_SPANS``).

    The worker can't share the parent's in-memory tracer, so it appends
    one JSON line per compiled key — ``{"name", "t0_epoch", "dur_s", ...}``
    in epoch seconds — and the parent's trace export rebases the lines onto
    its own clock as ``xc-worker`` track spans.  No-op when the parent
    isn't tracing; write failures never disturb compilation."""
    import json
    import os
    import time

    path = os.environ.get("REPRO_XC_SPANS")
    if not path:
        return lambda name, t0, **kw: None

    def emit(name: str, t0: float, **kw) -> None:
        try:
            with open(path, "a") as f:
                f.write(json.dumps(
                    {"name": name, "t0_epoch": t0,
                     "dur_s": time.time() - t0, **kw}) + "\n")
        except OSError:
            pass

    return emit


def main() -> None:
    import os
    import time

    _start_heartbeat()
    emit_span = _span_writer()
    with open(sys.argv[1], "rb") as f:
        keys = pickle.load(f)
    os.unlink(sys.argv[1])
    # fresh process: serialization is reliable here, and the parent's
    # load-time tombstone fallback covers the residual risk — skip the
    # store-time round-trip verification to publish entries sooner
    os.environ["REPRO_XC_VERIFY"] = "0"
    t_boot = time.time()
    from repro.ssd import exec_cache
    from repro.ssd import sim as S

    emit_span("worker_boot", t_boot, keys=len(keys))
    # one compile stream: keys arrive in the parent's need order, so the
    # earliest-needed programs publish first (a second stream was measured
    # to DELAY early programs and fight the parent's executing devices for
    # cores — single-stream-in-need-order wins on small hosts)
    for key in keys:
        try:
            if exec_cache.has(key):
                continue
            t0 = time.time()
            S.ensure_compiled(key)
            emit_span(f"compile:{key[0]}", t0)
        except Exception as e:  # noqa: BLE001 — skip, parent will compile
            print(f"[xc_worker] {key[0]} failed: {type(e).__name__}: {e}",
                  file=sys.stderr, flush=True)
    exec_cache.flush()


if __name__ == "__main__":
    main()
