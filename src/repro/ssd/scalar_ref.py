"""Scalar fault-aware reference for the jitted scan step (ISSUE 8).

A plain-python/numpy transliteration of the two step bodies built by
``sim._make_step`` — ``static_step`` (bus / fixed-route mesh designs,
two-candidate scheduling over the unified resource vector) and
``scout_step`` (Venice: FC selection, scout retry loop, circuit commit) —
one transaction at a time, with the fault semantics threaded through
exactly as in the vectorized scan: dead candidates lose selection, a
transaction with no live candidate fails permanently at
``tcand + FAIL_TIMEOUT``, dead links look busy to the scout DFS
(``routing.scout_route_ref`` is the decision-identical routing oracle),
and dead FCs are never selected.

This module is the *oracle* the vectorized fault path is pinned against
element-wise (``tests/test_faults.py``), the same role
``routing.scout_route_ref`` / ``ftl.FTL`` / ``sim._nominal_order_ref``
play for their engines.  It shares only host-side, non-jitted helpers
with ``sim`` (packing, nominal ordering, state rebase); every scheduling
decision of the scan itself is re-derived independently here.

State is carried in exactly ``sim.initial_lane_state``'s layout, so the
streaming window-boundary tests rebase it with the production
``sim.rebase_lane_state`` and swap faulted tables between windows just
like ``stream.stream_simulate`` does.
"""
from __future__ import annotations

import numpy as np

from repro.core.routing import scout_route_ref
from repro.core.topology import build_mesh
from repro.ssd import sim as S
from repro.ssd.config import SSDConfig, TICK_NS
from repro.ssd.designs import (
    KIND_SCOUT,
    LaneTables,
    lower_designs,
    resolve_specs,
    sweep_layout_geom,
)
from repro.ssd.ftl import KIND_READ

__all__ = ["LaneRef", "simulate_ref"]

_BIG = int(S._BIG)
_FAIL = int(S.FAIL_TIMEOUT)
_MAX_TRIES = S._MAX_TRIES


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ---- the one-gap resource model, scalar ----------------------------------
# resources are numpy int32 triples (free_at, gap_s, gap_e); all arithmetic
# below runs in python ints (values are bounded by _BIG + a few durations,
# well inside int32, so the int32 scan and this reference agree exactly)


def _gap_avail(gs, ge, fa, e, d):
    s_gap = max(e, gs)
    if s_gap + d <= ge:
        return s_gap
    return max(e, fa)


def _gap_commit(gs, ge, fa, s, e2):
    if s >= gs and e2 <= ge:  # inside the remembered gap
        if (s - gs) >= (ge - e2):
            return gs, s, fa
        return e2, ge, fa
    new_idle = max(s, fa) - fa
    if (ge - gs) >= new_idle:
        return gs, ge, max(fa, e2)
    return fa, max(s, fa), max(fa, e2)


def _avail1(res, i, e, d):
    free, gs, ge = res
    return _gap_avail(int(gs[i]), int(ge[i]), int(free[i]), e, d)


def _commit1(res, i, s, e2, enable):
    if not enable:
        return
    free, gs, ge = res
    ngs, nge, nfa = _gap_commit(int(gs[i]), int(ge[i]), int(free[i]), s, e2)
    free[i], gs[i], ge[i] = nfa, ngs, nge


def _busy_at1(res, i, t, d):
    free, gs, ge = res
    return not (t >= int(free[i])
                or (t >= int(gs[i]) and t + d <= int(ge[i])))


def _sched_gap(res, i, e, d, enable):
    s = _avail1(res, i, e, d) if enable else e
    _commit1(res, i, s, s + d, enable)
    return s


def _path_sched(res, mask, e, d):
    """Earliest common start — transliterates ``path_sched`` including the
    masked-out zeros inside the maxima."""
    free = res[0]
    s1 = 0
    tail = 0
    for i in range(len(mask)):
        if mask[i]:
            s1 = max(s1, _avail1(res, i, e, d))
            tail = max(tail, int(free[i]))
    s1 = max(s1, e)
    ok = not any(mask[i] and _busy_at1(res, i, s1, d)
                 for i in range(len(mask)))
    return s1 if ok else max(e, tail)


def _commit_mask(res, mask, s, e2, enable):
    if not enable:
        return
    for i in range(len(mask)):
        if mask[i]:
            _commit1(res, i, s, e2, True)


def _fc_select(avail, dist_row, tcand):
    """Closest FC available now, else earliest-available (first-min
    argmin ties, matching ``jnp.argmin``)."""
    free_now = [a <= tcand for a in avail]
    if any(free_now):
        key = [d if f else _BIG for d, f in zip(dist_row, free_now)]
        fc = int(np.argmin(key))
    else:
        fc = int(np.argmin(avail))
    return fc, max(tcand, avail[fc])


class LaneRef:
    """One design lane of the scalar reference scan.

    ``state`` is the production lane-state pytree (numpy); pass a carried
    state into :meth:`run` to replay streaming windows, rebasing between
    them with ``sim.rebase_lane_state`` and swapping tables via
    :meth:`set_faults` at window boundaries."""

    def __init__(self, cfg: SSDConfig, design: str, faults=None):
        self.cfg = cfg
        self.design = design
        self.spec = resolve_specs((design,))[0]
        self.scout = self.spec.kind == KIND_SCOUT
        sig = S._geom_sig(cfg)
        self.topo = build_mesh(sig[0], sig[1])
        self.lay = sweep_layout_geom(sig[0], sig[1])
        self.scout_hop_ns = sig[4]
        self.set_faults(faults)

    def set_faults(self, faults) -> None:
        """(Re-)lower this lane's tables under ``faults`` — the scalar
        analogue of the stream engine's window-boundary table swap."""
        tables = lower_designs(self.cfg, (self.design,), faults)
        self.t = LaneTables(*(np.asarray(a)[0] for a in tables))

    # -- scalar views of the lowered tables --
    def _sc(self, name):
        return np.asarray(getattr(self.t, name)).item()

    def initial_state(self, seed: int):
        return S.initial_lane_state(self.cfg, self.scout, seed)

    def _cmd_ticks(self, hops: int) -> int:
        ns = self._sc("cmd_base_ns") + hops * self._sc("hop_ns")
        return max(_ceil_div(ns, TICK_NS), 1)

    def _xfer_ticks(self, nbytes: int, hops: int) -> int:
        ns = _ceil_div(nbytes * self._sc("xfer_num"), self._sc("xfer_den"))
        return _ceil_div(ns + hops * self._sc("hop_ns"), TICK_NS)

    def _d_est(self, nbytes: int, is_read: bool, op: int) -> int:
        d = (self._xfer_ticks(nbytes, self._sc("d_est_hops"))
             + self._sc("d_est_pad"))
        if self._sc("hold") and is_read:
            d += op
        return d

    # -- one statically-routed transaction ---------------------------------
    def _static_txn(self, state, tx: dict) -> dict:
        plane_free, res = state
        L0, F0 = self.lay.L_pad, self.lay.F_pad
        t = self.t
        is_read = tx["kind"] == KIND_READ
        tcand = max(tx["arrival"], int(plane_free[tx["plane"]]))
        d_est = self._d_est(tx["nbytes"], is_read, tx["op"])

        if self._sc("fc_nearest"):
            avail = [
                _avail1(res, L0 + f, tcand, d_est)
                if bool(t.fc_valid[f]) else _BIG
                for f in range(F0)
            ]
            fc, t0 = _fc_select(avail,
                                [int(t.dist[f, tx["node"]])
                                 for f in range(F0)], tcand)
            fcA = fcB = fc
        else:
            t0 = tcand
            fcA = int(t.fc_fixed[tx["node"], 0])
            fcB = int(t.fc_fixed[tx["node"], 1])
        cand2 = bool(t.cand2_ok[tx["node"]])

        def eval_cand(fc, cand, enable):
            mask = np.asarray(t.cmask[fc, tx["node"], cand], bool)
            dead = bool(np.any(mask & np.asarray(t.res_dead, bool)))
            enable = enable and not dead
            hops = int(t.hops[fc, tx["node"], cand])
            cmd = self._cmd_ticks(hops)
            xfer = self._xfer_ticks(tx["nbytes"], hops)
            ovh = self._sc("ovh")
            d0 = ovh + cmd + (0 if is_read else xfer)
            r = tuple(a.copy() for a in res)
            s0 = _path_sched(r, mask, t0, d0)
            _commit_mask(r, mask, s0, s0 + d0, enable)
            op_end = s0 + d0 + tx["op"]
            d1 = ovh + xfer
            s1 = _path_sched(r, mask, op_end, d1)
            _commit_mask(r, mask, s1, s1 + d1, enable and is_read)
            done = s1 + d1 if is_read else op_end
            wait = (s0 - t0) + (s1 - op_end if is_read else 0)
            occ = d0 + (d1 if is_read else 0)
            return r, done, wait, occ, hops, dead

        resA, doneA, waitA, occA, hopsA, deadA = eval_cand(fcA, 0, True)
        resB, doneB, waitB, occB, hopsB, deadB = eval_cand(fcB, 1, cand2)
        useA = ((_BIG if deadA else doneA)
                <= (doneB if (cand2 and not deadB) else _BIG))
        failed = deadA and (deadB or not cand2)
        res_new = resA if useA else resB
        done, wait, occ, hops_o = (
            (doneA, waitA, occA, hopsA) if useA
            else (doneB, waitB, occB, hopsB)
        )
        if failed:
            done = tcand + _FAIL
            wait = _FAIL
            occ = 0
            hops_o = 0
        for a, b in zip(res, res_new):
            a[:] = b
        plane_free[tx["plane"]] = done
        count_bus = self._sc("count_bus")
        return dict(
            completion=done, wait=wait, conflict=wait > 0, hops=hops_o,
            tries=1, scout_steps=0, misroutes=0,
            bus_hold=occ if count_bus else 0,
            link_hold=0 if count_bus else hops_o * occ,
            failed=failed,
        )

    # -- one scout-routed transaction --------------------------------------
    def _scout_until_success(self, links, src, dst, t0, rng, d_hold):
        t = self.t
        n_scouts = int(self._sc("n_scouts"))
        allow = bool(self._sc("allow_nonmin"))
        nl = self.topo.n_links
        dead = np.asarray(t.res_dead, bool)[:nl]

        def try_once(tt, rng):
            busy = np.array(
                [_busy_at1(links, i, tt, d_hold) for i in range(nl)], bool
            ) | dead
            best = None
            for k in range(n_scouts):
                rng = ((rng * 747796405 + 2891336453) & 0xFFFFFFFF) | 1
                r = scout_route_ref(self.topo, src, dst, busy, rng, allow)
                if best is None:
                    best = r
                elif r.success and (not best.success or r.hops < best.hops):
                    best = r
            return best, rng

        res, rng = try_once(t0, rng)
        tt, tries = t0, 1
        free, gs, _ = links
        while not res.success and tries < _MAX_TRIES:
            ev = min(
                min((int(f) for f in free if int(f) > tt), default=_BIG),
                min((int(g) for g in gs if int(g) > tt), default=_BIG),
            )
            t_next = max(ev, tt + 1)
            if tries + 1 >= _MAX_TRIES:
                t_next = int(free.max())
            res, rng = try_once(t_next, rng)
            tt = t_next
            tries += 1
        return res, tt, rng, tries

    def _scout_txn(self, state, tx: dict) -> dict:
        plane_free, links, fcs, chips, rng = state
        t = self.t
        n_fcs = self.lay.rows
        is_read = tx["kind"] == KIND_READ
        hold = bool(self._sc("hold"))
        tcand = max(tx["arrival"], int(plane_free[tx["plane"]]))
        d_est = self._d_est(tx["nbytes"], is_read, tx["op"])
        avail = [
            _avail1(fcs, f, tcand, d_est) if bool(t.fc_valid[f]) else _BIG
            for f in range(n_fcs)
        ]
        fc, t0 = _fc_select(
            avail, [int(t.dist[f, tx["node"]]) for f in range(n_fcs)], tcand
        )
        src = int(t.fc_node[fc])
        min_hops = int(t.dist[fc, tx["node"]])
        cmd_pkt = self._cmd_ticks(min_hops)
        en_cmd = is_read and not hold
        s_cmd = _sched_gap(fcs, fc, t0, cmd_pkt, en_cmd)
        ready_r = s_cmd + cmd_pkt + tx["op"]
        t_nonread = max(t0, _avail1(chips, tx["node"], t0, d_est))
        t_read = max(ready_r, _avail1(fcs, fc, ready_r, d_est),
                     _avail1(chips, tx["node"], ready_r, d_est))
        t_xfer_req = t_read if is_read else t_nonread
        t_scout = t0 if hold else t_xfer_req
        sres, t_resv, rng_new, tries = self._scout_until_success(
            links, src, tx["node"], t_scout, int(rng), d_est
        )
        hops_o = sres.hops
        rtt = _ceil_div((sres.steps + hops_o) * self.scout_hop_ns, TICK_NS)
        start = t_resv + rtt
        cmd_v = self._cmd_ticks(hops_o)
        xfer_v = self._xfer_ticks(tx["nbytes"], hops_o)
        dur_p = xfer_v if is_read else cmd_v + xfer_v
        end_p = start + dur_p
        done_p = end_p if is_read else end_p + tx["op"]
        wait_p = (s_cmd - t0) + (start - t_xfer_req)
        done_r_h = start + cmd_v + tx["op"] + xfer_v
        data_end_w = start + cmd_v + xfer_v
        circuit_end = done_r_h if is_read else data_end_w
        done_h = done_r_h if is_read else data_end_w + tx["op"]
        commit_end = circuit_end if hold else end_p
        done = done_h if hold else done_p
        wait = (start - t0) if hold else wait_p
        fail = not sres.success
        if fail:
            done = tcand + _FAIL
            wait = _FAIL
        else:
            for lnk in sres.path_links:
                _commit1(links, int(lnk), t_resv, commit_end, True)
            _commit1(fcs, fc, t_resv, commit_end, True)
            _commit1(chips, tx["node"], t_resv, commit_end, True)
        plane_free[tx["plane"]] = done
        state = (plane_free, links, fcs, chips,
                 np.uint32(rng_new))
        return state, dict(
            completion=done, wait=wait, conflict=(tries > 1) or fail,
            hops=hops_o, tries=tries,
            scout_steps=sres.steps, misroutes=sres.misroutes,
            bus_hold=0,
            link_hold=0 if fail else hops_o * (commit_end - t_resv),
            failed=fail,
        )

    # -- drive a packed transaction batch ----------------------------------
    def run(self, packed, state=None):
        """Scan ``packed`` (a numpy ``sim.TxnArrays``, natural length)
        through the scalar step; returns ``(state, outs)`` with ``outs`` a
        dict of numpy arrays in scan order."""
        if state is None:
            state = self.initial_state(0)
        n = len(np.asarray(packed.arrival))
        keys = ("completion", "wait", "conflict", "hops", "tries",
                "scout_steps", "misroutes", "bus_hold", "link_hold",
                "failed")
        outs = {k: [] for k in keys}
        for j in range(n):
            tx = dict(
                arrival=int(packed.arrival[j]), kind=int(packed.kind[j]),
                plane=int(packed.plane[j]), node=int(packed.node[j]),
                nbytes=int(packed.nbytes[j]), op=int(packed.op_ticks[j]),
            )
            if self.scout:
                state, o = self._scout_txn(state, tx)
            else:
                o = self._static_txn(state, tx)
            for k in keys:
                outs[k].append(o[k])
        dt = dict(conflict=bool, failed=bool)
        return state, {k: np.asarray(v, dt.get(k, np.int64))
                       for k, v in outs.items()}


def simulate_ref(cfg: SSDConfig, txns, design: str, seed: int = 0,
                 faults=None):
    """Scalar-reference run of one design lane: nominal-orders and packs
    with the production host-side helpers (they are not part of the jitted
    scan), then scans with :class:`LaneRef`.  Returns the outs dict in
    scan order — element-wise comparable to ``sim.simulate``'s per-txn
    arrays."""
    order = S._nominal_order(cfg, txns)
    packed, _op = S._pack_txns(cfg, txns, order, faults)
    lane = LaneRef(cfg, design, faults)
    # the planner forces odd scout seeds (sweep_plan: ``seeds[i] | 1``)
    _, outs = lane.run(packed, lane.initial_state(seed | 1))
    return outs
