"""Declarative design registry: every interconnect design lowers to tables.

This module is the table-driven substrate behind ``repro.ssd.sim``.  A
:class:`DesignSpec` describes one interconnect design (shared-bus groups,
link tables, routing mode, bandwidth multipliers, scout parameters) and
:func:`lower_designs` lowers any set of specs into one *common padded array
layout* (:class:`LaneTables`) consumed by the simulator's single jitted scan
step.  Because every design is data — not code — the whole design space runs
as one batched (vmapped) program sharing one compiled executable, and adding
a design is a ~20-line spec here instead of simulator surgery.

Unified resource space
  Every time-shared resource lives in one padded vector of length ``R_pad``:

      [ 0, L_pad )                 links   (mesh links / shared buses)
      [ L_pad, L_pad+F_pad )       flash controllers
      [ L_pad+F_pad, R_pad )       chip I/O interfaces

  A design's route is a boolean *combined mask* over this vector: a shared
  bus is a 1-link "mesh" with routing disabled (its mask holds exactly one
  link bit), pnSSD's two bus paths are two candidate masks, NoSSD's XY path
  is a multi-link mask, and Venice's mask is produced at runtime by the
  Algorithm-1 scout.  Degenerate designs disable routing by scouting a
  zero-length path (``dst == src``).

Timing tables
  Transfer time is one rational formula per design,
  ``ns = ceil(nbytes * xfer_num / xfer_den) + hops * hop_ns`` (then ticks =
  ceil(ns / TICK_NS)), which reproduces both the shared-channel rate
  (xfer_num/xfer_den = 1000 / round(GB/s * 1000), hop_ns = 0) and the mesh
  Eq. (1) link rate (1 B/ns, +1 ns pipeline fill per hop).

Ablations (each documented next to its spec in ``REGISTRY``):
  venice_minimal  Algorithm 1 restricted to minimal-adaptive routing — no
                  misroutes; isolates the value of non-minimal adaptivity.
  venice_hold     the circuit is reserved across CMD + tR + transfer instead
                  of per transfer phase — quantifies wasted link-hours.
  venice_kscout   beyond-paper: 3 scouts race per reservation and the
                  fewest-hop success is committed — shorter circuits hold
                  fewer link-hours (paper fn. 3 hints at resend policies).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.topology import MeshTopology, all_xy_paths, build_mesh
from repro.ssd.config import SSDConfig, TICK_NS

_BIG = np.int32(2**30)

KIND_BUS = "bus"
KIND_PNSSD = "pnssd"
KIND_NOSSD = "nossd"
KIND_SCOUT = "scout"
_KINDS = (KIND_BUS, KIND_PNSSD, KIND_NOSSD, KIND_SCOUT)


@dataclasses.dataclass(frozen=True)
class DesignSpec:
    """One interconnect design, declaratively.

    ``kind`` selects the lowering recipe (how the tables are built); all
    runtime behaviour differences between designs of the same kind are pure
    data in :class:`LaneTables`.
    """

    name: str
    kind: str  # one of _KINDS
    doc: str = ""
    # --- bus designs ---
    chan: str = "row"  # "row": one bus per channel; "node": private per chip
    bw_mult: float = 1.0  # channel bandwidth multiplier (pSSD: 2x)
    bus_ovh: bool = False  # pays cfg.t_bus_ovh per bus phase (legacy ONFI)
    # --- scout (Venice) designs ---
    allow_nonminimal: bool = True  # Algorithm-1 misrouting enabled
    hold_during_op: bool = False  # keep one circuit across CMD+tR+transfer
    n_scouts: int = 1  # scouts raced per reservation (k-scout ablation)
    d_est_hops: int = 0  # hop margin in the availability-estimate duration
    d_est_pad: int = 0  # constant tick margin in the estimate

    def __post_init__(self) -> None:
        if self.kind not in _KINDS:
            raise ValueError(f"unknown design kind {self.kind!r}")
        if self.n_scouts < 1:
            raise ValueError("n_scouts must be >= 1")

    @property
    def uses_mesh(self) -> bool:
        """Mesh-routed designs carry per-node routers (energy accounting)."""
        return self.kind in (KIND_NOSSD, KIND_SCOUT)

    @property
    def fc_nearest(self) -> bool:
        """Nearest-available FC selection (§4.2) vs fixed FC-per-channel."""
        return self.kind in (KIND_NOSSD, KIND_SCOUT)

    @property
    def counts_bus_energy(self) -> bool:
        """Occupancy billed as shared-bus hold (vs per-link hold)."""
        return self.kind in (KIND_BUS, KIND_PNSSD)

    def n_routers(self, topo: MeshTopology) -> int:
        return topo.n_nodes if self.uses_mesh else 0


REGISTRY: dict[str, DesignSpec] = {
    s.name: s
    for s in (
        DesignSpec(
            name="baseline", kind=KIND_BUS, chan="row", bus_ovh=True,
            doc="Multi-channel shared ONFI bus (Table 1): one bus per "
                "channel, per-phase protocol overhead.",
        ),
        DesignSpec(
            name="pssd", kind=KIND_BUS, chan="row", bw_mult=2.0,
            doc="Kim+ [15] pSSD: packetized channel (no ONFI overhead) at "
                "2x bandwidth.",
        ),
        DesignSpec(
            name="pnssd", kind=KIND_PNSSD,
            doc="Kim+ [15] pnSSD: row+column shared buses — two candidate "
                "paths per chip, FC i drives row bus i and column bus i.",
        ),
        DesignSpec(
            name="nossd", kind=KIND_NOSSD, d_est_hops=6,
            doc="Tavakkol+ [38] NoSSD: packet-switched 2D mesh, "
                "deterministic XY routing, nearest-available FC.",
        ),
        DesignSpec(
            name="venice", kind=KIND_SCOUT, d_est_hops=48, d_est_pad=16,
            doc="The paper (§4): per-transfer path reservation via "
                "Algorithm-1 scouts, non-minimal fully-adaptive.",
        ),
        DesignSpec(
            name="venice_minimal", kind=KIND_SCOUT, allow_nonminimal=False,
            d_est_hops=48, d_est_pad=16,
            doc="Ablation: Venice with minimal-only adaptive routing (no "
                "misroutes) — isolates non-minimal adaptivity's value.",
        ),
        DesignSpec(
            name="venice_hold", kind=KIND_SCOUT, hold_during_op=True,
            d_est_hops=48, d_est_pad=16,
            doc="Ablation: one circuit held across CMD + flash op + "
                "transfer — quantifies the link-hours the paper's "
                "per-transfer reservation recovers.",
        ),
        DesignSpec(
            name="venice_kscout", kind=KIND_SCOUT, n_scouts=3,
            d_est_hops=48, d_est_pad=16,
            doc="Beyond-paper k-scout: race 3 scouts with independent "
                "tie-break streams, commit the fewest-hop success.",
        ),
        DesignSpec(
            name="ideal", kind=KIND_BUS, chan="node", bus_ovh=True,
            doc="Path-conflict-free ideal: a private channel per chip "
                "(same ONFI protocol as baseline, just never shared).",
        ),
    )
}

DESIGNS = tuple(REGISTRY)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Hardware faults injected into a lowered design (ISSUE 8).

    All faults are named in the *mesh* frame (link ids from
    :func:`repro.core.topology.build_mesh`, router = mesh node, FC = channel
    row) and each design's lowering maps them onto its own resource
    structure — a dead horizontal link in row ``r`` kills the whole shared
    bus ``r`` for bus designs but only one hop for mesh designs, which is
    the degraded-mode asymmetry the fault model exists to measure.

    Read-retry (``retry_*``) models the chip-level latency tail of marginal
    NAND reads: each read on an afflicted chip independently retries with
    probability ``retry_prob`` per ladder rung, adding the rung's ticks.
    It is applied host-side to transaction op times (deterministic per
    ``retry_seed``) so every design sees the identical extended reads.

    An all-default (empty) FaultSpec lowers to all-False masks and is
    bit-identical to the fault-free path by construction.
    """

    failed_links: tuple = ()    # mesh link ids
    failed_routers: tuple = ()  # mesh node ids — every port of the node dies
    failed_fcs: tuple = ()      # flash-controller ids (channel rows)
    retry_chips: tuple = ()     # chip/node ids with read-retry; () = none
    retry_prob: float = 0.0     # per-rung retry probability for reads
    retry_ladder: tuple = ()    # extra ticks per successive retry rung
    retry_seed: int = 0         # deterministic retry draw stream

    def __post_init__(self) -> None:
        for f in ("failed_links", "failed_routers", "failed_fcs",
                  "retry_chips"):
            object.__setattr__(
                self, f, tuple(sorted({int(x) for x in getattr(self, f)}))
            )
        object.__setattr__(
            self, "retry_ladder", tuple(int(x) for x in self.retry_ladder)
        )
        if not (0.0 <= self.retry_prob <= 1.0):
            raise ValueError(f"retry_prob must be in [0,1], got {self.retry_prob}")
        if any(t < 0 for t in self.retry_ladder):
            raise ValueError("retry_ladder ticks must be >= 0")

    @property
    def hw_faulty(self) -> bool:
        return bool(self.failed_links or self.failed_routers or self.failed_fcs)

    @property
    def retry_active(self) -> bool:
        return self.retry_prob > 0.0 and bool(self.retry_ladder)

    def __bool__(self) -> bool:
        return self.hw_faulty or self.retry_active

    def dead_sets(self, topo: MeshTopology) -> tuple[set, set]:
        """(dead mesh link ids, dead FC ids) — routers expand to their ports."""
        for l in self.failed_links:
            if not 0 <= l < topo.n_links:
                raise ValueError(f"failed link {l} out of range [0,{topo.n_links})")
        for n in self.failed_routers:
            if not 0 <= n < topo.n_nodes:
                raise ValueError(f"failed router {n} out of range [0,{topo.n_nodes})")
        for f in self.failed_fcs:
            if not 0 <= f < topo.rows:
                raise ValueError(f"failed FC {f} out of range [0,{topo.rows})")
        dead_links = set(self.failed_links)
        for n in self.failed_routers:
            dead_links.update(
                int(l) for l in topo.port_link[n] if l >= 0
            )
        return dead_links, set(self.failed_fcs)


NO_FAULTS = FaultSpec()


def static_design_names(names: Sequence[str] = DESIGNS) -> tuple:
    """The statically-routed designs among ``names`` — every design whose
    lane the batched runner (and its Pallas lane kernel) can serve; the
    complement is the scout-routed set, which needs the DFS scan."""
    return tuple(n for n in names if REGISTRY[n].kind != KIND_SCOUT)


class SweepLayout(NamedTuple):
    """Static padded sizes of the unified resource space for one config."""

    rows: int
    cols: int
    n_nodes: int
    n_links: int  # mesh links of the underlying topology
    L_pad: int  # link section width (covers every design's link count)
    F_pad: int  # flash-controller section width
    R_pad: int  # total combined resource vector width


def sweep_layout_geom(rows: int, cols: int) -> SweepLayout:
    topo = build_mesh(rows, cols)
    L_pad = max(topo.n_links, topo.n_nodes, rows + cols, 1)
    F_pad = max(rows, cols)
    return SweepLayout(
        rows=rows,
        cols=cols,
        n_nodes=topo.n_nodes,
        n_links=topo.n_links,
        L_pad=L_pad,
        F_pad=F_pad,
        R_pad=L_pad + F_pad + topo.n_nodes,
    )


def sweep_layout(cfg: SSDConfig) -> SweepLayout:
    return sweep_layout_geom(cfg.rows, cfg.cols)


class LaneTables(NamedTuple):
    """Per-design tables, stacked on a leading design axis.

    The simulator vmaps its scan over this axis: one compiled executable
    serves every lane.  All shapes depend only on the config, never on the
    design set, so different sweeps over the same config share the compile.
    """

    # --- scalars [D] ---
    is_scout: jnp.ndarray  # bool — route via Algorithm-1 scout
    fc_nearest: jnp.ndarray  # bool — nearest-available FC selection (§4.2)
    ovh: jnp.ndarray  # int32 — per-bus-phase protocol overhead (ticks)
    cmd_base_ns: jnp.ndarray  # int32 — command packet ns before hop term
    xfer_num: jnp.ndarray  # int32 — transfer ns = ceil(B*num/den) + hops*hop_ns
    xfer_den: jnp.ndarray  # int32
    hop_ns: jnp.ndarray  # int32 — per-hop ns (0 for buses)
    allow_nonmin: jnp.ndarray  # bool — scout may misroute
    hold: jnp.ndarray  # bool — venice_hold circuit policy
    n_scouts: jnp.ndarray  # int32 — scouts raced per reservation
    d_est_hops: jnp.ndarray  # int32 — availability-estimate hop margin
    d_est_pad: jnp.ndarray  # int32 — availability-estimate tick margin
    count_bus: jnp.ndarray  # bool — bill occupancy as bus-hold
    # --- tables ---
    cmask: jnp.ndarray  # bool [D, F_pad, n_nodes, 2, R_pad] combined masks
    hops: jnp.ndarray  # int32 [D, F_pad, n_nodes, 2]
    cand2_ok: jnp.ndarray  # bool [D, n_nodes] — second candidate path valid
    fc_fixed: jnp.ndarray  # int32 [D, n_nodes, 2] — fixed FC per candidate
    dist: jnp.ndarray  # int32 [D, F_pad, n_nodes] — FC->chip distance
    fc_valid: jnp.ndarray  # bool [D, F_pad]
    fc_node: jnp.ndarray  # int32 [D, F_pad] — mesh injection node per FC
    res_dead: jnp.ndarray  # bool [D, R_pad] — failed-resource mask (ISSUE 8)


def _fault_mask(topo: MeshTopology, lay: SweepLayout, spec: DesignSpec,
                faults: FaultSpec | None) -> tuple[np.ndarray, set]:
    """Lower mesh-frame faults onto one design's resource vector.

    Returns ``(res_dead [R_pad] bool, dead_fcs)``.  Shared-bus designs
    inherit a fault anywhere on the structure the bus replaces: a dead
    horizontal link in row ``r`` (or FC ``r``) kills bus ``r`` outright,
    which is exactly the "one fault strands the channel" cliff Venice's
    path diversity avoids.  Vertical links / routers have no bus analogue
    (chan="row" buses have neither) and are ignored there.
    """
    res_dead = np.zeros((lay.R_pad,), dtype=bool)
    if faults is None or not faults.hw_faulty:
        return res_dead, set()
    dead_links, dead_fcs = faults.dead_sets(topo)
    rows, cols = lay.rows, lay.cols
    n_h = rows * (cols - 1)  # horizontal link ids precede vertical (topology)
    if spec.kind == KIND_BUS and spec.chan == "row":
        for l in dead_links:
            if l < n_h:  # horizontal link in row r => shared bus r dead
                res_dead[l // max(cols - 1, 1)] = True
        for f in dead_fcs:
            res_dead[f] = True  # FC f drives bus f
    elif spec.kind == KIND_BUS:  # chan == "node": private channel per chip
        for l in dead_links:
            for n in topo.link_endpoints[l]:
                res_dead[int(n)] = True
        for f in dead_fcs:  # FC f serves row f's private channels
            res_dead[f * cols:(f + 1) * cols] = True
    elif spec.kind == KIND_PNSSD:
        for l in dead_links:
            if l < n_h:
                res_dead[l // max(cols - 1, 1)] = True  # row bus
            else:
                res_dead[rows + (l - n_h) // max(rows - 1, 1)] = True  # col bus
        for f in dead_fcs:
            res_dead[lay.L_pad + f] = True
    else:  # mesh kinds (nossd / scout): faults map 1:1
        for l in dead_links:
            res_dead[l] = True
        for f in dead_fcs:
            res_dead[lay.L_pad + f] = True
    return res_dead, dead_fcs


def _lower_one(cfg: SSDConfig, topo: MeshTopology, lay: SweepLayout,
               spec: DesignSpec, faults: FaultSpec | None = None) -> dict:
    """Lower one spec to numpy tables in the unified padded layout."""
    rows, cols, N = lay.rows, lay.cols, lay.n_nodes
    L0, F0, R = lay.L_pad, lay.F_pad, lay.R_pad
    node_row = np.arange(N) // cols
    node_col = np.arange(N) % cols

    cmask = np.zeros((F0, N, 2, R), dtype=bool)
    hops = np.zeros((F0, N, 2), dtype=np.int32)
    cand2_ok = np.zeros((N,), dtype=bool)
    fc_fixed = np.zeros((N, 2), dtype=np.int32)
    dist = np.full((F0, N), _BIG, dtype=np.int32)
    fc_valid = np.zeros((F0,), dtype=bool)
    fc_valid[:rows] = True
    fc_node = np.zeros((F0,), dtype=np.int32)
    fc_node[:rows] = topo.fc_node

    # mesh manhattan distance from each FC's injection node (f, 0)
    mesh_dist = (
        np.abs(np.arange(rows)[:, None] - node_row[None, :]) + node_col[None, :]
    ).astype(np.int32)

    if spec.kind == KIND_BUS:
        link = node_row if spec.chan == "row" else np.arange(N)
        for n in range(N):
            cmask[:, n, :, link[n]] = True
        fc_fixed[:, 0] = fc_fixed[:, 1] = node_row
        dist[:rows] = 0
    elif spec.kind == KIND_PNSSD:
        # candidate 0: the chip's row bus, driven by FC row; candidate 1:
        # its column bus (ids rows..rows+cols-1), driven by FC col.  Both
        # candidates additionally occupy the chip's single I/O interface and
        # the owning FC (pnSSD adds path diversity, not transfer engines).
        for n in range(N):
            r, c = node_row[n], node_col[n]
            for cand, (lnk, fc) in enumerate(((r, r), (rows + c, c))):
                cmask[:, n, cand, lnk] = True
                cmask[:, n, cand, L0 + fc] = True
                cmask[:, n, cand, L0 + F0 + n] = True
            fc_fixed[n] = (r, c)
        cand2_ok[:] = True
        dist[:rows] = 0
    elif spec.kind == KIND_NOSSD:
        paths_np, hops_np = all_xy_paths(topo)
        for f in range(rows):
            for n in range(N):
                lk = paths_np[f, n]
                cmask[f, n, :, lk[lk >= 0]] = True
                cmask[f, n, :, L0 + f] = True
                cmask[f, n, :, L0 + F0 + n] = True
                hops[f, n] = hops_np[f, n]
        dist[:rows] = hops_np  # XY hops == manhattan distance
    else:  # KIND_SCOUT — route masks come from the scout at runtime
        dist[:rows] = mesh_dist

    res_dead, dead_fcs = _fault_mask(topo, lay, spec, faults)
    if spec.fc_nearest:
        # nearest-available FC selection must never pick a dead controller
        for f in dead_fcs:
            fc_valid[f] = False

    if spec.kind in (KIND_BUS, KIND_PNSSD):
        mult = spec.bw_mult
        xfer_num, xfer_den = 1000, int(round(cfg.chan_gbps * mult * 1000))
        hop_ns = 0
        cmd_base_ns = cfg.t_cmd * TICK_NS  # lowers back to exactly t_cmd ticks
        ovh = cfg.t_bus_ovh if spec.bus_ovh else 0
    else:
        xfer_num, xfer_den = 1, 1  # Eq. (1): 8-bit links at 1 GHz = 1 B/ns
        hop_ns = 1
        cmd_base_ns = 8  # 8-byte command packet
        ovh = 0

    return dict(
        is_scout=spec.kind == KIND_SCOUT,
        fc_nearest=spec.fc_nearest,
        ovh=np.int32(ovh),
        cmd_base_ns=np.int32(cmd_base_ns),
        xfer_num=np.int32(xfer_num),
        xfer_den=np.int32(xfer_den),
        hop_ns=np.int32(hop_ns),
        allow_nonmin=spec.allow_nonminimal,
        hold=spec.hold_during_op,
        n_scouts=np.int32(spec.n_scouts),
        d_est_hops=np.int32(spec.d_est_hops),
        d_est_pad=np.int32(spec.d_est_pad),
        count_bus=spec.counts_bus_energy,
        cmask=cmask,
        hops=hops,
        cand2_ok=cand2_ok,
        fc_fixed=fc_fixed,
        dist=dist,
        fc_valid=fc_valid,
        fc_node=fc_node,
        res_dead=res_dead,
    )


@functools.lru_cache(maxsize=None)
def lower_designs(cfg: SSDConfig, names: tuple,
                  faults: FaultSpec | None = None) -> LaneTables:
    """Lower ``names`` (design names, in order) into stacked LaneTables.

    ``faults`` (hashable, part of the memo key) lowers hardware faults into
    per-design ``res_dead`` availability masks; ``None`` (and any empty
    FaultSpec) produces all-False masks — the fault-free tables are
    bit-identical to the pre-fault-model lowering.
    """
    for d in names:
        if d not in REGISTRY:
            raise ValueError(f"unknown design {d!r}; one of {DESIGNS}")
    topo = build_mesh(cfg.rows, cfg.cols)
    lay = sweep_layout(cfg)
    lowered = [_lower_one(cfg, topo, lay, REGISTRY[d], faults) for d in names]
    stacked = {
        k: jnp.asarray(np.stack([low[k] for low in lowered]))
        for k in lowered[0]
    }
    return LaneTables(**stacked)


def resolve_specs(designs: Sequence[str]) -> tuple:
    """Validate design names and return their specs (same order)."""
    try:
        return tuple(REGISTRY[d] for d in designs)
    except KeyError as e:
        raise ValueError(f"unknown design {e.args[0]!r}; one of {DESIGNS}")


# ---------------------------------------------------------------------------
# per-transaction pre-gathered tables (batched small-lane runner)
#
# The node-indexed tables (cmask/hops/dist/cand2_ok/fc_fixed) are static
# data, and a lane's transaction stream is known before the scan — so the
# batched runner never gathers them at runtime: every node lookup is
# resolved HERE, host-side, into per-transaction arrays that ride the scan
# as sliced inputs.  Only state-dependent lookups (plane free-at, live FC
# selection) remain in the step, as one-hot compare-and-reduce
# (``repro.kernels.onehot``).  Candidate masks are bit-packed along the
# resource axis (uint8, little-endian) to keep the [n, F_pad, 2, R] blow-up
# at R/8 bytes; the step unpacks them with shifts (no gather either).
# ---------------------------------------------------------------------------


def pregather_node_tables(tables_row, nodes: np.ndarray) -> dict:
    """Resolve one lane's node-indexed tables per transaction.

    ``tables_row``: one design's view of :class:`LaneTables` (no lane
    axis); ``nodes``: int array [n] of the lane's transaction nodes.
    Returns numpy arrays (lane-major, length n; the planner stacks them
    time-major per batch):
      ``mask_words`` uint8 [n, F_pad, 2, ceil(R_pad/8)], ``hops`` int32
      [n, F_pad, 2], ``dist`` int32 [n, F_pad], ``cand2`` bool [n],
      ``fc_fixed`` int32 [n, 2].
    """
    cmask = np.asarray(tables_row.cmask)  # [F0, N, 2, R]
    packed = np.packbits(cmask, axis=-1, bitorder="little")
    return dict(
        mask_words=np.ascontiguousarray(packed.transpose(1, 0, 2, 3)[nodes]),
        hops=np.ascontiguousarray(
            np.asarray(tables_row.hops).transpose(1, 0, 2)[nodes]
        ),
        dist=np.ascontiguousarray(np.asarray(tables_row.dist).T[nodes]),
        cand2=np.ascontiguousarray(np.asarray(tables_row.cand2_ok)[nodes]),
        fc_fixed=np.ascontiguousarray(
            np.asarray(tables_row.fc_fixed)[nodes]
        ),
    )


def pregather_scout_tables(tables_row, nodes: np.ndarray) -> dict:
    """Resolve one SCOUT lane's node-indexed tables per transaction.

    The scout step's only node-indexed design table is ``dist`` (FC
    selection + the command-packet hop estimate); the path itself is found
    at runtime by the DFS, so there are no candidate masks to pre-gather.
    Returns ``dist`` int32 [n, F_pad] (same layout contract as
    :func:`pregather_node_tables`).
    """
    return dict(
        dist=np.ascontiguousarray(np.asarray(tables_row.dist).T[nodes]),
    )


# ---------------------------------------------------------------------------
# channel-decomposition proof obligation
#
# The simulator may partition a lane's transactions by channel row and scan
# the rows as parallel lanes (cutting sequential scan length from N to
# ~N/rows) ONLY if the lane provably never couples state across rows.  That
# is a property of the lowered tables, so it is verified here, at lowering
# time, not assumed per design name: a lane qualifies iff its FC choice is
# static (nearest-available selection reads every FC's live state) and every
# resource its candidate masks can touch is touched by nodes of one row only.
# baseline/pssd/ideal pass (their bus is private to a row or a chip); pnssd
# fails (a column bus is shared by every row), nossd fails (dynamic FC +
# XY paths cross rows), and scout lanes fail by construction (the scout
# walks the global mesh).  Callers fall back to the flat scan on False.
# ---------------------------------------------------------------------------


def _mask_row_confined(lay: SweepLayout, low: dict) -> bool:
    """Proof check for one lowered lane (see block comment above)."""
    if bool(low["is_scout"]) or bool(low["fc_nearest"]):
        return False
    cmask = np.asarray(low["cmask"])
    fc_fixed = np.asarray(low["fc_fixed"])
    cand2_ok = np.asarray(low["cand2_ok"])
    owner = np.full((lay.R_pad,), -1, dtype=np.int64)
    for n in range(lay.n_nodes):
        r = n // lay.cols
        for cand in (0, 1):
            # an invalid second candidate is evaluated but value-dead
            # (``useA`` is forced), so only reachable masks are checked
            if cand == 1 and not cand2_ok[n]:
                continue
            used = np.flatnonzero(cmask[fc_fixed[n, cand], n, cand])
            clash = (owner[used] != -1) & (owner[used] != r)
            if clash.any():
                return False
            owner[used] = r
    return True


@functools.lru_cache(maxsize=None)
def rows_confined(cfg: SSDConfig, names: tuple) -> tuple:
    """Per-lane bool: may this lane's scan be decomposed by channel row?"""
    topo = build_mesh(cfg.rows, cfg.cols)
    lay = sweep_layout(cfg)
    return tuple(
        _mask_row_confined(lay, _lower_one(cfg, topo, lay, REGISTRY[d]))
        for d in names
    )
