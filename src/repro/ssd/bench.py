"""Benchmark harness: trace → FTL → per-design simulation → paper metrics.

Methodology note (documented in DESIGN.md / EXPERIMENTS.md): the paper replays
week-long enterprise traces whose *bursts* saturate the device even though the
Table-2 mean inter-arrival times look sparse.  Our synthetic traces match the
Table-2 statistics exactly; to reproduce the paper's saturation regime we use
*accelerated replay* (standard MQSim-style methodology): arrivals are scaled
so the offered load reaches ``target_util`` of the baseline's aggregate
channel bandwidth (never decelerated).  Table-2 statistics are validated on
the unscaled traces in the test suite; fig-13 conflict rates and fig-9/10
speedup magnitudes are validated on the accelerated replays.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable

import numpy as np

from repro.ssd.config import SSDConfig
from repro.ssd.ftl import decompose_trace
from repro.ssd.sim import SimResult, simulate_sweep
from repro.traces.generator import default_n_requests, to_pages, trace_for


@dataclasses.dataclass
class WorkloadRun:
    name: str
    cfg: SSDConfig
    accel: float
    n_requests: int
    results: Dict[str, SimResult]

    def speedup(self, design: str, base: str = "baseline") -> float:
        return self.results[base].exec_s / self.results[design].exec_s

    def iops_norm(self, design: str, base: str = "ideal") -> float:
        return self.results[design].iops() / self.results[base].iops()


def offered_utilization(trace, cfg: SSDConfig) -> float:
    """Offered load as a fraction of aggregate shared-channel bandwidth."""
    span_us = float(trace["arrival_us"][-1] - trace["arrival_us"][0])
    tot_bytes = float(np.sum(trace["size_bytes"]))
    bw_bytes_per_us = cfg.chan_gbps * 1e3 * cfg.rows  # GB/s == KB/ms == B/us*1e3
    return tot_bytes / max(span_us, 1e-9) / bw_bytes_per_us


def accelerate(trace, cfg: SSDConfig, target_util: float = 1.5) -> tuple:
    """Scale arrivals to reach ``target_util`` offered load (never slow down)."""
    u = offered_utilization(trace, cfg)
    factor = max(1.0, target_util / max(u, 1e-9))
    if factor > 1.0:
        trace = dict(trace)
        trace["arrival_us"] = trace["arrival_us"] / factor
    return trace, factor


# Completed runs, keyed by every input that affects the result.  Benchmark
# presets revisit the same (workload, config) pair across figure phases
# (fig9's runs serve fig10/13/14 and part of fig11); the sweep is
# deterministic, so memoizing whole WorkloadRuns removes that duplicate
# simulation work.  Bounded: evicts oldest beyond _RUN_CACHE_MAX entries.
_RUN_CACHE: dict = {}
_RUN_CACHE_MAX = 24


def _cache_put(key, run) -> None:
    if len(_RUN_CACHE) >= _RUN_CACHE_MAX:
        _RUN_CACHE.pop(next(iter(_RUN_CACHE)))
    _RUN_CACHE[key] = run


def run_workload(
    name: str,
    cfg: SSDConfig,
    designs: Iterable[str] = ("baseline", "pssd", "pnssd", "nossd", "venice", "ideal"),
    n_requests: int | None = None,
    target_util: float | None = 1.5,
    seed: int = 0,
) -> WorkloadRun:
    designs = tuple(designs)
    key = (name, cfg, designs, n_requests, target_util, seed)
    hit = _RUN_CACHE.get(key)
    if hit is not None:
        return hit
    # Sweep lanes are independent (the parity tests assert a lane is
    # bit-identical to its standalone simulation), so a cached run over a
    # SUPERSET of designs serves any subset — e.g. fig15's 8x8 leg reuses
    # fig9's runs even though it drops pnssd.
    for (n2, c2, d2, r2, u2, s2), run in _RUN_CACHE.items():
        if ((n2, c2, r2, u2, s2) == (name, cfg, n_requests, target_util, seed)
                and set(designs) <= set(d2)):
            sub = WorkloadRun(
                name=run.name, cfg=run.cfg, accel=run.accel,
                n_requests=run.n_requests,
                results={d: run.results[d] for d in designs},
            )
            _cache_put(key, sub)
            return sub
    n = n_requests or default_n_requests(name)
    trace = trace_for(name, n, seed)
    accel = 1.0
    if target_util is not None:
        trace, accel = accelerate(trace, cfg, target_util)
    pages = to_pages(trace, cfg.page_bytes)
    txns = decompose_trace(cfg, pages, footprint_pages=int(pages["footprint_pages"]))
    # one batched jitted program per cost class serves every design lane
    results = dict(
        zip(designs, simulate_sweep(cfg, txns, designs, seeds=seed + 7))
    )
    run = WorkloadRun(
        name=name, cfg=cfg, accel=accel, n_requests=txns.n_requests, results=results
    )
    _cache_put(key, run)
    return run


def geomean(xs) -> float:
    xs = np.asarray(list(xs), dtype=np.float64)
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-12)))))
