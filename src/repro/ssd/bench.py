"""Benchmark harness: trace → FTL → per-design simulation → paper metrics.

Methodology note (documented in DESIGN.md / EXPERIMENTS.md): the paper replays
week-long enterprise traces whose *bursts* saturate the device even though the
Table-2 mean inter-arrival times look sparse.  Our synthetic traces match the
Table-2 statistics exactly; to reproduce the paper's saturation regime we use
*accelerated replay* (standard MQSim-style methodology): arrivals are scaled
so the offered load reaches ``target_util`` of the baseline's aggregate
channel bandwidth (never decelerated).  Table-2 statistics are validated on
the unscaled traces in the test suite; fig-13 conflict rates and fig-9/10
speedup magnitudes are validated on the accelerated replays.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Iterable

import numpy as np

from repro.obs.registry import MetricsRegistry, PerfDict
from repro.ssd.config import SSDConfig
from repro.ssd.ftl import Transactions, decompose_trace
from repro.ssd.sim import SimResult


@dataclasses.dataclass
class WorkloadRun:
    name: str
    cfg: SSDConfig
    accel: float
    n_requests: int
    results: Dict[str, SimResult]
    # figure phase that actually paid for this run (None outside the
    # benchmark harness): lets a later phase served from the run cache
    # report WHERE its "free" results came from instead of lying s=0/lanes=0
    origin_phase: str | None = None

    def speedup(self, design: str, base: str = "baseline") -> float:
        return self.results[base].exec_s / self.results[design].exec_s

    def iops_norm(self, design: str, base: str = "ideal") -> float:
        return self.results[design].iops() / self.results[base].iops()


def offered_utilization(trace, cfg: SSDConfig) -> float:
    """Offered load as a fraction of aggregate shared-channel bandwidth."""
    span_us = float(trace["arrival_us"][-1] - trace["arrival_us"][0])
    tot_bytes = float(np.sum(trace["size_bytes"]))
    bw_bytes_per_us = cfg.chan_gbps * 1e3 * cfg.rows  # GB/s == KB/ms == B/us*1e3
    return tot_bytes / max(span_us, 1e-9) / bw_bytes_per_us


def accelerate(trace, cfg: SSDConfig, target_util: float = 1.5) -> tuple:
    """Scale arrivals to reach ``target_util`` offered load (never slow down)."""
    u = offered_utilization(trace, cfg)
    factor = max(1.0, target_util / max(u, 1e-9))
    if factor > 1.0:
        trace = dict(trace)
        trace["arrival_us"] = trace["arrival_us"] / factor
    return trace, factor


def record_accel(name: str, cfg: SSDConfig, factor: float, offered: float,
                 target_util: float | None) -> None:
    """Audit one (possibly) accelerated replay in ``PERF["accel"]`` —
    the scale factor and the offered utilization before/after scaling
    (exported verbatim into BENCH_*.json's ``accel`` key)."""
    PERF["accel"][f"{name}/{cfg.name}"] = {
        "factor": round(factor, 4),
        "offered_util": round(offered, 5),
        "offered_util_replayed": round(offered * factor, 5),
        "target_util": target_util,
    }


# Per-process perf accounting: wall-clock split between the FTL front end
# (trace → transactions) and the jitted sweep, plus cache telemetry and the
# sweep planner's execution counters — lanes dispatched, trimmed-vs-valid
# scan steps, host devices used, and the per-group compile-vs-execute split
# (``groups`` holds one record per dispatched lane group) so every speedup
# in a BENCH_*.json is attributable.  ``benchmarks/run.py`` snapshots these
# around each figure phase.
#
# Declared through the structured metrics registry (ISSUE 9): ``PERF`` is
# a :class:`repro.obs.registry.PerfDict` — still a real dict with exactly
# the historical keys (BENCH_*.json schema unchanged, every ``perf["x"] +=``
# call site untouched) — gaining typed declarations plus
# ``reset()``/``snapshot()``/``delta()`` semantics so scenario engines can
# report per-run counter deltas instead of process-cumulative ones.
METRICS = MetricsRegistry()
METRICS.timer("ftl_s")
METRICS.timer("sim_s")
for _c in ("decomp_hits", "decomp_misses", "run_hits", "run_subset_hits",
           "run_misses", "run_prefetched", "lanes", "scan_steps_valid",
           "scan_steps_padded"):
    METRICS.counter(_c)
METRICS.gauge("devices_used", 0)
METRICS.timer("compile_s")
METRICS.timer("exec_s")
METRICS.object("groups", [])
# warm-path execution backend (DESIGN.md §2.2): persistent-executable
# store telemetry (hits/misses/errors/stores mirrored from
# ``exec_cache.STATS``, plus deserialize wall-clock) and the overlapped
# compile/execute pipeline split — background compile time hidden
# behind execution vs time the dispatcher actually stalled
for _c in ("xc_hits", "xc_misses", "xc_errors", "xc_stores",
           "xc_tombstones"):
    METRICS.counter(_c)
METRICS.timer("xc_load_s")
METRICS.timer("compile_overlap_s")
METRICS.timer("compile_wait_s")
# self-healing compile pipeline (ISSUE 8): compile-server watchdog trips
# (heartbeat loss / straggler / crash — see ``sweep_plan._ServerWatchdog``),
# the reason of the last trip, and how many delegated keys fell back to
# in-process compilation
METRICS.counter("xc_watchdog_trips")
METRICS.gauge("xc_watchdog_reason", None)
METRICS.counter("xc_watchdog_fallbacks")
# streaming engine (repro.ssd.stream): windows replayed and wall-clock
# spent in the overlapped prep stage (decompose + order + pack) — prep
# that hides behind execution shows up here but not in compile_wait_s
METRICS.counter("stream_windows")
METRICS.timer("stream_prep_s")
# kernel-dispatch split (ISSUE 7): per-backend group counts
# ({"xla"|"pallas-interpret"|"pallas-compiled": n}) and how many
# lane-steps ran through the batched static step vs the unbatched
# scan — the backend/batching share surfaced in BENCH_*.json's
# ``kernel_dispatch`` block and the trajectory table.  Scout lanes
# tally separately (ISSUE 10): their batched runner landed three PRs
# after the static one, so the scout split is the figure of merit.
METRICS.object("kernel_backends", {})
METRICS.counter("steps_batched")
METRICS.counter("steps_unbatched")
METRICS.counter("steps_scout_batched")
METRICS.counter("steps_scout_unbatched")
# current figure phase (set by benchmarks/run.py) + per-phase run-cache
# attribution: {phase: {"hits": n, "from": {origin_phase: n}}}
METRICS.gauge("phase", None)
METRICS.object("phase_cache", {})
# per-(workload, config) accelerated-replay audit trail: the
# ``accelerate()`` scale factor and the offered utilization before/after
# scaling (satellite: the factor used to be computed and dropped by
# ``run_workload`` callers, leaving replays unauditable).
METRICS.object("accel", {})
# workload ingestion (ISSUE 9 satellite): rows skipped by
# ``ingest.load_trace(on_error="skip")`` across the process — nonzero
# counts also emit a warning naming the file (see ``workloads/ingest.py``)
METRICS.counter("ingest_skipped_rows")

PERF: PerfDict = METRICS.view()

# The FTL engine the harness decomposes with ("auto" | "vector" | "scalar");
# benchmarks/run.py --ftl-engine flips this for A/B perf runs.
FTL_ENGINE = "auto"

# Completed runs, keyed by every input that affects the result.  Benchmark
# presets revisit the same (workload, config) pair across figure phases
# (fig9's runs serve fig10/13/14 and part of fig11); the sweep is
# deterministic, so memoizing whole WorkloadRuns removes that duplicate
# simulation work.  A true LRU: hits refresh recency (move-to-end — plain
# dicts preserve insertion order), eviction drops the least-recently-used
# entry, and subset hits are served as derived views WITHOUT inserting a
# duplicate entry (a derived copy of data the superset entry already holds
# would push out an unrelated run).
_RUN_CACHE: dict = {}
_RUN_CACHE_MAX = 24

# Decompositions, keyed on (trace content, FTL-relevant geometry): the FTL
# never sees interconnect or timing parameters, so every design lane, every
# figure phase and any config sharing (page size, array geometry, striping
# chunk) reuses one decomposition even when the WorkloadRun cache misses
# (different design sets, evictions).
_DECOMP_CACHE: dict = {}
_DECOMP_CACHE_MAX = 32


def _lru_get(cache: dict, key):
    hit = cache.pop(key, None)
    if hit is not None:
        cache[key] = hit  # re-insert: most-recently-used position
    return hit


def _lru_put(cache: dict, key, val, cap: int) -> None:
    cache.pop(key, None)
    while len(cache) >= cap:
        cache.pop(next(iter(cache)))  # least-recently-used
    cache[key] = val


def clear_caches() -> None:
    """Drop memoized runs/decompositions (tests, memory pressure)."""
    _RUN_CACHE.clear()
    _DECOMP_CACHE.clear()


def ftl_geometry(cfg: SSDConfig) -> tuple:
    """The SSDConfig fields the FTL decomposition depends on — nothing
    else (latencies, interconnect, power) can change the transaction
    stream, so configs differing only there share cache entries."""
    return (cfg.rows, cfg.cols, cfg.dies_per_chip, cfg.planes_per_die,
            cfg.pages_per_block, cfg.page_bytes, cfg.chunk_pages)


def _trace_digest(pages: Dict[str, np.ndarray]) -> bytes:
    h = hashlib.sha1()
    for k in ("arrival_us", "is_read", "offset_page", "n_pages"):
        h.update(np.ascontiguousarray(pages[k]).tobytes())
    if "tenant" in pages:  # attribution rides on the cached Transactions:
        # same arrays + different tags must not share an entry (the tagged
        # and untagged decompositions are bit-identical otherwise)
        h.update(b"tenant")
        h.update(np.ascontiguousarray(pages["tenant"]).tobytes())
    return h.digest()


def decompose_cached(
    cfg: SSDConfig,
    pages: Dict[str, np.ndarray],
    footprint_pages: int,
    overprovision: float = 1.28,
) -> Transactions:
    """``decompose_trace`` behind the content-keyed LRU (read-only result)."""
    key = (_trace_digest(pages), ftl_geometry(cfg), footprint_pages,
           overprovision, FTL_ENGINE)
    hit = _lru_get(_DECOMP_CACHE, key)
    if hit is not None:
        PERF["decomp_hits"] += 1
        return hit
    PERF["decomp_misses"] += 1
    txns = decompose_trace(cfg, pages, footprint_pages=footprint_pages,
                           overprovision=overprovision, engine=FTL_ENGINE)
    _lru_put(_DECOMP_CACHE, key, txns, _DECOMP_CACHE_MAX)
    return txns


def _cached_run(name, cfg, designs, n_requests, target_util, seed,
                count: bool = True) -> WorkloadRun | None:
    """Serve a run from the LRU (exact hit or superset-derived view).

    Sweep lanes are independent (the parity tests assert a lane is
    bit-identical to its standalone simulation), so a cached run over a
    SUPERSET of designs serves any subset — e.g. fig15's 8x8 leg reuses
    fig9's runs even though it drops pnssd.  Served as a derived view
    (refreshing the superset's recency), never cached under its own key.

    ``count=False`` makes this a silent probe (the planner's prefetch
    peeks without distorting the hit/miss telemetry — only the phase
    body's real ``run_workload`` calls are counted).
    """
    key = (name, cfg, designs, n_requests, target_util, seed)
    hit = _lru_get(_RUN_CACHE, key)
    if hit is not None:
        if count:
            PERF["run_hits"] += 1
            _count_phase_hit(hit)
        return hit
    for sup_key, run in list(_RUN_CACHE.items()):
        (n2, c2, d2, r2, u2, s2) = sup_key
        if ((n2, c2, r2, u2, s2) == (name, cfg, n_requests, target_util, seed)
                and set(designs) <= set(d2)):
            _lru_get(_RUN_CACHE, sup_key)
            if count:
                PERF["run_subset_hits"] += 1
                _count_phase_hit(run)
            return WorkloadRun(
                name=run.name, cfg=run.cfg, accel=run.accel,
                n_requests=run.n_requests,
                results={d: run.results[d] for d in designs},
                origin_phase=run.origin_phase,
            )
    return None


def _count_phase_hit(run: WorkloadRun) -> None:
    """Attribute one run-cache hit to the current figure phase, keyed by
    the phase that originally paid for the run — so a fully-cached phase's
    artifact says "served from fig9" instead of pretending it ran nothing."""
    phase = PERF.get("phase")
    if phase is None:
        return
    rec = PERF["phase_cache"].setdefault(phase, {"hits": 0, "from": {}})
    rec["hits"] += 1
    origin = run.origin_phase or "?"
    rec["from"][origin] = rec["from"].get(origin, 0) + 1


def run_workload(
    name: str,
    cfg: SSDConfig,
    designs: Iterable[str] = ("baseline", "pssd", "pnssd", "nossd", "venice", "ideal"),
    n_requests: int | None = None,
    target_util: float | None = 1.5,
    seed: int = 0,
) -> WorkloadRun:
    designs = tuple(designs)
    hit = _cached_run(name, cfg, designs, n_requests, target_util, seed)
    if hit is not None:
        return hit
    PERF["run_misses"] += 1
    # every miss routes through the sweep planner (one-request plan); figure
    # phases batch their whole workload list via ``sweep_plan.prefetch`` so
    # the lanes of many workloads/configs pool into shared sharded groups
    from repro.ssd.sweep_plan import RunRequest, execute_requests

    return execute_requests([
        RunRequest(name, cfg, designs, n_requests, target_util, seed)
    ])[0]


def geomean(xs) -> float:
    xs = np.asarray(list(xs), dtype=np.float64)
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-12)))))
