"""Deferred sweep planner: conflict-free execution of the simulator itself.

The paper's thesis — exploit the parallelism the structure already gives you
by removing path conflicts — applied to the simulator: sweep lanes across
workloads, configs and seeds are fully independent, and within a
statically-routed lane the bus-design resources are disjoint per channel
row.  The planner turns both into wall-clock parallelism while keeping every
result bit-identical to the flat single-lane scan:

Channel decomposition (tentpole 1)
    A statically-routed lane whose lowered masks are *provably row-confined*
    (``designs.rows_confined`` — verified at lowering time, never assumed
    per design name) is split into one lane per channel row, scanning only
    that row's transactions.  Rows touch disjoint resources and disjoint
    planes, so per-resource commit order — and therefore every output — is
    unchanged; sequential scan length drops from N to ~max-row (~N/rows).
    Lanes that fail the proof (pnssd couples rows through its column buses,
    nossd selects FCs dynamically, scouts walk the global mesh) fall back
    to the flat scan.

Planning + multi-core sharding (tentpole 2)
    ``execute_sim_runs`` collects every pending (cfg, txns, designs, seeds)
    run, lowers each to lanes, and pools lanes by (geometry, cost class) —
    perf/cost configs of one geometry share a pool, and the two cost
    classes stay apart because lanes sharing a group's barrier must not
    pay each other's program cost (promotions and the scout ``k_max`` are
    pool-wide).  Pool lanes are sorted by chunk count and cut into
    ``shard_map`` groups of one lane per host CPU device
    (``--xla_force_host_platform_device_count``, set by
    ``benchmarks/run.py`` before jax initializes): the shards of a group
    execute in parallel inside one SPMD program while each lane stays
    UNBATCHED in its shard (vmap-batching lanes measured ~50x slower per
    scout step on CPU — see ``sim._build_group_fn``), and the sorting
    keeps a group's barrier cheap.  Every group of a pool shares one
    executable (tables/seed/txns/chunk-count are arguments).  XLA's thunk
    CPU runtime is disabled for this program shape (~10x per-step, see
    the runtime note in ``sim``).

Trimmed scans
    After grouping, each lane's scan runs only ``ceil(n / CHUNK)`` chunks
    of its capacity bucket (dynamic trip count, ``sim.CHUNK`` = 1024): the
    up-to-4x cond-skipped steps the power-of-4 buckets used to charge are
    gone, and padded-vs-valid step counts are recorded in ``bench.PERF``.

``bench.run_workload`` routes every cache miss through this planner;
``prefetch`` lets a figure phase hand over its whole workload list so one
planning pass serves the phase from the run cache.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.ssd import bench
from repro.ssd import sim as S
from repro.ssd.config import SSDConfig
from repro.ssd.designs import (
    KIND_SCOUT,
    LaneTables,
    lower_designs,
    resolve_specs,
    rows_confined,
)

__all__ = ["RunRequest", "execute_requests", "execute_sim_runs", "prefetch"]

# "auto" channel-decomposes a row-confined lane only when every row is
# expected to span several chunks (n >= rows * this * CHUNK): each row-lane
# pays chunk round-up, so short traces cost more as rows than they save in
# scan depth.  Policy only: decomposed and flat scans are bit-identical.
AUTO_DECOMPOSE_MIN_CHUNKS_PER_ROW = 4

# Capacity high-water mark per geometry signature: a pool reuses the
# largest capacity bucket its geometry has seen so executables keyed on
# capacity are not recompiled for smaller later pools (execute time scales
# with the trimmed chunk count, not the capacity).
_CAP_SEEN: dict = {}


@dataclasses.dataclass(frozen=True)
class RunRequest:
    """One pending ``bench.run_workload`` call, planned for batched
    execution."""

    name: str
    cfg: SSDConfig
    designs: tuple
    n_requests: int | None = None
    target_util: float | None = 1.5
    seed: int = 0


class _Lane:
    """One scan lane: a (run, design[, channel row]) unit of work."""

    __slots__ = ("run_idx", "design_idx", "seed", "tables_row", "txns",
                 "n", "pos", "spec", "out")

    def __init__(self, run_idx, design_idx, seed, tables_row, txns, n, pos,
                 spec):
        self.run_idx = run_idx
        self.design_idx = design_idx
        self.seed = seed
        self.tables_row = tables_row  # LaneTables row, numpy, no lane axis
        self.txns = txns  # TxnArrays, numpy, natural length n
        self.n = n
        self.pos = pos  # positions in the run's ordered space (None = all)
        self.spec = spec
        self.out = None  # StepOut numpy [capacity], filled by _run_pool

    @property
    def n_chunks(self) -> int:
        return -(-self.n // S.CHUNK)  # ceil; 0 chunks for an empty lane


def _want_decompose(flag, spec, confined: bool, cfg: SSDConfig, n: int,
                    rows_ok: bool) -> bool:
    if spec.kind == KIND_SCOUT or not confined or cfg.rows <= 1 or n == 0:
        return False
    if not rows_ok:  # txn row field inconsistent with node layout — safety
        return False
    if flag is True:
        return True
    return (flag == "auto"
            and n >= cfg.rows * AUTO_DECOMPOSE_MIN_CHUNKS_PER_ROW * S.CHUNK)


def _slice_txns(txns: S.TxnArrays, idx: np.ndarray) -> S.TxnArrays:
    return S.TxnArrays(*(a[idx] for a in txns))


def _pad_txns(txns: S.TxnArrays, cap: int) -> S.TxnArrays:
    out = []
    for a in txns:
        b = np.zeros((cap,), dtype=a.dtype)
        b[: len(a)] = a
        out.append(b)
    return S.TxnArrays(*out)


def _pool_promotions(lanes: list) -> tuple:
    """Common value of each promotable scalar across the POOL (not per
    group): every group of the pool must share one executable, so the
    specialization is computed once over all its lanes."""

    class _Stack:
        def __getattr__(self, name):
            return np.stack(
                [np.asarray(getattr(ln.tables_row, name)) for ln in lanes]
            )

    return S._promotions(_Stack())


def _run_pool(sig: tuple, lanes: list, has_scout: bool) -> list:
    """Execute one (geometry, cost class) pool of lanes; fills lane.out.

    Returns the pool's perf records (one entry per dispatched group).
    """
    n_shards = S.host_device_count()
    k_max = (max(ln.spec.n_scouts for ln in lanes) if has_scout else 1)
    fixed = _pool_promotions(lanes)
    cap = max(_CAP_SEEN.get(sig, 0), S._pad_to(max(ln.n for ln in lanes)))
    _CAP_SEEN[sig] = cap

    perf_groups = []
    # one lane per device shard, unbatched inside (sim._build_group_fn);
    # sorting by length keeps the lanes sharing a group's barrier similar
    # in cost.  A pool smaller than the device count compiles at its own
    # size (no duplicate work for e.g. a solo ``simulate`` on a many-core
    # host); only the remainder block of a larger pool is padded with a
    # duplicate lane, where the discarded re-execution is cheaper than a
    # smaller-group executable
    G = max(1, min(n_shards, len(lanes)))
    order = sorted(range(len(lanes)), key=lambda i: lanes[i].n_chunks)
    groups = []
    for i in range(0, len(order), G):
        block = [lanes[j] for j in order[i : i + G]]
        while len(block) % G:
            block.append(block[-1])
        groups.append(block)

    for group in groups:
        tables = LaneTables(
            *(np.stack([np.asarray(getattr(ln.tables_row, f))
                        for ln in group])
              for f in LaneTables._fields)
        )
        seeds = np.asarray([ln.seed for ln in group], np.uint32)
        txns = S.TxnArrays(
            *(np.stack(cols) for cols in
              zip(*(_pad_txns(ln.txns, cap) for ln in group)))
        )
        ncs = np.asarray([ln.n_chunks for ln in group], np.int32)
        outs, perf = S.run_group(sig, tables, seeds, txns, ncs, k_max,
                                 has_scout, fixed, len(group))
        seen = set()
        for j, ln in enumerate(group):
            if id(ln) in seen:  # padding duplicate — outputs discarded
                continue
            seen.add(id(ln))
            ln.out = S.StepOut(*(np.asarray(a)[j] for a in outs))
        # attribute real lanes; "steps" keeps counting the duplicates'
        # re-execution — it is the executed-waste metric
        perf["lanes"] = len(seen)
        perf_groups.append(perf)
    return perf_groups


def execute_sim_runs(runs: Sequence[tuple]) -> list:
    """Execute many sweeps as pooled, sharded lane groups.

    ``runs``: iterable of ``(cfg, txns, designs, seeds, decompose)`` —
    ``seeds`` a per-lane tuple.  Returns per-run lists of
    :class:`~repro.ssd.sim.SimResult`, each bit-identical to
    ``sim.simulate`` of that lane alone.
    """
    runs = list(runs)
    prepared = []  # (cfg, txns, designs, order, op, n)
    pools: dict = {}
    for run_idx, (cfg, txns, designs, seeds, decompose) in enumerate(runs):
        designs = tuple(designs)
        specs = resolve_specs(designs)
        order = S._nominal_order(cfg, txns)
        n = len(order)
        packed, op = S._pack_txns(cfg, txns, order)
        prepared.append((cfg, txns, designs, order, op, n))
        confined = rows_confined(cfg, designs)
        tables = lower_designs(cfg, designs)
        rows_np = np.asarray(packed.row)
        rows_ok = bool(
            np.array_equal(rows_np, np.asarray(packed.node) // cfg.cols)
        )
        row_pos = None
        sig = S._geom_sig(cfg)
        for i, spec in enumerate(specs):
            tables_row = LaneTables(
                *(np.asarray(a)[i] for a in tables)
            )
            seed = seeds[i] | 1
            scout = spec.kind == KIND_SCOUT
            key = (sig, scout)
            dec = _want_decompose(decompose, spec, confined[i], cfg, n,
                                  rows_ok)
            if dec and row_pos is None:
                row_pos = [np.flatnonzero(rows_np == r)
                           for r in range(cfg.rows)]
            lane_list = pools.setdefault(key, [])
            if dec:
                for pos in row_pos:
                    if len(pos) == 0:
                        continue
                    lane_list.append(_Lane(
                        run_idx, i, seed, tables_row,
                        _slice_txns(packed, pos), len(pos), pos, spec,
                    ))
            else:
                lane_list.append(_Lane(
                    run_idx, i, seed, tables_row, packed, n, None, spec,
                ))

    all_groups = []
    for (sig, scout), lanes in pools.items():
        all_groups.extend(_run_pool(sig, lanes, scout))

    # ---- PERF accounting (bench.PERF is the process-wide scoreboard) ----
    perf = bench.PERF
    if all_groups:  # devices actually used, not merely available
        perf["devices_used"] = max(perf.get("devices_used", 0),
                                   max(g["shards"] for g in all_groups))
    for g in all_groups:
        perf["lanes"] = perf.get("lanes", 0) + g["lanes"]
        perf["scan_steps_padded"] = (
            perf.get("scan_steps_padded", 0) + g["steps"]
        )
        perf["compile_s"] = perf.get("compile_s", 0.0) + g["compile_s"]
        perf["exec_s"] = perf.get("exec_s", 0.0) + g["exec_s"]
    perf.setdefault("groups", []).extend(all_groups)

    # ---- merge lanes back into per-run SimResults ----
    results: list = []
    by_run: dict = {}
    for lanes in pools.values():
        for ln in lanes:
            by_run.setdefault((ln.run_idx, ln.design_idx), []).append(ln)
    for run_idx, (cfg, txns, designs, order, op, n) in enumerate(prepared):
        run_res = []
        for i, design in enumerate(designs):
            lanes = by_run[(run_idx, i)]
            perf["scan_steps_valid"] = (
                perf.get("scan_steps_valid", 0) + sum(ln.n for ln in lanes)
            )
            if len(lanes) == 1 and lanes[0].pos is None:
                outs = lanes[0].out
            else:  # channel-decomposed: scatter rows back to ordered space
                outs = S.StepOut(*(
                    np.zeros((n,), dtype=np.asarray(f).dtype)
                    for f in lanes[0].out
                ))
                for ln in lanes:
                    for dst, src in zip(outs, ln.out):
                        dst[ln.pos] = src[: ln.n]
            run_res.append(
                S._finish_result(cfg, design, txns, order, op, outs, n)
            )
        results.append(run_res)
    return results


def _request_key(rq: RunRequest) -> tuple:
    return (rq.name, rq.cfg, rq.designs, rq.n_requests, rq.target_util,
            rq.seed)


def execute_requests(requests: Sequence[RunRequest]) -> list:
    """Trace + decompose + simulate a batch of workload requests as one
    planned execution; results are inserted into ``bench._RUN_CACHE`` under
    the exact keys ``bench.run_workload`` uses."""
    from repro.traces.generator import default_n_requests, to_pages, trace_for

    sims, meta = [], []
    for rq in requests:
        n_req = rq.n_requests or default_n_requests(rq.name)
        trace = trace_for(rq.name, n_req, rq.seed)
        accel = 1.0
        offered = bench.offered_utilization(trace, rq.cfg)
        if rq.target_util is not None:
            trace, accel = bench.accelerate(trace, rq.cfg, rq.target_util)
        bench.record_accel(rq.name, rq.cfg, accel, offered, rq.target_util)
        pages = to_pages(trace, rq.cfg.page_bytes)
        t0 = time.perf_counter()
        txns = bench.decompose_cached(rq.cfg, pages,
                                      int(pages["footprint_pages"]))
        bench.PERF["ftl_s"] += time.perf_counter() - t0
        seeds = ((rq.seed + 7),) * len(rq.designs)
        sims.append((rq.cfg, txns, rq.designs, seeds, "auto"))
        meta.append((accel, txns))
    t0 = time.perf_counter()
    all_results = execute_sim_runs(sims)
    bench.PERF["sim_s"] += time.perf_counter() - t0
    out = []
    # a prefetched phase reads the whole batch back AFTER this returns, so
    # the batch must survive in the LRU together — insert with a cap at
    # least the batch size (later normal-cap inserts shrink the cache back
    # down, so this pins the batch without permanently growing the cap)
    cap = max(bench._RUN_CACHE_MAX, len(requests))
    for rq, (accel, txns), results in zip(requests, meta, all_results):
        run = bench.WorkloadRun(
            name=rq.name, cfg=rq.cfg, accel=accel,
            n_requests=txns.n_requests,
            results=dict(zip(rq.designs, results)),
        )
        bench._lru_put(bench._RUN_CACHE, _request_key(rq), run, cap)
        out.append(run)
    return out


def prefetch(requests: Sequence[RunRequest]) -> None:
    """Plan and execute every not-yet-cached request as one batch.

    A figure phase calls this with its whole (workload, config) list; the
    phase body's ``run_workload`` calls are then all served from the run
    cache, so the phase's sweeps execute as pooled sharded groups instead
    of one eager sweep per workload."""
    pending, seen = [], set()
    for rq in requests:
        key = _request_key(rq)
        if key in seen:
            continue
        seen.add(key)
        # silent probe: planned work is counted as ``run_prefetched`` so
        # the hit/miss telemetry keeps meaning "work avoided/incurred by a
        # run_workload call" (the phase body's hits on prefetched entries
        # are real cache hits — the plan warmed them)
        if bench._cached_run(*key, count=False) is None:
            pending.append(rq)
    if pending:
        bench.PERF["run_prefetched"] += len(pending)
        execute_requests(pending)
