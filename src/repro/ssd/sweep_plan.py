"""Deferred sweep planner: conflict-free execution of the simulator itself.

The paper's thesis — exploit the parallelism the structure already gives you
by removing path conflicts — applied to the simulator: sweep lanes across
workloads, configs and seeds are fully independent, and within a
statically-routed lane the bus-design resources are disjoint per channel
row.  The planner turns both into wall-clock parallelism while keeping every
result bit-identical to the flat single-lane scan:

Channel decomposition (tentpole 1)
    A statically-routed lane whose lowered masks are *provably row-confined*
    (``designs.rows_confined`` — verified at lowering time, never assumed
    per design name) is split into one lane per channel row, scanning only
    that row's transactions.  Rows touch disjoint resources and disjoint
    planes, so per-resource commit order — and therefore every output — is
    unchanged; sequential scan length drops from N to ~max-row (~N/rows).
    Lanes that fail the proof (pnssd couples rows through its column buses,
    nossd selects FCs dynamically, scouts walk the global mesh) fall back
    to the flat scan.

Planning + multi-core sharding (tentpole 2)
    ``execute_sim_runs`` collects every pending (cfg, txns, designs, seeds)
    run, lowers each to lanes, and pools lanes by (geometry, cost class) —
    perf/cost configs of one geometry share a pool, and the two cost
    classes stay apart because lanes sharing a group's barrier must not
    pay each other's program cost (promotions and the scout ``k_max`` are
    pool-wide).  Pool lanes are sorted by chunk count and cut into
    ``shard_map`` groups of one lane per host CPU device
    (``--xla_force_host_platform_device_count``, set by
    ``benchmarks/run.py`` before jax initializes): the shards of a group
    execute in parallel inside one SPMD program while each lane stays
    UNBATCHED in its shard (vmap-batching lanes measured ~50x slower per
    scout step on CPU — see ``sim._build_group_fn``), and the sorting
    keeps a group's barrier cheap.  Every group of a pool shares one
    executable (tables/seed/txns/chunk-count are arguments).  XLA's thunk
    CPU runtime is disabled for this program shape (~10x per-step, see
    the runtime note in ``sim``).

Trimmed scans
    After grouping, each lane's scan runs only ``ceil(n / CHUNK)`` chunks
    of its capacity bucket (dynamic trip count, ``sim.CHUNK`` = 1024): the
    up-to-4x cond-skipped steps the power-of-4 buckets used to charge are
    gone, and padded-vs-valid step counts are recorded in ``bench.PERF``.

``bench.run_workload`` routes every cache miss through this planner;
``prefetch`` lets a figure phase hand over its whole workload list so one
planning pass serves the phase from the run cache.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import os
import threading
import time
from typing import Sequence

import numpy as np

from repro.obs import events as obs_events
from repro.obs import spans as obs_spans
from repro.ssd import bench
from repro.ssd import exec_cache
from repro.ssd import sim as S
from repro.ssd.config import SSDConfig
from repro.ssd.designs import (
    KIND_SCOUT,
    LaneTables,
    lower_designs,
    pregather_node_tables,
    pregather_scout_tables,
    resolve_specs,
    rows_confined,
)

__all__ = ["RunRequest", "execute_requests", "execute_sim_runs", "prefetch",
           "precompile", "prewarm_small_keys"]

# "auto" channel-decomposes a row-confined lane only when every row is
# expected to span several chunks (n >= rows * this * CHUNK): each row-lane
# pays chunk round-up, so short traces cost more as rows than they save in
# scan depth.  Policy only: decomposed and flat scans are bit-identical.
AUTO_DECOMPOSE_MIN_CHUNKS_PER_ROW = 4

# Capacity high-water mark per geometry signature: a pool reuses the
# largest capacity bucket its geometry has seen so executables keyed on
# capacity are not recompiled for smaller later pools (execute time scales
# with the trimmed chunk count, not the capacity).
_CAP_SEEN: dict = {}

# ---- small-lane policy (perf only; every layout is bit-identical) --------
# A lane at or below this many scan chunks counts as "small": small-lane
# pools are dispatch-bound (the QoS tail phase: hundreds of 1-2 chunk
# scans), so the planner collapses them — the measured policy, see
# DESIGN.md §2.2 and the A/B table in EXPERIMENTS.md:
#
#   * a small STATIC set of <= n_shards * _BATCH_MAX_PER_SHARD lanes runs
#     in the gather-free batched runner as ONE dispatch.  The per-shard
#     width cap is the measured fork/join cliff of XLA:CPU's parallel
#     task assigner: at ~[8, R_pad] int32 per op it starts splitting
#     every elementwise op across the intra-op pool, and the per-op
#     fork/join tax (~50-80us/step) dwarfs the batching win.  Below the
#     cliff the batched step runs ~0.5us per lane-step vs ~2.4us
#     unbatched — the PR-3 "50x slower" verdict was a property of the
#     vmap gather/scatter lowering, not of batching;
#   * any larger small-lane set — static or scout — runs as STACK groups:
#     K sequential unbatched lanes per shard (lax.map), one dispatch per
#     n_shards*K lanes, immune to the fork/join cliff.
#
# Above SMALL_LANE_MAX_CHUNKS chunks the flat sharded scan wins (the
# dispatch barrier amortizes, and a 3+-chunk lane is usually served by an
# already-compiled flat executable — pulling it into a small-lane layout
# would BUY a compile to save a dispatch).  0 disables both layouts.
SMALL_LANE_MAX_CHUNKS = int(os.environ.get("REPRO_SMALL_LANE_CHUNKS", "2"))
_BATCH_MIN_LANES = 3  # fewer small lanes than this stay on the flat path
_BATCH_MAX_PER_SHARD = 4  # fork/join cliff (measured; see above)
# Batched-SCOUT small-lane window: same shape as the static window but OFF
# by default — the batched scout runner loses on CPU at every measured
# width (B=4: 131us, B=8: 188us per lane-step vs 11.5us flat on the same
# workload; EXPERIMENTS.md scout A/B table).  Unlike the static step, a
# scout DFS decision is O(1) scalar work flat (four port probes compiled
# to straight-line code) but O(L_pad + 4*N_pad) one-hot vector work per
# lane batched — ~1.8us/lane-decision, linear in B with no amortization —
# and the lockstep retry loop runs max-iterations-over-B, so batching
# multiplies the inflated work by the slowest lane's divergence.  The
# window stays as an opt-in (env below / occupancy profile) for
# accelerator-shaped hosts where the one-hot rows are lane-parallel and
# it is the serial gathers that are catastrophic.
_BSCOUT_MAX_PER_SHARD = int(os.environ.get("REPRO_BSCOUT_PER_SHARD", "0"))
_STACK_MAX_K = 16  # lanes executed sequentially per shard, at most

# ---- planner cost-model weights (ordering heuristics only) ---------------
# Measured, replacing the former 3x-compile / 4x-step guesses (EXPERIMENTS
# "Scout lane layouts", measurement scripts quoted there).  Step weight:
# warm quick-preset group records (bench.PERF) put flat scout lanes at
# ~37.7us/step vs ~3.4us/step static.  Compile weights: cold
# ensure_compiled() wall on the quick preset's 8x8 geometry, cap 1024 —
# lane 1.9s static / 3.4s scout, stack 2.6/4.0, batched 2.8, bscout 5.3.
# Relative weights, not seconds: a mis-estimate only reorders the
# compile/dispatch queues.
_COST_SCOUT_STEP = 11.0  # scout scan step vs static step (37.7 / 3.4)
_COST_SCOUT_COMPILE = 1.7  # scout program compile vs static (3.4 / 1.9)
_COST_MULTILANE_COMPILE = 1.4  # stack/batched compile vs lane (2.6 / 1.9)

# ---- planner backend profile (DESIGN.md §2.2, Pallas lane layouts) -------
# "cpu" is the layout above: one unbatched lane per host core, batching
# only inside the measured small-lane window.  On an accelerator that
# inverts — one device wants thousands of batched lanes, and the CPU
# fork/join cliff does not exist — so the "occupancy" profile pools
# statically-routed lanes by occupancy (lanes x padded scan chunks per
# device, budget below) instead of core count and dispatches them through
# the batched runner (Pallas lane kernel when the lane backend says so).
# "auto" picks occupancy on GPU/TPU and cpu otherwise, which keeps the
# CPU profile — and every figure output — byte-identical by default.
# Scout pools follow the same split (ISSUE 10): occupancy-cut batched
# scout groups (``sim._make_batched_scout_step``) under "occupancy"; the
# cpu profile keeps the measured flat/stacked scout layout (its batched
# small-lane window is opt-in via REPRO_BSCOUT_PER_SHARD — off by
# default because it loses on CPU, see _BSCOUT_MAX_PER_SHARD above).
PLANNER_PROFILE = os.environ.get("REPRO_PLANNER_PROFILE", "auto")
_PROFILES = ("cpu", "occupancy", "auto")

# occupancy budget: padded scan chunks (lanes x chunks) a single device
# should carry per dispatch before the planner cuts a new group
OCCUPANCY_CHUNKS = int(os.environ.get("REPRO_OCCUPANCY_CHUNKS", "4096"))


def planner_profile() -> str:
    """Resolve PLANNER_PROFILE to "cpu" or "occupancy" for this process."""
    p = PLANNER_PROFILE
    if p not in _PROFILES:
        raise ValueError(f"unknown planner profile {p!r}; pick from {_PROFILES}")
    if p != "auto":
        return p
    import jax

    return "occupancy" if jax.default_backend() in S._ACCEL_BACKENDS else "cpu"

# background compile pool for the overlapped compile/execute pipeline: on
# an n-core host, n-1 workers compile while the main thread dispatches
# already-compiled groups (XLA compilation releases the GIL).
_COMPILE_POOL = None

# executable compiles/loads already in flight (cross-phase: ``precompile``
# submits a whole preset's worth before the first phase executes; the
# dispatch loop adopts the futures instead of resubmitting)
_INFLIGHT: dict = {}

# keys delegated to the out-of-process compile server (repro.ssd.xc_worker)
# and the server process handle.  Process mode needs the persistent store
# (the server publishes through it) and is the default when one is
# configured; REPRO_COMPILE_PROC=0 forces in-process threads.
_PROC_KEYS: set = set()
_PROC = None

# ---- self-healing compile backend (ISSUE 8) ------------------------------
# The compile server is a scheduling hint with no correctness surface, but
# a hint that HANGS (wedged process, SIGSTOP, swap death) used to cost the
# 600s poll deadline per delegated key.  A _ServerWatchdog built on the
# runtime fault-tolerance primitives closes that: the worker's heartbeat
# thread touches a file ~1/s, a silent worker past REPRO_XC_WATCHDOG_S is
# declared dead, and an alive-but-pathologically-slow worker is abandoned
# by the straggler rule.  Either way every delegated key falls back to the
# in-process compile path and the run completes — counted in
# ``bench.PERF["xc_watchdog_trips"/"xc_watchdog_fallbacks"]``.
_WATCHDOG_TIMEOUT_S = float(os.environ.get("REPRO_XC_WATCHDOG_S", "20.0"))
_WATCHDOG = None
_WD_LOCK = threading.Lock()


class _ServerWatchdog:
    """Liveness + progress tracking for one compile-server process.

    ``HeartbeatMonitor`` consumes the worker's heartbeat file (mtime
    changes become beats); ``StragglerDetector`` watches the wait time of
    each delegated key relative to the median wait of the keys currently
    being awaited, so one wedged key among progressing ones is flagged
    after ``patience`` strikes even while heartbeats continue."""

    # straggler observations are taken at this cadence, not per 50ms poll
    # tick, so ``patience`` means "straggling for patience * period"
    OBSERVE_PERIOD_S = 5.0

    def __init__(self, hb_path: str, timeout_s: float = None, clock=None):
        from repro.runtime.fault_tolerance import (HeartbeatMonitor,
                                                   StragglerDetector)

        self.hb_path = hb_path
        self._clock = clock or time.monotonic
        self.mon = HeartbeatMonitor(
            ["xc_worker"],
            timeout_s=(_WATCHDOG_TIMEOUT_S if timeout_s is None
                       else timeout_s),
            clock=clock,
        )
        self.strag = StragglerDetector(k=4.0, deadline_floor_s=60.0,
                                       patience=3)
        self.waits: dict = {}  # key -> wait start (perf_counter)
        self._mtime = None
        self._next_observe = self._clock() + self.OBSERVE_PERIOD_S
        self.reason = None

    def track(self, key: tuple) -> None:
        with _WD_LOCK:
            self.waits[key] = time.perf_counter()

    def untrack(self, key: tuple) -> None:
        with _WD_LOCK:
            self.waits.pop(key, None)

    def healthy(self) -> bool:
        """Poll the heartbeat file + straggler clock; False once the
        server should be abandoned (sticky)."""
        with _WD_LOCK:
            if self.reason is not None:
                return False
            try:
                m = os.path.getmtime(self.hb_path)
            except OSError:
                m = None
            if m is not None and m != self._mtime:
                self._mtime = m
                self.mon.beat("xc_worker")
            if self.mon.dead_hosts():
                self.reason = "heartbeat"
                return False
            now = self._clock()
            if now >= self._next_observe and self.waits:
                self._next_observe = now + self.OBSERVE_PERIOD_S
                t = time.perf_counter()
                durs = {str(k): t - t0 for k, t0 in self.waits.items()}
                if self.strag.observe_step(durs):
                    self.reason = "straggler"
                    return False
            return True


def _fail_server(reason: str) -> int:
    """Abandon the compile server: reclaim every delegated key for the
    in-process compile path.  Idempotent; returns reclaimed-key count."""
    global _PROC, _WATCHDOG
    with _WD_LOCK:
        n = len(_PROC_KEYS)
        if n == 0 and _PROC is None:
            return 0
        _PROC_KEYS.clear()
        proc, _PROC = _PROC, None
        _WATCHDOG = None
    if proc is not None and proc.poll() is None:
        try:
            proc.kill()
        except OSError:
            pass
    perf = bench.PERF
    perf["xc_watchdog_trips"] = perf.get("xc_watchdog_trips", 0) + 1
    perf["xc_watchdog_reason"] = reason
    obs_spans.instant("watchdog", "server_abandoned", reason=reason,
                      reclaimed_keys=n)
    return n


def _proc_mode() -> bool:
    return (exec_cache.cache_dir() is not None
            and os.environ.get("REPRO_COMPILE_PROC", "1") != "0")


def _proc_alive() -> bool:
    return _PROC is not None and _PROC.poll() is None


def _schedule_compiles(keys: list) -> None:
    """Route missing executables to the compile server (process mode) or
    the background thread pool."""
    keys = [k for k in keys
            if k not in S._EXEC_CACHE and k not in _INFLIGHT
            and k not in _PROC_KEYS and not exec_cache.has(k)]
    if not keys:
        return
    # keys arrive in need order (pool insertion follows run order, i.e.
    # phase order) — the compile stream publishes what the dispatcher
    # will ask for first
    if _proc_mode():
        global _PROC
        import subprocess
        import sys
        import tempfile

        # the first two programs gate the first phase, and nothing can
        # execute until they exist — compile them HERE, synchronously and
        # at full speed, while the server boots (its jax import alone is
        # ~3s) and works through the rest of the preset
        local, remote = keys[:2], keys[2:]
        if remote:
            global _WATCHDOG
            fd, path = tempfile.mkstemp(suffix=".xckeys")
            with os.fdopen(fd, "wb") as f:
                import pickle

                pickle.dump(remote, f)
            # heartbeat file: the worker's beat thread touches it ~1/s
            # from process start (before its jax import), the watchdog
            # turns mtime changes into HeartbeatMonitor beats
            hb_path = path + ".hb"
            with open(hb_path, "w"):
                pass
            env = dict(os.environ, REPRO_XC_HEARTBEAT=hb_path)
            _PROC = subprocess.Popen(
                [sys.executable, "-m", "repro.ssd.xc_worker", path],
                env=env,
            )
            _PROC_KEYS.update(remote)
            _WATCHDOG = _ServerWatchdog(hb_path)
            obs_spans.instant("compile", "xc_server_launched",
                              delegated_keys=len(remote))
        for k in local:
            S.ensure_compiled(k)
    else:
        for k in keys:
            _INFLIGHT[k] = _compile_pool().submit(S.ensure_compiled, k,
                                                  None)


def _await_server(key: tuple):
    """Poll-future body: wait for the compile server to publish ``key``,
    then load it; compile locally (in-process) if the server dies, hangs
    past the heartbeat deadline, or straggles — the watchdog abandons the
    server once, and every still-delegated key falls back immediately."""
    wd = _WATCHDOG
    if wd is not None:
        wd.track(key)
    tr = obs_spans.TRACER
    t_span = tr.now_us() if tr is not None else 0.0
    deadline = time.perf_counter() + 600.0
    try:
        while (_proc_alive() and not exec_cache.has(key)
               and time.perf_counter() < deadline):
            if wd is not None and not wd.healthy():
                _fail_server(wd.reason or "unhealthy")
                break
            time.sleep(0.05)
    finally:
        if wd is not None:
            wd.untrack(key)
    if not exec_cache.has(key):
        # the server never published this key — in-process fallback
        if _PROC is not None and not _proc_alive() and _PROC.returncode != 0:
            _fail_server("crashed")
        perf = bench.PERF
        perf["xc_watchdog_fallbacks"] = (
            perf.get("xc_watchdog_fallbacks", 0) + 1
        )
    if tr is not None:
        tr.complete("compile", "await_xc_server", t_span,
                    tr.now_us() - t_span)
    return S.ensure_compiled(key)


def _compile_pool():
    global _COMPILE_POOL
    if _COMPILE_POOL is None:
        # at least 2 workers even on a 2-core host: while the dispatcher
        # is starved (cold start of a phase) the cores should be running
        # two backend compiles, not one
        n = int(os.environ.get(
            "REPRO_COMPILE_WORKERS",
            str(min(4, max(2, (os.cpu_count() or 2) - 1))),
        ))
        _COMPILE_POOL = concurrent.futures.ThreadPoolExecutor(
            max_workers=max(1, n), thread_name_prefix="xc-compile",
        )
    return _COMPILE_POOL


def _pow2ceil(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


# fully-generic promotion tuple (every _PROMOTABLE scalar stays traced):
# the small-lane layouts trade per-step leanness for ONE executable per
# (geometry, capacity, layout) across every pool, phase and preset
_NO_PROMO = (None,) * len(S._PROMOTABLE)


@dataclasses.dataclass(frozen=True)
class RunRequest:
    """One pending ``bench.run_workload`` call, planned for batched
    execution."""

    name: str
    cfg: SSDConfig
    designs: tuple
    n_requests: int | None = None
    target_util: float | None = 1.5
    seed: int = 0


class _Lane:
    """One scan lane: a (run, design[, channel row]) unit of work."""

    __slots__ = ("run_idx", "design_idx", "seed", "tables_row", "txns",
                 "n", "pos", "spec", "out")

    def __init__(self, run_idx, design_idx, seed, tables_row, txns, n, pos,
                 spec):
        self.run_idx = run_idx
        self.design_idx = design_idx
        self.seed = seed
        self.tables_row = tables_row  # LaneTables row, numpy, no lane axis
        self.txns = txns  # TxnArrays, numpy, natural length n
        self.n = n
        self.pos = pos  # positions in the run's ordered space (None = all)
        self.spec = spec
        self.out = None  # StepOut numpy [capacity], filled by _run_pool

    @property
    def n_chunks(self) -> int:
        return -(-self.n // S.CHUNK)  # ceil; 0 chunks for an empty lane


def _want_decompose(flag, spec, confined: bool, cfg: SSDConfig, n: int,
                    rows_ok: bool) -> bool:
    if spec.kind == KIND_SCOUT or not confined or cfg.rows <= 1 or n == 0:
        return False
    if not rows_ok:  # txn row field inconsistent with node layout — safety
        return False
    if flag is True:
        return True
    return (flag == "auto"
            and n >= cfg.rows * AUTO_DECOMPOSE_MIN_CHUNKS_PER_ROW * S.CHUNK)


def _slice_txns(txns: S.TxnArrays, idx: np.ndarray) -> S.TxnArrays:
    return S.TxnArrays(*(a[idx] for a in txns))


def _pad_txns(txns: S.TxnArrays, cap: int) -> S.TxnArrays:
    out = []
    for a in txns:
        b = np.zeros((cap,), dtype=a.dtype)
        b[: len(a)] = a
        out.append(b)
    return S.TxnArrays(*out)


def _pool_promotions(lanes: list) -> tuple:
    """Common value of each promotable scalar across the POOL (not per
    group): every group of the pool must share one executable, so the
    specialization is computed once over all its lanes."""

    class _Stack:
        def __getattr__(self, name):
            return np.stack(
                [np.asarray(getattr(ln.tables_row, name)) for ln in lanes]
            )

    return S._promotions(_Stack())


@dataclasses.dataclass
class _GroupPlan:
    """One planned dispatch: a group of lanes bound to an executable key."""

    variant: str  # "lane" | "stack" | "batched" | "bscout"
    sig: tuple
    lanes: list  # dispatch order; may contain duplicate refs (padding)
    cap: int
    n_shards: int
    per_shard: int  # 1 (lane) | K (stack) | Bs (batched)
    k_max: int
    has_scout: bool
    fixed: tuple
    backend: str = "xla"  # lane-step kernel for "batched" plans
    key: tuple = None
    est_exec: float = 0.0
    est_compile: float = 0.0

    def finalize(self) -> "_GroupPlan":
        if self.variant == "lane":
            self.key = S.lane_group_key(self.sig, self.cap, len(self.lanes),
                                        self.k_max, self.has_scout,
                                        self.fixed, self.n_shards)
        elif self.variant == "stack":
            self.key = S.stack_group_key(self.sig, self.cap, self.per_shard,
                                         self.k_max, self.has_scout,
                                         self.fixed, self.n_shards)
        elif self.variant == "bscout":
            self.key = S.bscout_group_key(self.sig, self.cap,
                                          self.per_shard, self.k_max,
                                          self.fixed, self.n_shards,
                                          self.backend)
        else:
            self.key = S.batched_group_key(self.sig, self.cap,
                                           self.per_shard, self.fixed,
                                           self.n_shards, self.backend)
        # cost model (ordering heuristics only), measured from SpanTracer
        # plan->compile->dispatch spans on the quick preset (see
        # EXPERIMENTS.md "Planner cost model"): scout programs compile
        # slower than static ones (the nested scout while-loops) and a
        # scout step costs more than a static step; execute cost scales
        # with scheduled scan chunks
        w = _COST_SCOUT_STEP if self.has_scout else 1.0
        self.est_compile = (
            _COST_SCOUT_COMPILE if self.has_scout else 1.0
        ) * (_COST_MULTILANE_COMPILE if self.variant != "lane" else 1.0)
        self.est_exec = w * sum(ln.n_chunks for ln in self.lanes)
        return self


def _pad_block(block: list, size: int) -> list:
    block = list(block)
    while len(block) < size:
        block.append(block[-1])
    return block


def _plan_pool(sig: tuple, lanes: list, has_scout: bool) -> list:
    """Lay one (geometry, cost class) pool out as dispatchable groups,
    under the active planner backend profile (:func:`planner_profile`)."""
    if planner_profile() == "occupancy":
        return _plan_pool_occupancy(sig, lanes, has_scout)
    return _plan_pool_cpu(sig, lanes, has_scout)


def _plan_pool_occupancy(sig: tuple, lanes: list, has_scout: bool) -> list:
    """Accelerator layout for a pool: every lane runs in the batched
    runner — gather-free static step for statically-routed pools, the
    batched scout DFS runner (``sim._make_batched_scout_step``) for scout
    pools — grouped by occupancy: lanes x padded scan chunks per device,
    cut at OCCUPANCY_CHUNKS, rather than core count.  Lanes are
    length-sorted first, so a group's padded cost is its width times its
    longest (last) member and mixed-length pools don't pay a long lane's
    padding across every short one.  Bit-exact vs the cpu layout: both
    batched steps' masked-validity paths make the extra padding a no-op,
    pinned by tests/test_batched_pallas.py and tests/test_batched_scout.py.
    """
    n_shards = S.host_device_count()
    order = sorted(lanes, key=lambda ln: ln.n_chunks)
    cap = max(_CAP_SEEN.get(sig, 0), S._pad_to(max(ln.n for ln in order)))
    _CAP_SEEN[sig] = cap
    backend = S.resolve_lane_backend()
    k_max = (max(ln.spec.n_scouts for ln in lanes) if has_scout else 1)
    fixed = _pool_promotions(lanes) if has_scout else _NO_PROMO
    variant = "bscout" if has_scout else "batched"
    budget = max(1, OCCUPANCY_CHUNKS) * n_shards
    plans, i = [], 0
    while i < len(order):
        j = i + 1
        while (j < len(order)
               and (j - i + 1) * max(order[j].n_chunks, 1) <= budget):
            j += 1
        blk = order[i:j]
        i = j
        per = -(-len(blk) // n_shards)
        plans.append(_GroupPlan(
            variant, sig, _pad_block(blk, n_shards * per), cap,
            n_shards, per, k_max, has_scout, fixed, backend=backend,
        ))
    return [p.finalize() for p in plans]


def _plan_pool_cpu(sig: tuple, lanes: list, has_scout: bool) -> list:
    """The host-CPU layout of one (geometry, cost class) pool.

    Big lanes: one UNBATCHED lane per device shard, sorted by length (the
    sorted-length grouping keeps a group's barrier cheap).  Small lanes
    (<= SMALL_LANE_MAX_CHUNKS chunks): statically-routed ones collapse
    into the gather-free batched runner, scout ones stack K-per-shard —
    both cut the dispatch count of tiny-scan pools ~K/B-fold.  A pool
    smaller than the device count compiles at its own size; remainder
    blocks are padded with duplicate lanes (discarded outputs are cheaper
    than another executable).
    """
    n_shards = S.host_device_count()
    k_max = (max(ln.spec.n_scouts for ln in lanes) if has_scout else 1)
    fixed = _pool_promotions(lanes)
    order = sorted(lanes, key=lambda ln: ln.n_chunks)

    small_max = SMALL_LANE_MAX_CHUNKS
    small = [ln for ln in order if ln.n_chunks <= small_max]
    flat = [ln for ln in order if ln.n_chunks > small_max]
    plans = []
    # the small-lane window starts where the collapsed layouts save
    # dispatches over the flat path (> 2 per-lane groups' worth)
    if len(small) > 2 * n_shards and len(small) >= _BATCH_MIN_LANES:
        # small-lane layouts pad to their own (smaller) capacity
        # high-water, and run FULLY GENERIC programs (no promotions,
        # ``_NO_PROMO``): their total step count is tiny, so one
        # executable per (geometry, capacity, layout) serving every pool
        # beats a leaner program per promotion pattern — compile count is
        # the small-lane cost, not step cost
        skey = ("small", sig)
        scap = max(_CAP_SEEN.get(skey, 0),
                   S._pad_to(max(ln.n for ln in small)))
        _CAP_SEEN[skey] = scap
        if not has_scout and len(small) <= n_shards * _BATCH_MAX_PER_SHARD:
            Bs = -(-len(small) // n_shards)
            plans.append(_GroupPlan(
                "batched", sig, _pad_block(small, n_shards * Bs), scap,
                n_shards, Bs, 1, False, _NO_PROMO,
                backend=S.resolve_lane_backend(),
            ))
        elif has_scout and len(small) <= n_shards * _BSCOUT_MAX_PER_SHARD:
            # the batched-scout analogue of the static window: one
            # gather-free scout dispatch instead of K-per-shard lax.map
            # stacks.  Like every small-lane layout it runs the fully
            # generic program (``_NO_PROMO`` — hold/allow/n_scouts stay
            # traced per lane) so one executable per (geometry, capacity,
            # k_max) serves every pool.
            Bs = -(-len(small) // n_shards)
            plans.append(_GroupPlan(
                "bscout", sig, _pad_block(small, n_shards * Bs), scap,
                n_shards, Bs, k_max, True, _NO_PROMO,
                backend=S.resolve_lane_backend(),
            ))
        else:
            # one K for the whole pool, snapped to the {4, 16} ladder:
            # K fragments the executable key, and duplicate-lane padding
            # of tiny scans is far cheaper than another compile
            K = _pow2ceil(-(-len(small) // n_shards))
            K = 4 if K <= 4 else _STACK_MAX_K
            for i in range(0, len(small), n_shards * K):
                blk = small[i: i + n_shards * K]
                plans.append(_GroupPlan(
                    "stack", sig, _pad_block(blk, n_shards * K), scap,
                    n_shards, K, k_max, has_scout, _NO_PROMO,
                ))
    else:
        flat = order

    if flat:
        cap = max(_CAP_SEEN.get(sig, 0),
                  S._pad_to(max(ln.n for ln in flat)))
        _CAP_SEEN[sig] = cap
        G = max(1, min(n_shards, len(flat)))
        for i in range(0, len(flat), G):
            plans.append(_GroupPlan(
                "lane", sig, _pad_block(flat[i: i + G], G), cap,
                min(G, n_shards), 1, k_max, has_scout, fixed,
            ))
        if G < n_shards:
            # opportunistic width padding: a pool smaller than the device
            # count compiles at its own size UNLESS the full-width
            # executable already exists (memory or store) — duplicate
            # lanes run on otherwise-idle shards, so reusing the wide
            # program is free and saves the narrow compile
            p = plans[-1]
            wide = dataclasses.replace(
                p, lanes=_pad_block(p.lanes, n_shards),
                n_shards=n_shards,
            ).finalize()
            if wide.key in S._EXEC_CACHE or exec_cache.has(wide.key):
                plans[-1] = wide
    return [p.finalize() for p in plans]


def _dispatch(plan: _GroupPlan) -> dict:
    """Stack one plan's arguments, execute it, and scatter lane outputs."""
    lanes, cap = plan.lanes, plan.cap
    if plan.variant in ("lane", "stack"):
        tables = LaneTables(
            *(np.stack([np.asarray(getattr(ln.tables_row, f))
                        for ln in lanes])
              for f in LaneTables._fields)
        )
        seeds = np.asarray([ln.seed for ln in lanes], np.uint32)
        txns = S.TxnArrays(
            *(np.stack(cols) for cols in
              zip(*(_pad_txns(ln.txns, cap) for ln in lanes)))
        )
        ncs = np.asarray([ln.n_chunks for ln in lanes], np.int32)
        outs, perf = S.run_group(
            plan.sig, tables, seeds, txns, ncs, plan.k_max,
            plan.has_scout, plan.fixed, plan.n_shards,
            K=(plan.per_shard if plan.variant == "stack" else 0),
        )
        seen = set()
        for j, ln in enumerate(lanes):
            if id(ln) in seen:  # padding duplicate — outputs discarded
                continue
            seen.add(id(ln))
            ln.out = S.StepOut(*(np.asarray(a)[j] for a in outs))
    elif plan.variant == "bscout":
        B = len(lanes)
        scal = S.ScoutBatchScalars(
            *(np.asarray([np.asarray(getattr(ln.tables_row, name))
                          for ln in lanes])
              for name in S._PROMOTABLE),
            fc_valid=np.stack([np.asarray(ln.tables_row.fc_valid)
                               for ln in lanes]),
            fc_node=np.stack([np.asarray(ln.tables_row.fc_node)
                              for ln in lanes]),
            res_dead=np.stack([np.asarray(ln.tables_row.res_dead)
                               for ln in lanes]),
        )
        seeds = np.asarray([ln.seed for ln in lanes], np.uint32)
        txns = S.TxnArrays(*(
            np.stack([np.asarray(a) for a in cols], axis=1)
            for cols in zip(*(_pad_txns(ln.txns, cap) for ln in lanes))
        ))
        F0 = np.asarray(lanes[0].tables_row.fc_valid).shape[0]
        tt = S.ScoutBatchTxnTables(
            dist=np.zeros((cap, B, F0), np.int32),
        )
        done = {}
        for j, ln in enumerate(lanes):
            key = id(ln)
            if key not in done:  # dup padding lanes share the pregather
                done[key] = pregather_scout_tables(
                    ln.tables_row, np.asarray(ln.txns.node)
                )
            tt.dist[:ln.n, j] = done[key]["dist"]
        ncs = np.asarray([ln.n_chunks for ln in lanes], np.int32)
        outs, perf = S.run_batched_scout_group(
            plan.sig, scal, seeds, txns, tt, ncs, plan.k_max,
            plan.fixed, plan.n_shards, plan.per_shard, plan.backend,
        )
        seen = set()
        for j, ln in enumerate(lanes):
            if id(ln) in seen:
                continue
            seen.add(id(ln))
            ln.out = S.StepOut(*(np.asarray(a)[:, j] for a in outs))
    else:
        B = len(lanes)
        scal = S.BatchScalars(
            *(np.asarray([np.asarray(getattr(ln.tables_row, name))
                          for ln in lanes])
              for name in S._PROMOTABLE),
            fc_valid=np.stack([np.asarray(ln.tables_row.fc_valid)
                               for ln in lanes]),
            res_dead=np.stack([np.asarray(ln.tables_row.res_dead)
                               for ln in lanes]),
        )
        txns = S.TxnArrays(*(
            np.stack([np.asarray(a) for a in cols], axis=1)
            for cols in zip(*(_pad_txns(ln.txns, cap) for ln in lanes))
        ))
        F0 = np.asarray(lanes[0].tables_row.fc_valid).shape[0]
        R = np.asarray(lanes[0].tables_row.cmask).shape[-1]
        W = -(-R // 8)
        bt = S.BatchTxnTables(
            mask_words=np.zeros((cap, B, F0, 2, W), np.uint8),
            hops=np.zeros((cap, B, F0, 2), np.int32),
            dist=np.zeros((cap, B, F0), np.int32),
            cand2=np.zeros((cap, B), bool),
            fc_fixed=np.zeros((cap, B, 2), np.int32),
        )
        done = {}
        for j, ln in enumerate(lanes):
            key = id(ln)
            if key not in done:  # dup padding lanes share the pregather
                done[key] = pregather_node_tables(
                    ln.tables_row, np.asarray(ln.txns.node)
                )
            pg = done[key]
            n = ln.n
            bt.mask_words[:n, j] = pg["mask_words"]
            bt.hops[:n, j] = pg["hops"]
            bt.dist[:n, j] = pg["dist"]
            bt.cand2[:n, j] = pg["cand2"]
            bt.fc_fixed[:n, j] = pg["fc_fixed"]
        ncs = np.asarray([ln.n_chunks for ln in lanes], np.int32)
        outs, perf = S.run_batched_group(plan.sig, scal, txns, bt, ncs,
                                         plan.fixed, plan.n_shards,
                                         plan.per_shard, plan.backend)
        seen = set()
        for j, ln in enumerate(lanes):
            if id(ln) in seen:
                continue
            seen.add(id(ln))
            ln.out = S.StepOut(*(np.asarray(a)[:, j] for a in outs))
    perf["lanes"] = len(seen)
    return perf


def _execute_plans(plans: list) -> list:
    """The overlapped compile/execute pipeline.

    Missing executables are resolved on the background pool — persistent-
    store loads and XLA backend compiles both release the GIL — while the
    main thread dispatches groups whose executables are ready.  The
    GIL-bound half of a compile (tracing + lowering) would fight the
    dispatching main thread for the interpreter, so it happens HERE, on
    the main thread, before the dispatch loop (``sim.lower_for_key``);
    keys the store already holds skip it entirely.  Orders are the cost
    model's: lowering/compile submission longest-compile-first (the
    cold-path critical path), dispatch longest-estimated-execute first
    (warm-path order: big groups keep the devices busy while stragglers'
    compiles finish).  Time the main thread spends with nothing
    dispatchable is ``compile_wait_s``; compile wall-clock hidden behind
    execution is the pipeline's win, ``compile_overlap_s``.
    """
    perf = bench.PERF
    c0 = perf.get("compile_s", 0.0)
    futures = {}
    for p in sorted(plans, key=lambda p: -p.est_compile):
        if p.key in futures or p.key in S._EXEC_CACHE:
            continue
        fut = _INFLIGHT.get(p.key)
        if fut is None:
            if p.key in _PROC_KEYS and _proc_alive():
                # delegated to the compile server: poll for its entry
                fut = _compile_pool().submit(_await_server, p.key)
            else:
                lowered = (None if exec_cache.has(p.key)
                           else S.lower_for_key(p.key))
                fut = _compile_pool().submit(S.ensure_compiled, p.key,
                                             lowered)
            _INFLIGHT[p.key] = fut
        futures[p.key] = fut
    pending = sorted(plans, key=lambda p: -p.est_exec)
    compile_recs = {}  # key -> [seconds, source], claimed by first group
    wait_s = 0.0
    perf_groups = []
    while pending:
        ready = [p for p in pending
                 if p.key not in futures or futures[p.key].done()]
        if not ready:
            t0 = time.perf_counter()
            with obs_spans.span("dispatch", "compile_stall",
                                pending=len(pending)):
                concurrent.futures.wait(
                    {futures[p.key] for p in pending if p.key in futures},
                    return_when=concurrent.futures.FIRST_COMPLETED,
                )
            wait_s += time.perf_counter() - t0
            continue
        p = ready[0]
        pending.remove(p)
        if p.key in futures and p.key not in compile_recs:
            _, dt, src = futures[p.key].result()
            compile_recs[p.key] = [dt, src]
            _INFLIGHT.pop(p.key, None)
        with obs_spans.span("dispatch", f"group:{p.variant}",
                            lanes=len(p.lanes), shards=p.n_shards,
                            capacity=p.cap):
            g = _dispatch(p)
        rec = compile_recs.get(p.key)
        if rec is not None and rec[1] != "claimed":
            dt, src = rec
            g["cache"] = src
            if src == "build":
                g["compile_s"] = round(dt, 3)
            elif src == "disk":
                g["load_s"] = round(dt, 3)
            rec[1] = "claimed"
        perf_groups.append(g)
    # attribute the pipeline: compile wall-clock that accrued during this
    # dispatch pass vs the time the main thread actually stalled on it
    # (approximate across phase boundaries — background compiles span them)
    total_compile = perf.get("compile_s", 0.0) - c0
    perf["compile_wait_s"] = perf.get("compile_wait_s", 0.0) + wait_s
    perf["compile_overlap_s"] = (
        perf.get("compile_overlap_s", 0.0)
        + max(0.0, total_compile - wait_s)
    )
    return perf_groups


def _lower_runs(runs: list) -> tuple:
    """Lower runs to lanes pooled by (geometry, cost class).

    Returns ``(prepared, pools)`` — ``prepared`` holds per-run
    ``(cfg, txns, designs, order, op, n)`` for result assembly, ``pools``
    maps ``(sig, scout)`` to its :class:`_Lane` list.

    A run may carry an optional sixth element, a ``designs.FaultSpec``:
    its hardware faults lower into the lane tables (``res_dead`` rides as
    a table argument, so faulted and fault-free lanes share executables)
    and its read-retry ladder stretches the packed op ticks."""
    prepared = []
    pools: dict = {}
    for run_idx, run in enumerate(runs):
        cfg, txns, designs, seeds, decompose = run[:5]
        faults = run[5] if len(run) > 5 else None
        designs = tuple(designs)
        specs = resolve_specs(designs)
        order = S._nominal_order(cfg, txns)
        n = len(order)
        packed, op = S._pack_txns(cfg, txns, order, faults)
        prepared.append((cfg, txns, designs, order, op, n))
        confined = rows_confined(cfg, designs)
        tables = lower_designs(cfg, designs, faults)
        rows_np = np.asarray(packed.row)
        rows_ok = bool(
            np.array_equal(rows_np, np.asarray(packed.node) // cfg.cols)
        )
        row_pos = None
        sig = S._geom_sig(cfg)
        for i, spec in enumerate(specs):
            tables_row = LaneTables(
                *(np.asarray(a)[i] for a in tables)
            )
            seed = seeds[i] | 1
            scout = spec.kind == KIND_SCOUT
            key = (sig, scout)
            dec = _want_decompose(decompose, spec, confined[i], cfg, n,
                                  rows_ok)
            if dec and row_pos is None:
                row_pos = [np.flatnonzero(rows_np == r)
                           for r in range(cfg.rows)]
            lane_list = pools.setdefault(key, [])
            if dec:
                for pos in row_pos:
                    if len(pos) == 0:
                        continue
                    lane_list.append(_Lane(
                        run_idx, i, seed, tables_row,
                        _slice_txns(packed, pos), len(pos), pos, spec,
                    ))
            else:
                lane_list.append(_Lane(
                    run_idx, i, seed, tables_row, packed, n, None, spec,
                ))
    return prepared, pools


def execute_sim_runs(runs: Sequence[tuple]) -> list:
    """Execute many sweeps as pooled, sharded lane groups.

    ``runs``: iterable of ``(cfg, txns, designs, seeds, decompose)`` —
    ``seeds`` a per-lane tuple — optionally extended with a sixth
    element, a ``designs.FaultSpec`` to inject hardware faults into that
    run's lanes.  Returns per-run lists of
    :class:`~repro.ssd.sim.SimResult`, each bit-identical to
    ``sim.simulate`` of that lane alone.
    """
    runs = list(runs)
    prepared, pools = _lower_runs(runs)
    plans = []
    for (sig, scout), lanes in pools.items():
        plans.extend(_plan_pool(sig, lanes, scout))
    all_groups = _execute_plans(plans)

    # ---- PERF accounting (bench.PERF is the process-wide scoreboard) ----
    perf = bench.PERF
    if all_groups:  # devices actually used, not merely available
        perf["devices_used"] = max(perf.get("devices_used", 0),
                                   max(g["shards"] for g in all_groups))
    # compile_s / xc_load_s accumulate inside ``sim.ensure_compiled`` (a
    # background compile counts even if it finishes before any group
    # adopts its future); groups carry per-group attribution only
    for g in all_groups:
        perf["lanes"] = perf.get("lanes", 0) + g["lanes"]
        perf["scan_steps_padded"] = (
            perf.get("scan_steps_padded", 0) + g["steps"]
        )
        perf["exec_s"] = perf.get("exec_s", 0.0) + g["exec_s"]
    perf.setdefault("groups", []).extend(all_groups)
    # mirror the persistent-store telemetry (absolute, process-wide)
    for k, v in exec_cache.STATS.items():
        perf[f"xc_{k}"] = v

    # ---- merge lanes back into per-run SimResults ----
    results: list = []
    by_run: dict = {}
    for lanes in pools.values():
        for ln in lanes:
            by_run.setdefault((ln.run_idx, ln.design_idx), []).append(ln)
    rec = obs_events.RECORDER
    for run_idx, (cfg, txns, designs, order, op, n) in enumerate(prepared):
        run_res = []
        for i, design in enumerate(designs):
            lanes = by_run[(run_idx, i)]
            perf["scan_steps_valid"] = (
                perf.get("scan_steps_valid", 0) + sum(ln.n for ln in lanes)
            )
            if len(lanes) == 1 and lanes[0].pos is None:
                outs = lanes[0].out
            else:  # channel-decomposed: scatter rows back to ordered space
                outs = S.StepOut(*(
                    np.zeros((n,), dtype=np.asarray(f).dtype)
                    for f in lanes[0].out
                ))
                for ln in lanes:
                    for dst, src in zip(outs, ln.out):
                        dst[ln.pos] = src[: ln.n]
            run_res.append(
                S._finish_result(cfg, design, txns, order, op, outs, n)
            )
            if rec is not None:
                # flight recorder: same ingredients as _finish_result —
                # purely host-side, the scan carried nothing extra
                run_in = runs[run_idx]
                if len(run_in) > 5 and run_in[5] is not None:
                    rec.record_fault_swap(design, 0, lanes[0].tables_row,
                                          cfg.rows * cfg.cols)
                rec.record_run(
                    cfg, design, txns, order, op, outs, n,
                    lanes[0].tables_row,
                    lanes[0].spec.kind == KIND_SCOUT,
                    label=f"run{run_idx}",
                )
        results.append(run_res)
    return results


def _request_key(rq: RunRequest) -> tuple:
    return (rq.name, rq.cfg, rq.designs, rq.n_requests, rq.target_util,
            rq.seed)


def _sims_for(requests: Sequence[RunRequest]) -> tuple:
    """Trace + decompose a request batch into planner runs.

    Returns ``(sims, meta)`` with ``sims`` the ``execute_sim_runs`` input
    and ``meta`` per-request ``(accel, txns)``.  Decompositions go through
    the content-keyed LRU, so ``precompile`` and the phase body share one
    pass."""
    from repro.traces.generator import default_n_requests, to_pages, trace_for

    sims, meta = [], []
    for rq in requests:
        n_req = rq.n_requests or default_n_requests(rq.name)
        trace = trace_for(rq.name, n_req, rq.seed)
        accel = 1.0
        offered = bench.offered_utilization(trace, rq.cfg)
        if rq.target_util is not None:
            trace, accel = bench.accelerate(trace, rq.cfg, rq.target_util)
        bench.record_accel(rq.name, rq.cfg, accel, offered, rq.target_util)
        pages = to_pages(trace, rq.cfg.page_bytes)
        t0 = time.perf_counter()
        txns = bench.decompose_cached(rq.cfg, pages,
                                      int(pages["footprint_pages"]))
        bench.PERF["ftl_s"] += time.perf_counter() - t0
        seeds = ((rq.seed + 7),) * len(rq.designs)
        sims.append((rq.cfg, txns, rq.designs, seeds, "auto"))
        meta.append((accel, txns))
    return sims, meta


def execute_requests(requests: Sequence[RunRequest]) -> list:
    """Trace + decompose + simulate a batch of workload requests as one
    planned execution; results are inserted into ``bench._RUN_CACHE`` under
    the exact keys ``bench.run_workload`` uses."""
    sims, meta = _sims_for(requests)
    t0 = time.perf_counter()
    all_results = execute_sim_runs(sims)
    bench.PERF["sim_s"] += time.perf_counter() - t0
    out = []
    # a prefetched phase reads the whole batch back AFTER this returns, so
    # the batch must survive in the LRU together — insert with a cap at
    # least the batch size (later normal-cap inserts shrink the cache back
    # down, so this pins the batch without permanently growing the cap)
    cap = max(bench._RUN_CACHE_MAX, len(requests))
    for rq, (accel, txns), results in zip(requests, meta, all_results):
        run = bench.WorkloadRun(
            name=rq.name, cfg=rq.cfg, accel=accel,
            n_requests=txns.n_requests,
            results=dict(zip(rq.designs, results)),
            origin_phase=bench.PERF.get("phase"),
        )
        bench._lru_put(bench._RUN_CACHE, _request_key(rq), run, cap)
        out.append(run)
    return out


def prefetch(requests: Sequence[RunRequest]) -> None:
    """Plan and execute every not-yet-cached request as one batch.

    A figure phase calls this with its whole (workload, config) list; the
    phase body's ``run_workload`` calls are then all served from the run
    cache, so the phase's sweeps execute as pooled sharded groups instead
    of one eager sweep per workload."""
    pending, seen = [], set()
    for rq in requests:
        key = _request_key(rq)
        if key in seen:
            continue
        seen.add(key)
        # silent probe: planned work is counted as ``run_prefetched`` so
        # the hit/miss telemetry keeps meaning "work avoided/incurred by a
        # run_workload call" (the phase body's hits on prefetched entries
        # are real cache hits — the plan warmed them)
        if bench._cached_run(*key, count=False) is None:
            pending.append(rq)
    if pending:
        bench.PERF["run_prefetched"] += len(pending)
        execute_requests(pending)


def precompile(requests: Sequence[RunRequest],
               extra_keys: Sequence[tuple] = ()) -> None:
    """Plan a request batch WITHOUT executing it and start compiling every
    missing executable — on the out-of-process compile server when the
    persistent store is configured (in-process background compilation
    measured a ~2.3x GIL/core-contention tax on small hosts), else on the
    background thread pool.

    The cross-phase half of the overlapped pipeline: ``benchmarks/run.py``
    hands the whole preset over before the first phase runs, so a late
    phase's programs (fig15's fresh geometries, the tail's small-lane
    layouts via ``extra_keys``) compile while early phases execute.  Costs
    one planning pass (decompositions land in the shared LRU the phases
    reuse); dispatch later adopts in-flight futures / published store
    entries.  Purely a scheduling hint — a wrong or stale hint only means
    the compile happens at first use, as without it."""
    pending, seen = [], set()
    for rq in requests:
        key = _request_key(rq)
        if key in seen:
            continue
        seen.add(key)
        if bench._cached_run(*key, count=False) is None:
            pending.append(rq)
    plans = []
    if pending:
        sims, _ = _sims_for(pending)
        _, pools = _lower_runs(sims)
        for (sig, scout), lanes in pools.items():
            plans.extend(_plan_pool(sig, lanes, scout))
    keys = [p.key for p in plans] + list(extra_keys)
    if keys:
        _schedule_compiles(keys)


def prewarm_small_keys(cfg: SSDConfig, n_hint: int,
                       k_max: int = 1) -> list:
    """Executable keys of the generic small-lane layout programs a QoS
    phase will predictably need (static stack, scout stack) for lanes of
    roughly ``n_hint`` transactions — feed to :func:`precompile` as
    ``extra_keys``.  A hint, not a commitment."""
    sig = S._geom_sig(cfg)
    ns = S.host_device_count()
    cap = max(_CAP_SEEN.get(("small", sig), 0), S._pad_to(n_hint))
    return [
        S.stack_group_key(sig, cap, _STACK_MAX_K, 1, False, _NO_PROMO, ns),
        S.stack_group_key(sig, cap, 4, k_max, True, _NO_PROMO, ns),
    ]
