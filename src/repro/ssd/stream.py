"""Chunked streaming engine: unbounded traces at flat throughput.

The simulator's time base is int32 ticks of 10 ns, so a monolithic replay
caps out at ~21 s of trace (``traces/generator._MAX_SPAN_US``) — far below
the multi-hour MSR-Cambridge volumes the paper evaluates.  This engine
lifts the cap without widening the hot scan state: the trace is cut into
fixed-span *windows*, each window's arrivals are rebased to its own tick
origin (int64 at ingest, int32 inside the window), and every piece of
carried state crosses the boundary explicitly:

* **FTL state** rides the ``resume=`` continuation of
  ``repro.ssd.ftl.decompose_trace``: the carried L2P/free-block/GC state is
  exactly what a monolithic decomposition would hold at the boundary, and
  forcing an allocation-epoch boundary at the window edge is bit-exact
  (epochs are deterministic wear-ordered pops — see
  ``ftl_engine.decompose_vectorized``).
* **In-flight sim state** — per-plane free-at, the one-gap occupancy
  triples of every link/FC/chip/bus, and the scout RNG word — is carried as
  the ``lanec`` executable's scan-state argument (``sim.run_group_carry``)
  and rebased host-side by the window span (``sim.rebase_lane_state``).
  The rebase clamp ``max(t - W, 0)`` is semantics-preserving because window
  arrivals are >= 0: a transaction incomplete at the boundary keeps exactly
  its residual occupancy, so it re-enters the next window with its residual
  latency intact.
* **Commit order** is kept *identical* to the monolithic run: windows are
  cut by arrival for decomposition (FTL causality), but execution batches
  are formed by **nominal commit time** — the per-plane nominal FIFO
  availability is carried into each window's ``sim._nominal_times`` pass,
  and any transaction whose nominal time lands past the window end is
  deferred and re-injected into the next window's batch with its
  (frame-shifted, possibly negative) original arrival, i.e. with its
  residual latency intact.  Batches stable-sorted by nominal with
  decomposition-order ties therefore concatenate to exactly the global
  nominal order, so resources commit in the monolithic sequence even when
  a backlog straddles the cut.

The steady state is **execution-bound**: while window N executes, a
single-worker prep thread decomposes window N+1 and routes any missing
executables through ``sweep_plan``'s compile pipeline (background thread
pool or the ``xc_worker`` out-of-process compile server).  Windows share
one ``lanec`` executable per (geometry, capacity bucket, cost class,
promotions) — the capacity bucket is a running high-water mark — so after
window 1 on a warm store the per-window compile wait is ~0.

Bit-exactness contract (pinned by ``tests/test_stream.py``): a windowed
replay of any prefix that fits one window is bit-identical to
``sim.simulate`` of that prefix, and window-boundary carry (GC at the
edge, in-flight transactions spanning it, empty interior windows)
reproduces the monolithic run's per-request latencies and completions
exactly.
"""
from __future__ import annotations

import concurrent.futures
import time
from typing import NamedTuple, Sequence

import jax
import numpy as np

from repro.core.topology import build_mesh
from repro.obs import events as obs_events
from repro.obs import spans as obs_spans
from repro.ssd import bench
from repro.ssd import sim as S
from repro.ssd import sweep_plan as SP
from repro.ssd.config import SSDConfig, TICK_NS
from repro.ssd.designs import (
    KIND_SCOUT,
    LaneTables,
    REGISTRY,
    lower_designs,
    resolve_specs,
)
from repro.ssd.ftl import KIND_READ, KIND_WRITE, decompose_trace
from repro.traces.generator import to_pages

__all__ = ["DEFAULT_WINDOW_S", "StreamResult", "stream_simulate",
           "window_ticks_for"]

DEFAULT_WINDOW_S = 10.0
_I32_MAX = 2**31 - 1
# completions of in-flight transactions run past the window end, so the
# window span keeps ~2.7 s of int32 headroom for the overhang
_HEADROOM_TICKS = 1 << 28


def window_ticks_for(window_s: float) -> int:
    """Window span in ticks; guards the int32 scheduling headroom."""
    w = int(round(window_s * 1e9 / TICK_NS))
    if not 0 < w <= _I32_MAX - _HEADROOM_TICKS:
        raise ValueError(
            f"window_s={window_s!r} must be in (0, "
            f"{(_I32_MAX - _HEADROOM_TICKS) * TICK_NS * 1e-9:.1f}] s "
            "(int32 tick budget minus in-flight completion headroom)"
        )
    return w


def _arrival_ticks_abs(arrival_us) -> np.ndarray:
    """Absolute int64 arrival ticks — the exact float64 op sequence of
    ``us_to_ticks`` so window-rebased ticks match a monolithic replay."""
    us = np.asarray(arrival_us, np.float64)
    return np.ceil(us * 1e3 / TICK_NS).astype(np.int64)


class StreamResult(NamedTuple):
    """A windowed replay: per-design results + per-window telemetry."""

    results: list  # SimResult per design (absolute int64 tick frame)
    windows: list  # per-window dicts (n_requests, wall_s, ios_per_wallclock_s, ...)
    window_ticks: int
    n_windows: int
    n_requests: int
    ftl: object  # final carried FTL (state-parity tests)

    def throughput_flatness(self) -> float:
        """last-window / first-steady-window simulated-IOs per wall-clock
        second; 1.0 means perfectly flat.  The first nonempty window is
        warm-up (it pays the one-time executable load / compile wait) and
        is skipped when later nonempty windows exist."""
        tp = [w["ios_per_wallclock_s"] for w in self.windows
              if w["n_requests"]]
        if len(tp) > 2:
            tp = tp[1:]  # drop warm-up
        if len(tp) < 2 or tp[0] <= 0:
            return 1.0
        return tp[-1] / tp[0]


class _Lane:
    """One design's streaming lane: static program identity + carried
    scan state."""

    __slots__ = ("design", "tables_row", "scout", "k_max", "fixed", "state")

    def __init__(self, design, tables_row, scout, k_max, fixed, state):
        self.design = design
        self.tables_row = tables_row
        self.scout = scout
        self.k_max = k_max
        self.fixed = fixed
        self.state = state


def _active_faults(schedule: dict, w: int):
    """Latest scheduled ``FaultSpec`` at or before window ``w`` (windows
    inherit the most recent boundary's spec; None before the first)."""
    spec = None
    for k in sorted(schedule):
        if k <= w:
            spec = schedule[k]
    return spec


def _finish_stream(cfg: SSDConfig, design: str, agg: dict,
                   n_req_total: int, tenant) -> S.SimResult:
    """``sim._finish_result`` over the stream's concatenated (absolute,
    int64) per-transaction arrays — same reductions, widened tick frame."""
    completion = agg["completion"]
    arrival = agg["arrival"]
    latency = completion - arrival
    n = len(completion)
    exec_ticks = int(completion.max() - arrival.min()) if n else 0

    req = agg["req"]
    failed = agg["failed"]
    req_done = np.zeros((n_req_total,), np.int64)
    req_arr = np.full((n_req_total,), np.iinfo(np.int64).max)
    req_fail = np.zeros((n_req_total,), bool)
    host = req >= 0
    np.maximum.at(req_done, req[host], completion[host])
    np.minimum.at(req_arr, req[host], arrival[host])
    np.logical_or.at(req_fail, req[host], failed[host])
    seen = req_arr < np.iinfo(np.int64).max
    req_latency = (req_done - req_arr)[seen]
    req_completion = req_done[seen]
    req_tenant = None
    if tenant is not None and len(tenant) >= n_req_total:
        req_tenant = np.asarray(tenant, np.int32)[:n_req_total][seen]

    pm = cfg.power
    tick_s = TICK_NS * 1e-9
    kind = agg["kind"]
    op = agg["op"]
    die_w = np.where(
        kind == KIND_READ,
        pm.die_read_w,
        np.where(kind == KIND_WRITE, pm.die_prog_w, pm.die_erase_w),
    )
    flash_energy = float(np.sum(op.astype(np.float64) * tick_s * die_w))
    bus_hold = int(agg["bus_hold_ticks"])
    link_hold = int(agg["link_hold_ticks"])
    transfer_energy = (
        bus_hold * tick_s * pm.bus_active_w
        + link_hold * tick_s * pm.link_active_w
    )
    n_routers = REGISTRY[design].n_routers(build_mesh(cfg.rows, cfg.cols))
    static_energy = (pm.static_w + n_routers * pm.router_w) * exec_ticks * tick_s

    return S.SimResult(
        design=design,
        completion=completion,
        latency=latency,
        req_latency=req_latency,
        wait=agg["wait"],
        conflict=agg["conflict"],
        hops=agg["hops"],
        tries=agg["tries"],
        misroutes=agg["misroutes"],
        exec_ticks=exec_ticks,
        bus_hold_ticks=bus_hold,
        link_hold_ticks=link_hold,
        flash_energy_j=flash_energy,
        transfer_energy_j=float(transfer_energy),
        static_energy_j=float(static_energy),
        req_completion=req_completion,
        req_tenant=req_tenant,
        failed=failed,
        req_failed=req_fail[seen],
    )


def _resolve_executable(key: tuple) -> float:
    """Block until ``key``'s executable is loaded; returns the main-thread
    stall seconds (mirrors ``sweep_plan._execute_plans``'s wait pattern —
    an in-flight background compile is adopted, a compile-server key is
    polled, anything else resolves through the three-tier store)."""
    if key in S._EXEC_CACHE:
        return 0.0
    t0 = time.perf_counter()
    fut = SP._INFLIGHT.pop(key, None)
    if fut is not None:
        fut.result()
    elif key in SP._PROC_KEYS and SP._proc_alive():
        SP._await_server(key)
    else:
        S.ensure_compiled(key)
    return time.perf_counter() - t0


def stream_simulate(
    cfg: SSDConfig,
    trace,
    designs: Sequence[str] = ("venice",),
    seeds: int | Sequence[int] = 0,
    window_s: float = DEFAULT_WINDOW_S,
    engine: str = "auto",
    overprovision: float = 1.28,
    precondition: bool = True,
    decompose_seed: int = 0,
    faults=None,
    fault_schedule: dict | None = None,
    capture: list | None = None,
) -> StreamResult:
    """Replay an arbitrarily long trace in tick-rebased windows.

    ``trace`` is a canonical byte trace (``offset_bytes``/``size_bytes``)
    or an already-paged trace (``offset_page``/``n_pages`` +
    ``footprint_pages``).  Windows are decomposed with the carried FTL,
    ordered with the carried nominal availability, executed with the
    carried scan state, and window N+1's decomposition + compile overlap
    window N's execution on a single prep thread.  Returns a
    :class:`StreamResult` whose per-design :class:`~repro.ssd.sim.SimResult`
    carries absolute int64 ticks.

    ``faults`` (a ``designs.FaultSpec``) injects hardware faults for the
    whole replay; ``fault_schedule`` maps window index -> ``FaultSpec``
    taking effect at that window's START (a window boundary), modelling
    mid-trace fault arrival — later windows inherit the latest boundary's
    spec.  Faulted tables are swapped in as ARGUMENTS of the same
    ``lanec`` executables (promotions are fault-invariant), so a schedule
    never costs a recompile.  Hardware-fault windows stay bit-identical
    to a monolithic ``sim.simulate`` with the same spec; read-retry draws
    are keyed on window-frame arrivals and are therefore stream-frame
    specific.

    ``capture`` (debug hook): a list that receives one dict per window —
    ``{"w", "packed", "n"}`` with the exact window-frame execution batch
    the lanes scanned — so a scalar reference can replay the identical
    per-window batches (``tests/test_faults.py`` pins the mid-stream
    fault-arrival path element-wise this way).
    """
    designs = tuple(designs)
    specs = resolve_specs(designs)
    if isinstance(seeds, (int, np.integer)):
        seeds = (int(seeds),) * len(designs)
    seeds = tuple(int(s) for s in seeds)
    if len(seeds) != len(designs):
        raise ValueError(
            f"got {len(seeds)} seeds for {len(designs)} design lanes"
        )

    pages = trace if "offset_page" in trace else to_pages(trace,
                                                         cfg.page_bytes)
    fp = int(pages["footprint_pages"])
    t_abs = _arrival_ticks_abs(pages["arrival_us"])
    n_requests = len(t_abs)
    if n_requests == 0:
        raise ValueError("cannot stream an empty trace")
    if np.any(np.diff(t_abs) < 0):
        raise ValueError("stream_simulate requires time-ordered arrivals")

    W = window_ticks_for(window_s)
    n_windows = int(t_abs[-1] // W) + 1
    bounds = np.searchsorted(t_abs, np.arange(1, n_windows + 1) * W,
                             side="left")
    starts = np.concatenate(([0], bounds[:-1]))

    schedule = {int(k): v for k, v in (fault_schedule or {}).items()}
    if faults is not None:
        schedule.setdefault(0, faults)
    if any(k < 0 for k in schedule):
        raise ValueError("fault_schedule windows must be >= 0")
    cur_spec = _active_faults(schedule, 0)

    tables = lower_designs(cfg, designs, cur_spec)
    sig = S._geom_sig(cfg)
    lanes = []
    for i, spec in enumerate(specs):
        tables_row = LaneTables(*(np.asarray(a)[i] for a in tables))
        scout = spec.kind == KIND_SCOUT
        k_max = spec.n_scouts if scout else 1
        fixed = S._promotions(tables_row)
        state = S.initial_lane_state(cfg, scout, seeds[i] | 1)
        lanes.append(_Lane(designs[i], tables_row, scout, k_max, fixed,
                           state))

    perf = bench.PERF
    c0 = perf.get("compile_s", 0.0)
    _POOL_FIELDS = ("arrival", "kind", "plane", "node", "row", "nbytes",
                    "req", "nominal")
    carry = {
        "ftl": None,
        "nom_avail": np.zeros((cfg.n_planes,), np.int64),
        "cap": 0,
        "req_base": 0,
        # deferred transactions: decomposed in an earlier window but
        # nominally committing in a later one, kept in global decomposition
        # order with frame-rebased (possibly negative) arrivals/nominals
        "pool": None,
    }

    def _prepare(w: int) -> dict:
        """Decompose, defer-partition, order, and pack window ``w``'s
        execution batch, then schedule its compiles.

        Runs on the prep thread for w > 0 (overlapped with window w-1's
        execution); mutates ``carry`` — safe because preps execute strictly
        in sequence on the single worker.

        The batch is formed by *nominal commit time*, not arrival: window
        ``w`` executes every pending transaction whose nominal time lands
        before the window end, and defers the rest — re-injected next
        window with arrival/nominal shifted into that frame.  Stable-sorted
        by nominal with ties falling back to decomposition order (the pool
        is kept in global order), the concatenation of per-window batches
        IS the monolithic nominal order, which is what makes boundary
        carry bit-exact even when a backlog straddles the cut."""
        t0 = time.perf_counter()
        lo, hi = int(starts[w]), int(bounds[w])
        sl = slice(lo, hi)
        win = {
            "arrival_us": np.asarray(pages["arrival_us"])[sl],
            "is_read": np.asarray(pages["is_read"])[sl],
            "offset_page": np.asarray(pages["offset_page"])[sl],
            "n_pages": np.asarray(pages["n_pages"])[sl],
            "footprint_pages": fp,
        }
        txns = decompose_trace(
            cfg, win, footprint_pages=fp, overprovision=overprovision,
            precondition=(precondition and carry["ftl"] is None),
            seed=decompose_seed, engine=engine, resume=carry["ftl"],
            arrival_ticks=t_abs[sl] - w * W,
        )
        carry["ftl"] = txns.ftl
        nominal, avail_out = S._nominal_times(cfg, txns, carry["nom_avail"])
        carry["nom_avail"] = np.maximum(avail_out - W, 0)
        req = np.asarray(txns["req"], np.int64)
        new = {f: np.asarray(txns[f], np.int64) for f in _POOL_FIELDS[:-2]}
        new["req"] = np.where(req >= 0, req + carry["req_base"], -1)
        new["nominal"] = nominal
        carry["req_base"] += hi - lo
        pool = (new if carry["pool"] is None else
                {f: np.concatenate((carry["pool"][f], new[f]))
                 for f in _POOL_FIELDS})
        # the last window flushes everything still pending
        take = (np.ones(len(pool["nominal"]), bool) if w == n_windows - 1
                else pool["nominal"] < W)
        batch = {f: pool[f][take] for f in _POOL_FIELDS}
        if take.all():
            carry["pool"] = None
        else:
            defer = {f: pool[f][~take] for f in _POOL_FIELDS}
            defer["arrival"] = defer["arrival"] - W
            defer["nominal"] = defer["nominal"] - W
            if int(defer["arrival"].min()) <= S.REBASE_FLOOR:
                raise ValueError(
                    "streamed backlog: transactions deferred so far past "
                    "their window that rebased arrivals fall below the "
                    f"int32 rebase floor; increase window_s (={window_s}) "
                    "or reduce the offered load"
                )
            carry["pool"] = defer
        order = np.argsort(batch["nominal"], kind="stable")
        packed, op = S._pack_txns(cfg, batch, order,
                                  _active_faults(schedule, w))
        n = len(order)
        cap = max(carry["cap"], S._pad_to(max(n, 1)))
        carry["cap"] = cap
        prep = {
            "w": w, "n": n, "n_req": hi - lo, "cap": cap,
            "packed": packed, "op": op,
            "padded": SP._pad_txns(packed, cap) if n else None,
            "req": batch["req"][order],
            "arrival_abs": batch["arrival"][order] + w * W,
            "keys": [],
        }
        if n:
            prep["keys"] = [
                S.lanec_group_key(sig, cap, 1, ln.k_max, ln.scout,
                                  ln.fixed, 1)
                for ln in lanes
            ]
            SP._schedule_compiles(list(dict.fromkeys(prep["keys"])))
        prep["prep_s"] = time.perf_counter() - t0
        perf["stream_prep_s"] = (perf.get("stream_prep_s", 0.0)
                                 + prep["prep_s"])
        return prep

    def _prep_traced(w: int) -> dict:
        with obs_spans.span("stream-prep", "prep", window=w):
            return _prepare(w)

    agg = [
        {"completion": [], "arrival": [], "wait": [], "conflict": [],
         "hops": [], "tries": [], "misroutes": [], "kind": [], "op": [],
         "req": [], "failed": [], "bus_hold_ticks": 0,
         "link_hold_ticks": 0}
        for _ in designs
    ]
    windows: list = []
    wait_total = 0.0

    rec = obs_events.RECORDER
    stream_id = rec.stream_token() if rec is not None else 0
    if rec is not None and cur_spec is not None:
        for ln in lanes:
            rec.record_fault_swap(ln.design, 0, ln.tables_row,
                                  cfg.rows * cfg.cols, stream_id)
    tracer = obs_spans.TRACER
    pool = concurrent.futures.ThreadPoolExecutor(
        max_workers=1, thread_name_prefix="stream-prep")
    try:
        prep = _prep_traced(0)
        fut_next = (pool.submit(_prep_traced, 1) if n_windows > 1 else None)
        for w in range(n_windows):
            t_w = time.perf_counter()
            t_span = tracer.now_us() if tracer is not None else 0.0
            base = w * W
            # window-boundary fault injection: swap the faulted tables in
            # as executable ARGUMENTS (the lanec key's promotions are
            # fault-invariant), carrying the scan state across untouched —
            # in-flight occupancy survives the fault arrival, exactly as
            # a mid-trace failure would leave it
            spec_w = _active_faults(schedule, w)
            if spec_w is not cur_spec:
                cur_spec = spec_w
                t_f = lower_designs(cfg, designs, cur_spec)
                for i, ln in enumerate(lanes):
                    ln.tables_row = LaneTables(
                        *(np.asarray(a)[i] for a in t_f))
                if rec is not None:
                    for ln in lanes:
                        rec.record_fault_swap(ln.design, base,
                                              ln.tables_row,
                                              cfg.rows * cfg.cols,
                                              stream_id)
                obs_spans.instant("stream", "fault_swap", window=w)
            n = prep["n"]
            if capture is not None:
                capture.append({"w": w, "packed": prep["packed"], "n": n})
            exec_s = 0.0
            wait_s = 0.0
            if n:
                n_chunks = np.asarray([-(-n // S.CHUNK)], np.int32)
                txns_g = S.TxnArrays(*(a[None] for a in prep["padded"]))
                for i, ln in enumerate(lanes):
                    wait_s += _resolve_executable(prep["keys"][i])
                    tables_g = LaneTables(
                        *(np.asarray(getattr(ln.tables_row, f))[None]
                          for f in LaneTables._fields)
                    )
                    state_g = jax.tree_util.tree_map(
                        lambda a: np.asarray(a)[None], ln.state)
                    st, outs, g = S.run_group_carry(
                        sig, tables_g, state_g, txns_g, n_chunks,
                        ln.k_max, ln.scout, ln.fixed, 1,
                    )
                    ln.state = jax.tree_util.tree_map(
                        lambda a: np.asarray(a)[0], st)
                    out_row = S.StepOut(
                        *(np.asarray(a)[0][:n] for a in outs))
                    if rec is not None:
                        rec.record_window(
                            cfg, ln.design, prep["packed"], prep["op"],
                            out_row, base, n, prep["arrival_abs"],
                            ln.tables_row, ln.scout, stream_id,
                        )
                    a = agg[i]
                    a["completion"].append(
                        out_row.completion.astype(np.int64) + base)
                    a["arrival"].append(prep["arrival_abs"])
                    a["wait"].append(out_row.wait)
                    a["conflict"].append(out_row.conflict)
                    a["hops"].append(out_row.hops)
                    a["tries"].append(out_row.tries)
                    a["misroutes"].append(out_row.misroutes)
                    a["kind"].append(np.asarray(prep["packed"].kind))
                    a["op"].append(prep["op"])
                    a["req"].append(prep["req"])
                    a["failed"].append(out_row.failed)
                    a["bus_hold_ticks"] += int(
                        out_row.bus_hold.astype(np.int64).sum())
                    a["link_hold_ticks"] += int(
                        out_row.link_hold.astype(np.int64).sum())
                    exec_s += g["exec_s"]
                    g["window"] = w
                    perf["lanes"] = perf.get("lanes", 0) + 1
                    perf["scan_steps_padded"] = (
                        perf.get("scan_steps_padded", 0) + g["steps"])
                    perf["scan_steps_valid"] = (
                        perf.get("scan_steps_valid", 0) + n)
                    perf["exec_s"] = perf.get("exec_s", 0.0) + g["exec_s"]
                    perf.setdefault("groups", []).append(g)
                perf["devices_used"] = max(perf.get("devices_used", 0), 1)
            # every lane's clock rolls forward by one window span, txns
            # or not — an idle window still ages the carried occupancy
            for ln in lanes:
                ln.state = S.rebase_lane_state(ln.state, W)
            wait_total += wait_s
            wall_s = time.perf_counter() - t_w
            if tracer is not None:
                tracer.complete("stream", f"window {w}", t_span,
                                tracer.now_us() - t_span,
                                {"n_txns": n, "n_requests": prep["n_req"],
                                 "compile_wait_s": round(wait_s, 4)})
            windows.append({
                "window": w,
                "n_requests": prep["n_req"],
                "n_txns": n,
                "prep_s": round(prep["prep_s"], 4),
                "exec_s": round(exec_s, 4),
                "compile_wait_s": round(wait_s, 4),
                "wall_s": round(wall_s, 4),
                "ios_per_wallclock_s": round(
                    prep["n_req"] / max(wall_s, 1e-9), 2),
            })
            if fut_next is not None:
                prep = fut_next.result()
                fut_next = (pool.submit(_prep_traced, w + 2)
                            if w + 2 < n_windows else None)
    finally:
        pool.shutdown(wait=True)

    perf["compile_wait_s"] = perf.get("compile_wait_s", 0.0) + wait_total
    perf["compile_overlap_s"] = perf.get("compile_overlap_s", 0.0) + max(
        0.0, (perf.get("compile_s", 0.0) - c0) - wait_total)
    perf["stream_windows"] = perf.get("stream_windows", 0) + n_windows

    tenant = pages.get("tenant")
    cat = lambda chunks, dt: (np.concatenate(chunks).astype(dt) if chunks
                              else np.zeros(0, dt))
    results = []
    for i, ln in enumerate(lanes):
        a = agg[i]
        results.append(_finish_stream(cfg, ln.design, {
            "completion": cat(a["completion"], np.int64),
            "arrival": cat(a["arrival"], np.int64),
            "wait": cat(a["wait"], np.int32),
            "conflict": cat(a["conflict"], bool),
            "hops": cat(a["hops"], np.int32),
            "tries": cat(a["tries"], np.int32),
            "misroutes": cat(a["misroutes"], np.int32),
            "kind": cat(a["kind"], np.int32),
            "op": cat(a["op"], np.int32),
            "req": cat(a["req"], np.int64),
            "failed": cat(a["failed"], bool),
            "bus_hold_ticks": a["bus_hold_ticks"],
            "link_hold_ticks": a["link_hold_ticks"],
        }, n_requests, tenant))
    return StreamResult(
        results=results,
        windows=windows,
        window_ticks=W,
        n_windows=n_windows,
        n_requests=n_requests,
        ftl=carry["ftl"],
    )
