"""Array-native FTL engine: vectorized trace → transaction decomposition.

Produces **bit-identical** ``Transactions`` to the scalar page-at-a-time FTL
in ``repro.ssd.ftl`` (retained as the parity oracle; ``tests/test_ftl.py``
asserts array-for-array and state-for-state equality, including GC-heavy
geometries).  The scalar oracle walks one page per Python iteration —
32k ``write_page`` calls just to precondition a 128 MB footprint — while
this engine exploits the determinism of the FTL's policies:

* **Preconditioning is closed-form.**  The sequential footprint fill uses
  W-C-D-P striping, which is pure arithmetic on the stripe index, and with
  all-zero erase counts the wear-aware allocator opens blocks 0,1,2,… in
  order — so the entire initial L2P/P2L map, per-block accounting and
  per-plane cursors are one numpy pass.  (If the geometry is so tight that
  the fill itself would trigger GC, we fall back to the scalar loop: GC
  ordering is the oracle's to define.)
* **Request → page expansion is ``repeat``/``cumsum``.**  No per-request
  inner loop; LPNs, arrival ticks and request ids for every page-op come
  from one broadcast.
* **Reads lower to a pure L2P gather.**  With a preconditioned footprint a
  read never mutates FTL state, so its physical page is "the last write to
  this LPN earlier in the stream, else the preconditioned mapping" — a
  grouped forward-fill over (lpn, position), not a replay.
* **Writes are epoch-vectorized.**  Between GC triggers every allocation is
  closed-form given the per-plane cursors: pages fill the open block then
  free blocks in wear order (erase counts cannot change mid-epoch).  The
  engine computes, per plane, how many pages fit before the *next* risky
  block-open (one that finds free blocks ≤ ``gc_threshold``), allocates
  that run in one shot, and hands exactly the triggering write to the
  scalar FTL's ``write_page`` — GC, victim selection and copyback stay the
  oracle's code, byte for byte.  GC is rare, so epochs are long.

The emitted rows are assembled in the oracle's insertion order (host row,
then that write's GC rows) before the shared stable sort-by-arrival, which
is what makes bit-identity a construction rather than a coincidence.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.ssd.config import SSDConfig, TICK_NS
from repro.ssd.ftl import (
    FTL,
    KIND_READ,
    KIND_WRITE,
    Transactions,
    stripe_plane,
    to_transactions,
)


def _cumcount(x: np.ndarray) -> np.ndarray:
    """Rank of each element among earlier equal elements (grouped 0,1,2,…)."""
    n = x.size
    order = np.argsort(x, kind="stable")
    xs = x[order]
    starts = np.flatnonzero(np.concatenate(([True], xs[1:] != xs[:-1])))
    lens = np.diff(np.concatenate((starts, [n])))
    rank_sorted = np.arange(n, dtype=np.int64) - np.repeat(starts, lens)
    out = np.empty(n, dtype=np.int64)
    out[order] = rank_sorted
    return out


def _precondition_vectorized(ftl: FTL) -> bool:
    """One-pass sequential footprint fill; False if the fill would GC."""
    F = ftl.n_lpns
    if F == 0:
        return True
    cfg = ftl.cfg
    ppb = ftl.pages_per_block
    planes = stripe_plane(cfg, np.arange(F, dtype=np.int64))
    counts = np.bincount(planes, minlength=ftl.n_planes)
    # k-th block-open in a plane sees ``blocks_per_plane - k`` free blocks;
    # a fill needing an open the oracle would GC at — its steady trigger
    # (free ≤ gc_threshold) or its emergency headroom guard (free < 2),
    # folded via max() like the epoch loop — is rare (footprint ≈ whole
    # device) and handled by fallback.
    opens = np.maximum(0, -(-counts // ppb) - 1)
    if np.any(ftl.blocks_per_plane - opens <= max(ftl.gc_threshold, 1)):
        return False
    rank = _cumcount(planes)
    ppn = planes * ftl.pages_per_plane + rank  # blocks open 0,1,2,… in order
    ftl.l2p[:] = ppn
    ftl.p2l[ppn] = np.arange(F, dtype=np.int64)
    per_blk = np.bincount(
        planes * ftl.blocks_per_plane + rank // ppb,
        minlength=ftl.n_planes * ftl.blocks_per_plane,
    ).reshape(ftl.n_planes, ftl.blocks_per_plane)
    ftl.written[:] = per_blk
    ftl.valid[:] = per_blk
    open_blk = np.maximum(counts - 1, 0) // ppb  # lazy-open: stays on the
    ftl.open_block[:] = open_blk  # last filled block even when it is full
    ftl.next_page[:] = counts - open_blk * ppb
    ftl.is_free[:] = (
        np.arange(ftl.blocks_per_plane)[None, :] > open_blk[:, None]
    )
    ftl._stripe = F
    return True


def _alloc_epoch(
    ftl: FTL, planes: np.ndarray, lpns: np.ndarray, rank: np.ndarray
) -> np.ndarray:
    """Allocate one GC-free run of host writes (in stream order) in one pass.

    ``rank`` is each write's per-plane rank *within this run* (the caller
    derives it from the stream-global cumcount, so no re-sort here).  The
    caller guarantees no allocation in this run opens a block at
    free ≤ gc_threshold, so block opens are pure pops of the wear-ordered
    free list and no state consulted here (erase counts, victim masks) can
    change mid-run.  Mirrors exactly what ``write_page`` would have done.
    """
    ppb = ftl.pages_per_block
    P, B = ftl.n_planes, ftl.blocks_per_plane
    n = planes.size
    slot = ftl.next_page[planes] + rank  # virtual slot past the open cursor
    counts = np.bincount(planes, minlength=P)
    end = ftl.next_page + counts
    n_open = np.maximum(0, -(-(end - ppb) // ppb))  # opens this run needs
    max_open = int(n_open.max()) if n else 0
    in_open = slot < ppb
    blk = np.where(in_open, ftl.open_block[planes], 0)
    off = np.where(in_open, slot, 0)
    if max_open > 0:
        # wear order = (erase_count, block id): popping the argmin free
        # block k times equals taking the first k of this lexsort
        free_tab = np.zeros((P, max_open), dtype=np.int64)
        for p in np.flatnonzero(n_open > 0):
            ids = np.flatnonzero(ftl.is_free[p])
            take = ids[np.lexsort((ids, ftl.erase_count[p, ids]))][: n_open[p]]
            free_tab[p, : take.size] = take
            ftl.is_free[p, take] = False
        over = slot - ppb
        fi = np.where(in_open, 0, over // ppb)
        blk = np.where(in_open, blk, free_tab[planes, fi])
        off = np.where(in_open, off, over % ppb)
        opened = n_open > 0
        ftl.open_block[opened] = free_tab[opened, n_open[opened] - 1]
    ppn = planes * ftl.pages_per_plane + blk * ppb + off
    ftl.next_page[:] = np.where(counts > 0, end - n_open * ppb, ftl.next_page)

    inc = np.bincount(planes * B + blk, minlength=P * B).reshape(P, B)
    ftl.written += inc
    ftl.valid += inc
    # out-of-place invalidation: the page each write supersedes is the
    # previous write to the same LPN in this run, else the pre-run mapping
    order = np.argsort(lpns, kind="stable")
    l_s, p_s = lpns[order], ppn[order]
    old_s = ftl.l2p[l_s]
    same = l_s[1:] == l_s[:-1]
    old_s[1:][same] = p_s[:-1][same]
    old = old_s[old_s >= 0]
    if old.size:
        dec = np.bincount(
            (old // ftl.pages_per_plane) * B
            + (old % ftl.pages_per_plane) // ppb,
            minlength=P * B,
        ).reshape(P, B)
        ftl.valid -= dec
    ftl.p2l[ppn] = lpns
    if old.size:
        ftl.p2l[old] = -1  # intra-run supersessions land after their set
    ftl.l2p[lpns] = ppn  # duplicate LPNs: numpy keeps the last write
    return ppn


def decompose_vectorized(
    cfg: SSDConfig,
    trace: Dict[str, np.ndarray],
    footprint_pages: int,
    overprovision: float = 1.28,
    seed: int = 0,
    resume: FTL | None = None,
    arrival_ticks: np.ndarray | None = None,
) -> Transactions:
    """Vectorized ``decompose_trace`` (preconditioned traces only).

    ``resume``/``arrival_ticks``: streaming-window continuation — reuse the
    carried FTL (no construction, no precondition; mutated in place) and
    take per-request arrival ticks as given (int64, already window-rebased)
    instead of deriving them from float microseconds.  Splitting a trace at
    any request boundary and resuming is bit-exact: epochs are deterministic
    wear-ordered pops, so forcing an epoch boundary at the split changes no
    allocation, and the carried L2P *is* the pre-window mapping reads
    forward-fill from.
    """
    if resume is not None:
        ftl = resume
    else:
        ftl = FTL(cfg, n_lpns=footprint_pages, overprovision=overprovision)
        if not _precondition_vectorized(ftl):
            for lpn in range(footprint_pages):  # tight geometry: oracle's GC
                ftl.write_page(lpn, None, 0)
    l2p0 = ftl.l2p.copy()  # mapping reads see when no stream write precedes

    arrival = np.asarray(trace["arrival_us"], dtype=np.float64)
    is_read = np.asarray(trace["is_read"], dtype=bool)
    offset = np.asarray(trace["offset_page"], dtype=np.int64)
    n_pg = np.asarray(trace["n_pages"], dtype=np.int64)
    n_req = int(len(arrival))
    if arrival_ticks is not None:
        t_req = np.asarray(arrival_ticks, dtype=np.int64)
    else:
        # same float64 op sequence as us_to_ticks: (us * 1e3) / TICK_NS, ceil
        t_req = np.ceil(arrival * 1e3 / TICK_NS).astype(np.int64)

    # request → page-op expansion (repeat/cumsum, no inner loop)
    T = int(n_pg.sum()) if n_req else 0
    req_of = np.repeat(np.arange(n_req, dtype=np.int64), n_pg)
    starts = np.cumsum(n_pg) - n_pg
    k = np.arange(T, dtype=np.int64) - np.repeat(starts, n_pg)
    lpn = (offset[req_of] + k) % footprint_pages
    t_op = t_req[req_of]
    rd = is_read[req_of]

    # ---- write path: epoch-vectorized, scalar only at GC triggers --------
    w_pos = np.flatnonzero(~rd)
    W = w_pos.size
    w_lpn = lpn[w_pos]
    w_t = t_op[w_pos]
    w_plane = stripe_plane(cfg, ftl._stripe + np.arange(W, dtype=np.int64))
    # stream-global per-plane rank, computed ONCE: each epoch's local rank
    # is this minus the count of writes that plane has already consumed, so
    # GC-heavy traces don't re-sort the whole remaining suffix per trigger
    w_rank = _cumcount(w_plane)
    consumed = np.zeros(ftl.n_planes, dtype=np.int64)
    w_ppn = np.empty(W, dtype=np.int64)
    gc_chunks: list = []  # (host op position, oracle's gc_out rows)
    at = 0
    while at < W:
        free_cnt = ftl.is_free.sum(axis=1)
        # pages each plane absorbs before a *risky* open — one the oracle
        # would GC at: its steady-state trigger (free ≤ gc_threshold) or its
        # emergency headroom guard (free < 2, hardcoded in _open_new_block);
        # max() folds both so a lowered gc_threshold can't skip the guard.
        # Cap = the open block's tail plus every safe open's full block.
        risk_free = max(ftl.gc_threshold, 1)
        cap = (ftl.pages_per_block - ftl.next_page) + np.maximum(
            0, free_cnt - risk_free
        ) * ftl.pages_per_block
        suffix = w_plane[at:]
        risky = w_rank[at:] >= (cap + consumed)[suffix]
        j = int(np.argmax(risky)) if risky.any() else int(suffix.size)
        if j:
            sl = slice(at, at + j)
            w_ppn[sl] = _alloc_epoch(
                ftl, w_plane[sl], w_lpn[sl],
                w_rank[sl] - consumed[w_plane[sl]],
            )
            np.add.at(consumed, w_plane[sl], 1)
            ftl._stripe += j
            at += j
        if at < W:  # the triggering write runs the oracle (GC and all)
            out: list = []
            ftl.write_page(int(w_lpn[at]), out, int(w_t[at]))
            w_ppn[at] = int(ftl.l2p[w_lpn[at]])
            if out:
                gc_chunks.append((int(w_pos[at]), out))
            consumed[w_plane[at]] += 1
            at += 1

    # ---- read path: pure L2P gather (last stream write wins, else the
    # preconditioned mapping) — a grouped forward-fill over (lpn, pos) -----
    r_pos = np.flatnonzero(rd)
    R = r_pos.size
    if R:
        pos_all = np.concatenate((w_pos, r_pos))
        lpn_all = np.concatenate((w_lpn, lpn[r_pos]))
        val_all = np.concatenate((w_ppn, np.full(R, -1, dtype=np.int64)))
        is_wr = np.zeros(W + R, dtype=bool)
        is_wr[:W] = True
        order = np.lexsort((pos_all, lpn_all))
        lpn_s = lpn_all[order]
        val_s = val_all[order]
        wr_s = is_wr[order]
        idx = np.arange(W + R, dtype=np.int64)
        last_wr = np.maximum.accumulate(np.where(wr_s, idx, -1))
        lw = np.clip(last_wr, 0, None)
        hit = (last_wr >= 0) & (lpn_s[lw] == lpn_s)
        ppn_s = np.where(hit, val_s[lw], l2p0[lpn_s])
        inv = np.empty(W + R, dtype=np.int64)
        inv[order] = idx
        r_ppn = ppn_s[inv[W:]]
        if np.any(r_ppn < 0):  # precondition guarantees full coverage
            raise RuntimeError("read hit an unmapped LPN despite precondition")
    else:
        r_ppn = np.zeros(0, dtype=np.int64)

    # ---- assemble rows in the oracle's insertion order -------------------
    tick = np.empty(T, dtype=np.int64)
    kind = np.where(rd, KIND_READ, KIND_WRITE).astype(np.int64)
    plane_col = np.empty(T, dtype=np.int64)
    tick[:] = t_op
    plane_col[w_pos] = w_ppn // ftl.pages_per_plane
    plane_col[r_pos] = r_ppn // ftl.pages_per_plane
    nbytes = np.full(T, cfg.page_bytes, dtype=np.int64)
    req_col = req_of
    g_host = np.arange(T, dtype=np.int64)
    sub_host = np.zeros(T, dtype=np.int64)
    if gc_chunks:  # GC rows slot directly after their triggering host write
        g_gc = np.concatenate(
            [np.full(len(out), g, dtype=np.int64) for g, out in gc_chunks]
        )
        sub_gc = np.concatenate(
            [np.arange(1, len(out) + 1, dtype=np.int64) for _, out in gc_chunks]
        )
        flat = [row for _, out in gc_chunks for row in out]
        gc_arr = np.asarray(flat, dtype=np.int64)  # (t, kind, plane, 0, -1)
        tick = np.concatenate((tick, gc_arr[:, 0]))
        kind = np.concatenate((kind, gc_arr[:, 1]))
        plane_col = np.concatenate((plane_col, gc_arr[:, 2]))
        nbytes = np.concatenate((nbytes, gc_arr[:, 3]))
        req_col = np.concatenate((req_col, gc_arr[:, 4]))
        g_all = np.concatenate((g_host, g_gc))
        sub_all = np.concatenate((sub_host, sub_gc))
        ins = np.lexsort((sub_all, g_all))
    else:
        ins = g_host
    arr = np.stack((tick, kind, plane_col, nbytes, req_col), axis=1)[ins]
    return to_transactions(cfg, arr, ftl, n_req)
