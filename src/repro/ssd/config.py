"""SSD configurations (paper Table 1) and the power model (§6.4/§6.6).

All simulator time is integer *ticks* of 10 ns (``TICK_NS``): every latency in
Table 1 is a multiple of 10 ns, and integer ticks keep the jitted scan exact
with no float64 / x64 global-config requirements. int32 ticks span ±21 s;
traces longer than that replay through the chunked streaming engine
(``ssd/stream.py``), which rebases each window into the int32 budget and
carries FTL + in-flight simulator state across boundaries bit-exactly.
"""
from __future__ import annotations

import dataclasses
import math

TICK_NS = 10  # one simulator tick = 10 ns


def ns_to_ticks(ns: float) -> int:
    return int(math.ceil(ns / TICK_NS))


def us_to_ticks(us: float) -> int:
    return ns_to_ticks(us * 1e3)


@dataclasses.dataclass(frozen=True)
class PowerModel:
    """Component powers. Paper-sourced where the paper gives numbers (§6.6:
    router 0.241 mW; link 1.08 mW during a transfer, 90% below the shared bus
    ⇒ bus ≈ 10.8 mW while driven). Flash-die/static powers are calibrated
    estimates (Z-SSD-class device; documented in DESIGN.md): average SSD power
    is dominated by the controller+DRAM static term, which is what makes the
    paper's ~61% energy saving track the ~62% execution-time saving."""

    static_w: float = 1.50  # controller + DRAM + interface, always on
    die_read_w: float = 0.012  # per plane during tR
    die_prog_w: float = 0.018  # per plane during tPROG
    die_erase_w: float = 0.020  # per plane during tBERS
    bus_active_w: float = 0.0108  # per shared channel while driven (§6.6)
    link_active_w: float = 0.00108  # per mesh link while reserved (§6.6)
    router_w: float = 0.000241  # per router, always on (§6.6)


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    name: str
    # --- flash array geometry (Table 1) ---
    rows: int = 8  # flash controllers / channels
    cols: int = 8  # chips per channel (= mesh columns)
    dies_per_chip: int = 1
    planes_per_die: int = 2
    pages_per_block: int = 768
    page_bytes: int = 4096
    # --- latencies ---
    t_read_us: float = 3.0  # tR
    t_prog_us: float = 100.0  # tPROG
    t_erase_us: float = 1000.0  # tBERS
    cmd_ns: float = 10.0  # command transfer on a free path (§3.1)
    # --- interconnect ---
    chan_gbps: float = 1.2  # shared-channel I/O rate, GB/s (Table 1)
    link_ghz: float = 1.0  # Venice: 8-bit links at 1 GHz ⇒ 1 B/ns (Table 1)
    scout_flit_ns: float = 2.0  # 2 x 8-bit scout flits per hop at 1 GHz
    # Per-phase protocol overhead on the legacy (non-packetized) shared bus:
    # ONFI command/address/status cycles + arbitration.  Calibrated from the
    # paper's own §3.1 numbers: a 4KB transfer takes 4 us on the 1.2 GB/s
    # channel (4096 B / 1.2 GB/s = 3.41 us) => ~0.59 us protocol overhead.
    # Paid by baseline and the ideal SSD (same channel protocol, just private);
    # NOT paid by pSSD/pnSSD (packetized [15]) or the mesh designs.
    bus_protocol_ovh_ns: float = 590.0
    # FTL stripe chunk (pages): consecutive LBAs fill one plane for a chunk
    # before striping on (superpage allocation, industry standard); this is
    # what makes sequential bursts channel-skewed — the paper's conflicts.
    chunk_pages: int = 8
    power: PowerModel = dataclasses.field(default_factory=PowerModel)

    # ---- derived ----
    @property
    def n_chips(self) -> int:
        return self.rows * self.cols

    @property
    def n_planes(self) -> int:
        return self.n_chips * self.dies_per_chip * self.planes_per_die

    @property
    def t_read(self) -> int:
        return us_to_ticks(self.t_read_us)

    @property
    def t_prog(self) -> int:
        return us_to_ticks(self.t_prog_us)

    @property
    def t_erase(self) -> int:
        return us_to_ticks(self.t_erase_us)

    @property
    def t_cmd(self) -> int:
        return max(1, ns_to_ticks(self.cmd_ns))

    @property
    def t_bus_ovh(self) -> int:
        return ns_to_ticks(self.bus_protocol_ovh_ns)

    def bus_xfer_ticks(self, nbytes: int, bw_mult: float = 1.0) -> int:
        """Shared-channel transfer time for ``nbytes`` (pSSD: bw_mult=2)."""
        ns = nbytes / (self.chan_gbps * bw_mult)  # GB/s == B/ns
        return max(0, ns_to_ticks(ns))

    def link_xfer_ns(self, nbytes: int) -> float:
        """Per Eq. (1), excluding the +distance term (added at runtime)."""
        return nbytes / self.link_ghz  # 8-bit @ 1 GHz = 1 B/ns


def perf_optimized(**over) -> SSDConfig:
    """Samsung Z-NAND-based performance-optimized config (Table 1)."""
    kw = dict(
        name="perf",
        page_bytes=4096,
        pages_per_block=768,
        t_read_us=3.0,
        t_prog_us=100.0,
        t_erase_us=1000.0,
    )
    kw.update(over)
    return SSDConfig(**kw)


def cost_optimized(**over) -> SSDConfig:
    """Samsung PM9A3-based cost-optimized config (Table 1): 3D TLC."""
    kw = dict(
        name="cost",
        page_bytes=16384,
        pages_per_block=768,
        t_read_us=45.0,
        t_prog_us=650.0,
        t_erase_us=3500.0,
    )
    kw.update(over)
    return SSDConfig(**kw)
