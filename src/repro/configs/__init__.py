"""Assigned architecture configs (exact values from the assignment block).

``get_config(arch)`` returns the full-size ``LMConfig``; ``get_smoke(arch)``
a reduced same-family variant for CPU tests; ``input_specs(arch, shape)``
ShapeDtypeStruct stand-ins for every model input of a (arch x shape) cell;
``SHAPES`` / ``applicable_shapes(arch)`` encode the skip rules (long_500k
only for sub-quadratic archs; decode shapes for decoder-bearing archs).
"""
from repro.configs.archs import (
    ARCHS,
    SHAPES,
    applicable_shapes,
    get_config,
    get_smoke,
    input_specs,
    shape_skip_reason,
)

__all__ = [
    "ARCHS", "SHAPES", "applicable_shapes", "get_config", "get_smoke",
    "input_specs", "shape_skip_reason",
]
