"""The 10 assigned architectures — exact configuration values.

Sources are the assignment block (verbatim); [source; verified-tier] noted
per arch.  Where the assignment is silent (head_dim, rope theta, window
sizes, MoE first-dense layers) we use the published model-card values and
note them inline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm import LMConfig

# shape name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

_BF16 = jnp.bfloat16


def _mk(**kw) -> LMConfig:
    kw.setdefault("dtype", _BF16)
    kw.setdefault("param_dtype", _BF16)
    return LMConfig(**kw)


ARCHS = {
    # [hybrid] Mamba2 + shared attn blocks [arXiv:2411.15242; hf]
    "zamba2-2.7b": _mk(
        name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
        n_heads=32, n_kv=32, d_ff=10240, vocab=32000, ssm_state=64,
        hybrid_period=6,
    ),
    # [moe] MLA kv_lora=512, 2 shared + 64 routed top-6 [arXiv:2405.04434; hf]
    # (assignment header says "64e top-6"; the detail line's "160 routed" is
    # the V2-full config — we follow the 64-expert Lite header.)
    "deepseek-v2-lite-16b": _mk(
        name="deepseek-v2-lite-16b", family="moe", n_layers=27, d_model=2048,
        n_heads=16, n_kv=16, d_ff=10944, vocab=102400, head_dim=128,
        moe_experts=64, moe_top_k=6, moe_ff=1408, moe_shared=2,
        moe_first_dense=1, mla_kv_rank=512, mla_rope_dim=64,
    ),
    # [moe] Kimi K2 — trillion-param MoE [arXiv:2501.kimi2; unverified]
    "kimi-k2-1t-a32b": _mk(
        name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
        n_heads=64, n_kv=8, d_ff=18432, vocab=163840, head_dim=112,
        moe_experts=384, moe_top_k=8, moe_ff=2048, moe_shared=1,
        moe_first_dense=1,
    ),
    # [ssm] SSD (state-space duality) [arXiv:2405.21060; unverified]
    "mamba2-130m": _mk(
        name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
        n_heads=1, n_kv=1, d_ff=0, vocab=50280, ssm_state=128,
    ),
    # [dense] GQA, QKV bias [arXiv:2407.10671; hf]
    "qwen2-0.5b": _mk(
        name="qwen2-0.5b", family="dense", n_layers=24, d_model=896,
        n_heads=14, n_kv=2, d_ff=4864, vocab=151936, qkv_bias=True,
        rope_theta=1e6,
    ),
    # [dense] [hf:mistralai/Mistral-Large-Instruct-2407; unverified]
    "mistral-large-123b": _mk(
        name="mistral-large-123b", family="dense", n_layers=88, d_model=12288,
        n_heads=96, n_kv=8, d_ff=28672, vocab=32768, head_dim=128,
        rope_theta=1e6,
    ),
    # [dense] GQA [hf:ibm-granite/granite-3.0-2b-base; hf]
    "granite-3-2b": _mk(
        name="granite-3-2b", family="dense", n_layers=40, d_model=2048,
        n_heads=32, n_kv=8, d_ff=8192, vocab=49155,
    ),
    # [dense] local+global alternating, logit softcap [arXiv:2408.00118; hf]
    "gemma2-2b": _mk(
        name="gemma2-2b", family="gemma", n_layers=26, d_model=2304,
        n_heads=8, n_kv=4, d_ff=9216, vocab=256000, head_dim=256,
        window=4096, attn_softcap=50.0, final_softcap=30.0,
    ),
    # [vlm] cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision; unverified]
    "llama-3.2-vision-90b": _mk(
        name="llama-3.2-vision-90b", family="vlm", n_layers=100, d_model=8192,
        n_heads=64, n_kv=8, d_ff=28672, vocab=128256, head_dim=128,
        rope_theta=5e5, cross_attn_period=5, vision_dim=1280,
        n_img_tokens=1601,
    ),
    # [audio] enc-dec, conv frontend (stub) [arXiv:2212.04356; unverified]
    "whisper-base": _mk(
        name="whisper-base", family="audio", n_layers=6, d_model=512,
        n_heads=8, n_kv=8, d_ff=2048, vocab=51865, enc_layers=6,
        n_audio_frames=1500,
    ),
}

# gemma2-2b has 26 layers (13 local/global pairs) — n_layers must be even ✓


_SMOKE_OVER = dict(dtype=jnp.float32, param_dtype=jnp.float32, remat=False)


def get_config(arch: str) -> LMConfig:
    return ARCHS[arch]


def get_smoke(arch: str) -> LMConfig:
    """Reduced same-family config: small widths/layers/experts/vocab."""
    c = ARCHS[arch]
    import dataclasses

    def ov(**kw):
        kw.update(_SMOKE_OVER)
        return dataclasses.replace(c, **kw)

    if c.family == "moe":
        return ov(n_layers=3, d_model=64, n_heads=4, n_kv=4 if not c.mla_kv_rank else 4,
                  head_dim=16, d_ff=128, vocab=256, moe_experts=8, moe_top_k=2,
                  moe_ff=32, moe_shared=min(c.moe_shared, 1), moe_first_dense=1,
                  mla_kv_rank=32 if c.mla_kv_rank else None, mla_rope_dim=16)
    if c.family == "ssm":
        return ov(n_layers=3, d_model=128, vocab=256, ssm_state=16)
    if c.family == "hybrid":
        return ov(n_layers=6, d_model=128, n_heads=4, n_kv=4, head_dim=32,
                  d_ff=256, vocab=256, ssm_state=16, hybrid_period=3)
    if c.family == "vlm":
        return ov(n_layers=10, d_model=64, n_heads=4, n_kv=2, head_dim=16,
                  d_ff=128, vocab=256, cross_attn_period=5, vision_dim=48,
                  n_img_tokens=17)
    if c.family == "audio":
        return ov(n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16,
                  d_ff=128, vocab=256, enc_layers=2, n_audio_frames=32)
    if c.family == "gemma":
        return ov(n_layers=4, d_model=64, n_heads=4, n_kv=2, head_dim=16,
                  d_ff=128, vocab=256, window=16)
    return ov(n_layers=3, d_model=64, n_heads=4, n_kv=2, head_dim=16,
              d_ff=128, vocab=256)


def shape_skip_reason(arch: str, shape: str) -> str | None:
    """None if the (arch x shape) cell runs; else why it is skipped."""
    c = ARCHS[arch]
    if shape == "long_500k" and c.family not in ("ssm", "hybrid"):
        return (
            "long_500k needs sub-quadratic attention; "
            f"{arch} ({c.family}) has full-attention layers"
        )
    return None


def applicable_shapes(arch: str):
    return [s for s in SHAPES if shape_skip_reason(arch, s) is None]


def input_specs(arch: str, shape: str, batch_override: int | None = None):
    """ShapeDtypeStruct stand-ins for every model input of the cell.

    train/prefill: the full token batch (+ modality embeddings).
    decode: one token per sequence (+ pos scalar); the KV/SSM cache specs
    come from ``init_decode_cache`` via eval_shape in the dry-run driver.
    """
    cfg = ARCHS[arch]
    seq, batch, kind = SHAPES[shape]
    if batch_override:
        batch = batch_override
    i32 = jnp.int32
    if kind in ("train", "prefill"):
        batch_specs = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32)}
        if cfg.family == "vlm":
            batch_specs["images"] = jax.ShapeDtypeStruct(
                (batch, cfg.n_img_tokens, cfg.vision_dim), jnp.bfloat16
            )
        if cfg.family == "audio":
            batch_specs["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16
            )
        return batch_specs
    # decode: one new token against a seq_len-deep context
    return {
        "token": jax.ShapeDtypeStruct((batch,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }
