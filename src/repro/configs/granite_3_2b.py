"""Config module for --arch granite-3-2b (values in repro.configs.archs)."""
from repro.configs.archs import ARCHS, get_smoke, input_specs, applicable_shapes

ARCH_ID = "granite-3-2b"
CONFIG = ARCHS[ARCH_ID]
SMOKE = get_smoke(ARCH_ID)


def specs(shape: str):
    return input_specs(ARCH_ID, shape)
