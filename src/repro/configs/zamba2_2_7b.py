"""Config module for --arch zamba2-2.7b (values in repro.configs.archs)."""
from repro.configs.archs import ARCHS, get_smoke, input_specs, applicable_shapes

ARCH_ID = "zamba2-2.7b"
CONFIG = ARCHS[ARCH_ID]
SMOKE = get_smoke(ARCH_ID)


def specs(shape: str):
    return input_specs(ARCH_ID, shape)
