"""LM substrate: functional JAX model definitions for the assigned archs.

Everything is plain pytrees + pure functions (init/apply), dtype-explicit,
with ``lax.scan`` over (groups of) layers so a 100-layer model compiles as
one program.  Decode paths carry explicit KV / SSM-state caches.
"""
from repro.models.layers import (
    ModelDims,
    attention,
    attention_decode,
    init_attention,
    init_mlp,
    init_rmsnorm,
    mlp,
    rmsnorm,
    rope,
)
from repro.models.lm import (
    LMConfig,
    init_lm,
    lm_apply,
    lm_decode_step,
    lm_loss,
    init_decode_cache,
)

__all__ = [
    "ModelDims", "attention", "attention_decode", "init_attention",
    "init_mlp", "init_rmsnorm", "mlp", "rmsnorm", "rope",
    "LMConfig", "init_lm", "lm_apply", "lm_decode_step", "lm_loss",
    "init_decode_cache",
]
