"""Core transformer building blocks (functional, dtype-explicit).

Conventions: params are nested dicts of jnp arrays; ``init_*`` take an
``rng`` and dims; ``apply`` functions are pure.  Activations flow in
``cfg.dtype`` (bf16 for dry-runs, f32 for CPU smoke tests); params are
created in ``param_dtype``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelDims:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    qkv_bias: bool = False
    window: Optional[int] = None  # sliding-window attention (gemma local)
    softcap: Optional[float] = None  # logit soft-capping (gemma)
    rope_theta: float = 10000.0
    mlp_act: str = "silu"  # silu (swiglu) | gelu (geglu) | gelu_mlp (whisper)
    # §Perf hillclimb: grouped-query attention einsum — contract kv heads
    # directly ([B,S,K,G,D] x [B,T,K,D]) instead of materializing the
    # H-expanded K/V (whose jnp.repeat forces a reshard of sharded caches)
    gqa_grouped: bool = False
    # MLA (deepseek): kv low-rank compression
    mla_kv_rank: Optional[int] = None
    mla_rope_dim: int = 64


def _dense(rng, in_dim, out_dim, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(rng, (in_dim, out_dim), dtype=jnp.float32) * scale).astype(dtype)


def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p, x, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta=10000.0):
    """Rotary embedding. x [..., S, H, D], positions [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA / MLA)
# ---------------------------------------------------------------------------


def init_attention(rng, dims: ModelDims, dtype):
    ks = jax.random.split(rng, 8)
    d, H, K, hd = dims.d_model, dims.n_heads, dims.n_kv, dims.head_dim
    if dims.mla_kv_rank:  # DeepSeek MLA
        r, rd = dims.mla_kv_rank, dims.mla_rope_dim
        p = {
            "wq": _dense(ks[0], d, H * (hd + rd), dtype),
            "w_dkv": _dense(ks[1], d, r, dtype),
            "w_kr": _dense(ks[2], d, rd, dtype),  # shared rope key
            "w_uk": _dense(ks[3], r, H * hd, dtype),
            "w_uv": _dense(ks[4], r, H * hd, dtype),
            "wo": _dense(ks[5], H * hd, d, dtype),
            "norm_ckv": init_rmsnorm(r, dtype),
        }
        return p
    p = {
        "wq": _dense(ks[0], d, H * hd, dtype),
        "wk": _dense(ks[1], d, K * hd, dtype),
        "wv": _dense(ks[2], d, K * hd, dtype),
        "wo": _dense(ks[3], H * hd, d, dtype),
    }
    if dims.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    return p


def _mask_block(Sq, T, q0, causal, window):
    """[Sq, T] additive mask for a query block starting at position q0."""
    qi = jnp.arange(Sq)[:, None] + q0
    kj = jnp.arange(T)[None, :]
    ok = jnp.ones((Sq, T), bool)
    if causal:
        ok &= kj <= qi
    if window is not None:
        ok &= kj > qi - window
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def _sdpa_block(q, k, v, softcap, causal, window, q0):
    """q [B,Sq,H,D], k/v [B,T,Hk,D] with Hk == H (pre-expanded) or Hk == K
    (grouped GQA: contract kv heads directly, no materialized expansion)."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    B, Sq, H, D = q.shape
    K = k.shape[2]
    mask = _mask_block(Sq, k.shape[1], q0, causal, window)
    if K != H:  # grouped path
        G = H // K
        qg = q.reshape(B, Sq, K, G, D)
        logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
        logits = logits * scale
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        logits = logits + mask
        w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgst,btkd->bskgd", w, v)
        return out.reshape(B, Sq, H, v.shape[-1])
    logits = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = logits + mask
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", w, v)


Q_CHUNK = 512  # flash-style query chunking threshold / block size


def _sdpa(q, k, v, softcap, causal=True, window=None):
    """Memory-aware SDPA: for long sequences, scan over query chunks so the
    peak logits buffer is [B,H,Q_CHUNK,T] instead of [B,H,S,T] (and the mask
    is built per block — never a full [S,T] tensor).  The scan body is
    rematerialized in the backward pass."""
    B, S, H, D = q.shape
    Dv = v.shape[-1]  # may differ from D (MLA: q/k 192, v 128)
    if S <= Q_CHUNK or S % Q_CHUNK != 0:
        return _sdpa_block(q, k, v, softcap, causal, window, 0)
    n = S // Q_CHUNK
    qc = q.reshape(B, n, Q_CHUNK, H, D).transpose(1, 0, 2, 3, 4)

    def body(_, xs):
        qi, i = xs
        o = _sdpa_block(qi, k, v, softcap, causal, window, i * Q_CHUNK)
        return None, o

    body = jax.checkpoint(body)
    _, out = jax.lax.scan(body, None, (qc, jnp.arange(n)))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Dv)


def _expand_kv(k, n_heads):
    """[B,T,K,D] -> [B,T,H,D] by repeating each kv head H/K times."""
    B, T, K, D = k.shape
    rep = n_heads // K
    return jnp.repeat(k, rep, axis=2) if rep > 1 else k


def attention(p, dims: ModelDims, x, positions, cross_kv=None):
    """Full (training / prefill) attention. x [B,S,d].

    ``cross_kv``: (k_src, v_src) activations [B,T,d_src] for cross-attention
    (whisper decoder, VLM image layers) — no causal mask in that case.
    """
    B, S, d = x.shape
    H, hd = dims.n_heads, dims.head_dim
    if dims.mla_kv_rank:
        return _mla_attention(p, dims, x, positions)
    q = x @ p["wq"]
    if dims.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, S, H, hd)
    if cross_kv is None:
        k = x @ p["wk"]
        v = x @ p["wv"]
        if dims.qkv_bias:
            k, v = k + p["bk"], v + p["bv"]
        k = k.reshape(B, S, dims.n_kv, hd)
        v = v.reshape(B, S, dims.n_kv, hd)
        q = rope(q, positions, dims.rope_theta)
        k = rope(k, positions, dims.rope_theta)
        causal, window = True, dims.window
    else:
        src_k, src_v = cross_kv
        T = src_k.shape[1]
        k = (src_k @ p["wk"]).reshape(B, T, dims.n_kv, hd)
        v = (src_v @ p["wv"]).reshape(B, T, dims.n_kv, hd)
        causal, window = False, None
    if not dims.gqa_grouped:
        k, v = _expand_kv(k, H), _expand_kv(v, H)
    out = _sdpa(q, k, v, dims.softcap, causal=causal, window=window)
    return out.reshape(B, S, H * hd) @ p["wo"]


def _mla_attention(p, dims: ModelDims, x, positions):
    """DeepSeek-V2 Multi-head Latent Attention (training/prefill)."""
    B, S, d = x.shape
    H, hd, rd = dims.n_heads, dims.head_dim, dims.mla_rope_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd + rd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = rope(q_rope, positions, dims.rope_theta)
    c_kv = rmsnorm(p["norm_ckv"], x @ p["w_dkv"])  # [B,S,r]
    k_rope = rope((x @ p["w_kr"])[:, :, None, :], positions, dims.rope_theta)
    k_nope = (c_kv @ p["w_uk"]).reshape(B, S, H, hd)
    v = (c_kv @ p["w_uv"]).reshape(B, S, H, hd)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, rd))], -1)
    qfull = jnp.concatenate([q_nope, q_rope], -1)
    out = _sdpa(qfull, k, v, dims.softcap, causal=True)
    return out.reshape(B, S, H * hd) @ p["wo"]


# --- decode (KV cache) ------------------------------------------------------


def init_kv_cache(dims: ModelDims, B, S_max, dtype):
    if dims.mla_kv_rank:
        return {
            "ckv": jnp.zeros((B, S_max, dims.mla_kv_rank), dtype),
            "kr": jnp.zeros((B, S_max, dims.mla_rope_dim), dtype),
        }
    return {
        "k": jnp.zeros((B, S_max, dims.n_kv, dims.head_dim), dtype),
        "v": jnp.zeros((B, S_max, dims.n_kv, dims.head_dim), dtype),
    }


def attention_decode(p, dims: ModelDims, x, cache, pos):
    """One-token decode. x [B,1,d]; pos scalar int32 (current index);
    cache holds S_max entries (only [0, pos) + the new one are live)."""
    B = x.shape[0]
    H, hd = dims.n_heads, dims.head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    if dims.mla_kv_rank:
        rd = dims.mla_rope_dim
        q = (x @ p["wq"]).reshape(B, 1, H, hd + rd)
        q_nope, q_rope = q[..., :hd], q[..., hd:]
        q_rope = rope(q_rope, positions, dims.rope_theta)
        c_new = rmsnorm(p["norm_ckv"], x @ p["w_dkv"])  # [B,1,r]
        kr_new = rope((x @ p["w_kr"])[:, :, None, :], positions, dims.rope_theta)
        cache = {
            "ckv": jax.lax.dynamic_update_slice(
                cache["ckv"], c_new.astype(cache["ckv"].dtype), (0, pos, 0)
            ),
            "kr": jax.lax.dynamic_update_slice(
                cache["kr"], kr_new[:, :, 0].astype(cache["kr"].dtype), (0, pos, 0)
            ),
        }
        S_max = cache["ckv"].shape[1]
        # baseline: expand keys/values out of the latent cache (correct but
        # re-materializes K/V; the matrix-absorbed form that keeps attention
        # entirely in the rank-r latent space is a §Perf hillclimb iteration)
        k_nope = (cache["ckv"] @ p["w_uk"]).reshape(B, S_max, H, hd)
        v = (cache["ckv"] @ p["w_uv"]).reshape(B, S_max, H, hd)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(cache["kr"][:, :, None, :], (B, S_max, H, rd))],
            -1,
        )
        qfull = jnp.concatenate([q_nope, q_rope], -1)
        out = _sdpa_block(qfull, k, v, dims.softcap, True, None, pos)
        return out.reshape(B, 1, H * hd) @ p["wo"], cache

    q = x @ p["wq"]
    k_new = x @ p["wk"]
    v_new = x @ p["wv"]
    if dims.qkv_bias:
        q, k_new, v_new = q + p["bq"], k_new + p["bk"], v_new + p["bv"]
    q = rope(q.reshape(B, 1, H, hd), positions, dims.rope_theta)
    k_new = rope(k_new.reshape(B, 1, dims.n_kv, hd), positions, dims.rope_theta)
    v_new = v_new.reshape(B, 1, dims.n_kv, hd)
    cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0, 0)
        ),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0, 0)
        ),
    }
    kc, vc = cache["k"].astype(q.dtype), cache["v"].astype(q.dtype)
    if not dims.gqa_grouped:
        kc, vc = _expand_kv(kc, H), _expand_kv(vc, H)
    out = _sdpa_block(q, kc, vc, dims.softcap, True, dims.window, pos)
    return out.reshape(B, 1, H * hd) @ p["wo"], cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(rng, dims: ModelDims, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    d, ff = dims.d_model, dims.d_ff
    if dims.mlp_act == "gelu_mlp":  # plain 2-layer MLP (whisper)
        return {"w1": _dense(k1, d, ff, dtype), "w2": _dense(k2, ff, d, dtype)}
    return {
        "wg": _dense(k1, d, ff, dtype),
        "wu": _dense(k2, d, ff, dtype),
        "wd": _dense(k3, ff, d, dtype),
    }


def mlp(p, dims: ModelDims, x):
    if dims.mlp_act == "gelu_mlp":
        return jax.nn.gelu(x @ p["w1"]) @ p["w2"]
    act = jax.nn.silu if dims.mlp_act == "silu" else jax.nn.gelu
    return (act(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]
