"""Mamba2 block — SSD (state-space duality) chunked scan [arXiv:2405.21060].

Layout follows the reference Mamba2: in_proj -> (z, xBC, dt); causal depthwise
conv over xBC; scalar-per-head A; SSD recurrence over heads of dim P with
state size N:

    state_t = exp(dt_t A) * state_{t-1} + dt_t * x_t B_t^T        [P, N]
    y_t     = state_t C_t + D x_t

``mamba2_apply`` uses the chunked SSD algorithm (intra-chunk quadratic form +
inter-chunk state scan — MXU-friendly, O(S·Q) not O(S^2)); ``mamba2_ref`` is
the naive per-step recurrent oracle; ``mamba2_step`` is the O(1) decode step
(this is what makes ``long_500k`` decode constant-memory for SSM archs).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _dense, init_rmsnorm, rmsnorm


@dataclasses.dataclass(frozen=True)
class MambaDims:
    d_model: int
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 64
    # §Perf hillclimb: carry the intra-chunk attention-like tensors (CB,
    # decay, dtx — the memory-roofline dominators, O(B·S·Q·h)) in bf16 with
    # f32 accumulation; inter-chunk state stays f32
    ssd_bf16: bool = False

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def d_xbc(self) -> int:
        return self.d_inner + 2 * self.d_state  # x, B, C (single group)


def init_mamba2(rng, md: MambaDims, dtype):
    ks = jax.random.split(rng, 6)
    d_in_proj = 2 * md.d_inner + 2 * md.d_state + md.n_heads  # z,xBC,dt
    return {
        "in_proj": _dense(ks[0], md.d_model, d_in_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (md.d_conv, md.d_xbc), jnp.float32)
                   * 0.5).astype(dtype),
        "conv_b": jnp.zeros((md.d_xbc,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, md.n_heads)).astype(jnp.float32),
        "D": jnp.ones((md.n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((md.n_heads,), jnp.float32),
        "norm": init_rmsnorm(md.d_inner, dtype),
        "out_proj": _dense(ks[2], md.d_inner, md.d_model, dtype),
    }


def _split_in_proj(p, md: MambaDims, x):
    proj = x @ p["in_proj"]
    z = proj[..., : md.d_inner]
    xbc = proj[..., md.d_inner: md.d_inner + md.d_xbc]
    dt = proj[..., md.d_inner + md.d_xbc:]
    return z, xbc, dt


def _conv_full(p, md: MambaDims, xbc):
    """Causal depthwise conv over the sequence. xbc [B,S,d_xbc]."""
    B, S, C = xbc.shape
    pad = jnp.pad(xbc, ((0, 0), (md.d_conv - 1, 0), (0, 0)))
    w = p["conv_w"].astype(xbc.dtype)  # [K, C]
    out = sum(
        pad[:, k: k + S, :] * w[k][None, None, :] for k in range(md.d_conv)
    )
    return jax.nn.silu(out + p["conv_b"].astype(xbc.dtype))


def _ssd_inputs(p, md: MambaDims, xbc_conv, dt):
    B, S, _ = xbc_conv.shape
    x = xbc_conv[..., : md.d_inner].reshape(B, S, md.n_heads, md.head_dim)
    Bm = xbc_conv[..., md.d_inner: md.d_inner + md.d_state]
    Cm = xbc_conv[..., md.d_inner + md.d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,h]
    A = -jnp.exp(p["A_log"])  # [h], negative
    return x.astype(jnp.float32), Bm.astype(jnp.float32), Cm.astype(jnp.float32), dt, A


def mamba2_ref(p, md: MambaDims, x_in):
    """Naive O(S) recurrent oracle (f32). x_in [B,S,d_model]."""
    z, xbc, dt = _split_in_proj(p, md, x_in)
    xbc = _conv_full(p, md, xbc)
    x, Bm, Cm, dt, A = _ssd_inputs(p, md, xbc, dt)
    B, S, h, P = x.shape
    N = md.d_state

    def step(state, inp):
        xt, bt, ct, dtt = inp  # [B,h,P], [B,N], [B,N], [B,h]
        a = jnp.exp(dtt * A[None, :])  # [B,h]
        state = state * a[..., None, None] + jnp.einsum(
            "bhp,bn,bh->bhpn", xt, bt, dtt
        )
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    state0 = jnp.zeros((B, h, P, N), jnp.float32)
    xs = (
        x.transpose(1, 0, 2, 3),
        Bm.transpose(1, 0, 2),
        Cm.transpose(1, 0, 2),
        dt.transpose(1, 0, 2),
    )
    _, ys = jax.lax.scan(step, state0, xs)
    y = ys.transpose(1, 0, 2, 3) + x * p["D"][None, None, :, None]
    return _finish(p, md, y, z, x_in.dtype)


def _finish(p, md: MambaDims, y, z, dtype):
    B, S = y.shape[0], y.shape[1]
    y = y.reshape(B, S, md.d_inner).astype(dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y)
    return y @ p["out_proj"]


def mamba2_apply(p, md: MambaDims, x_in):
    """Chunked SSD (training/prefill). x_in [B,S,d_model]; sequences not
    divisible by the chunk are right-padded (causal — padding cannot affect
    the sliced-back outputs)."""
    S_in = x_in.shape[1]
    Q = min(md.chunk, S_in)
    pad = (-S_in) % Q
    if pad:
        x_in = jnp.pad(x_in, ((0, 0), (0, pad), (0, 0)))
    z, xbc, dt = _split_in_proj(p, md, x_in)
    xbc = _conv_full(p, md, xbc)
    x, Bm, Cm, dt, A = _ssd_inputs(p, md, xbc, dt)
    B, S, h, P = x.shape
    N = md.d_state
    C_ = S // Q

    xc = x.reshape(B, C_, Q, h, P)
    bc = Bm.reshape(B, C_, Q, N)
    cc = Cm.reshape(B, C_, Q, N)
    dtc = dt.reshape(B, C_, Q, h)

    loga = dtc * A[None, None, None, :]  # [B,C,Q,h]
    cum = jnp.cumsum(loga, axis=2)  # inclusive
    dtx = xc * dtc[..., None]  # [B,C,Q,h,P]

    # intra-chunk: y_i += C_i·B_j (prod_{j<k<=i} a) dt_j x_j, j<=i
    # §Perf H1: with ssd_bf16 every O(B·S·Q·h)-sized intermediate (the
    # memory-roofline dominators: the decay matrix, CB, M, dtx) is *born*
    # bf16 — the small [B,C,Q,h] cumsum stays f32, matmuls accumulate f32.
    wt = jnp.bfloat16 if md.ssd_bf16 else jnp.float32
    cum_w = cum.astype(wt)
    CB = jnp.einsum("bcin,bcjn->bcij", cc.astype(wt), bc.astype(wt),
                    preferred_element_type=wt)
    decay = jnp.exp(
        jnp.clip(cum_w[:, :, :, None, :] - cum_w[:, :, None, :, :],
                 -30.0, 0.0)
    )  # [B,C,i,j,h] in wt
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    M = CB[..., None] * decay * tri[None, None, :, :, None]
    dtx_w = (xc.astype(wt) * dtc.astype(wt)[..., None])
    y_intra = jnp.einsum(
        "bcijh,bcjhp->bcihp", M, dtx_w,
        preferred_element_type=jnp.float32,
    )

    # inter-chunk: fused scan (§Perf H1).  The naive formulation first
    # materializes ALL per-chunk states twice — S_c and prev_states, each
    # [B,C,h,P,N] — then einsums y_inter outside the scan.  Computing S_c
    # and y_inter *inside* the scan body keeps only one running [B,h,P,N]
    # state live and removes ~2/3 of the inter-chunk HBM traffic
    # (hypothesis -> confirmed in EXPERIMENTS.md §Perf).
    decay_end = jnp.exp(jnp.clip(cum[:, :, -1:, :] - cum, -60.0, 0.0))  # [B,C,Q,h]
    A_c = jnp.exp(jnp.clip(cum[:, :, -1, :], -60.0, 0.0))  # [B,C,h]
    cum_exp = jnp.exp(jnp.clip(cum, -60.0, 0.0))  # [B,C,Q,h]

    def scan_fn(state, inp):
        a_c, bc_c, de_c, dtx_c, cc_c, ce_c = inp
        # y from the state entering this chunk
        y_c = jnp.einsum("bin,bhpn,bih->bihp", cc_c, state, ce_c)
        s_c = jnp.einsum("bjn,bjh,bjhp->bhpn", bc_c, de_c, dtx_c)
        new = state * a_c[..., None, None] + s_c
        return new, y_c

    state0 = jnp.zeros((B, h, P, N), jnp.float32)
    xs = (
        A_c.transpose(1, 0, 2),
        bc.transpose(1, 0, 2, 3),
        decay_end.transpose(1, 0, 2, 3),
        dtx.transpose(1, 0, 2, 3, 4),
        cc.transpose(1, 0, 2, 3),
        cum_exp.transpose(1, 0, 2, 3),
    )
    _, y_inter = jax.lax.scan(scan_fn, state0, xs)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)  # [B,C,Q,h,P]

    y = (y_intra + y_inter).reshape(B, S, h, P) + x * p["D"][None, None, :, None]
    out = _finish(p, md, y, z, x_in.dtype)
    return out[:, :S_in] if pad else out


# --- decode -----------------------------------------------------------------


def init_mamba2_cache(md: MambaDims, B, dtype):
    return {
        "conv": jnp.zeros((B, md.d_conv - 1, md.d_xbc), dtype),
        "ssm": jnp.zeros((B, md.n_heads, md.head_dim, md.d_state), jnp.float32),
    }


def mamba2_step(p, md: MambaDims, x_in, cache):
    """One-token decode. x_in [B,1,d_model] -> ([B,1,d_model], cache')."""
    z, xbc, dt = _split_in_proj(p, md, x_in)
    xbc1 = xbc[:, 0, :]  # [B, d_xbc]
    window = jnp.concatenate([cache["conv"], xbc1[:, None, :]], axis=1)  # [B,K,d]
    w = p["conv_w"].astype(xbc1.dtype)
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(xbc1.dtype)
    conv_out = jax.nn.silu(conv_out)

    x, Bm, Cm, dtv, A = _ssd_inputs(p, md, conv_out[:, None, :], dt)
    xt, bt, ct, dtt = x[:, 0], Bm[:, 0], Cm[:, 0], dtv[:, 0]
    a = jnp.exp(dtt * A[None, :])
    ssm = cache["ssm"] * a[..., None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", xt, bt, dtt
    )
    y = jnp.einsum("bhpn,bn->bhp", ssm, ct) + xt * p["D"][None, :, None]
    out = _finish(p, md, y[:, None], z, x_in.dtype)
    return out, {"conv": window[:, 1:, :], "ssm": ssm}
