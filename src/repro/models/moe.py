"""Mixture-of-Experts layer: shared + routed experts, top-k capacity dispatch.

GShard/Switch-style dense dispatch: tokens are assigned a position inside
their expert's capacity buffer via a cumulative-sum over the token axis, and
moved with one-hot einsums — no gathers, EP-shardable (experts dim over the
"model" mesh axis), and the compiled FLOPs equal the *active*-parameter
budget (capacity ≈ tokens·top_k/E), which is what the roofline checks
against 6·N_active·D.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ModelDims, _dense, init_mlp, mlp


@dataclasses.dataclass(frozen=True)
class MoEDims:
    d_model: int
    d_ff_expert: int
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_ff_shared: Optional[int] = None  # defaults to n_shared * d_ff_expert
    capacity_factor: float = 1.25
    mlp_act: str = "silu"
    # §Perf: when set, pin the dispatch/expert shardings: G over the batch
    # axes, E over "model" (EP) — turns XLA's guessed resharding into one
    # explicit all-to-all-shaped movement
    ep_batch_axes: tuple = ()
    # GShard grouping: dispatch/capacity are computed per token group so the
    # one-hot combine tensor is [G, group, E, C] with C ~ group·k/E — linear
    # in tokens, not quadratic
    group_size: int = 512


def init_moe(rng, md: MoEDims, dtype):
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    d, ff, E = md.d_model, md.d_ff_expert, md.n_experts
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": _dense(k1, d, E, jnp.float32),  # router math stays f32
        "wg": (jax.random.normal(k2, (E, d, ff), jnp.float32) * scale).astype(dtype),
        "wu": (jax.random.normal(k3, (E, d, ff), jnp.float32) * scale).astype(dtype),
        "wd": (jax.random.normal(k4, (E, ff, d), jnp.float32) / np.sqrt(ff)).astype(dtype),
    }
    if md.n_shared:
        ffs = md.d_ff_shared or md.n_shared * md.d_ff_expert
        shared_dims = ModelDims(
            d_model=d, n_heads=1, n_kv=1, head_dim=1, d_ff=ffs, mlp_act=md.mlp_act
        )
        p["shared"] = init_mlp(k5, shared_dims, dtype)
    return p


def moe_apply(p, md: MoEDims, x, capacity: Optional[int] = None):
    """x [B, S, d] -> [B, S, d].  Returns (out, aux) with load-balance loss.

    Grouped top-k capacity dispatch: tokens are split into groups of
    ``md.group_size``; each group routes into a per-group capacity buffer
    C = ceil(group·k/E·cf), so every tensor is linear in the token count and
    the G dim shards with the batch while E shards over "model" (EP)."""
    B, S, d = x.shape
    T = B * S
    E, k = md.n_experts, md.top_k
    g = md.group_size if (md.group_size and T % md.group_size == 0) else T
    G = T // g
    xt = x.reshape(G, g, d)

    logits = xt.astype(jnp.float32) @ p["router"]  # [G, g, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # [G, g, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    if capacity is None:
        capacity = max(4, int(np.ceil(g * k / E * md.capacity_factor)))
    C = capacity

    # per-group dispatch: position-in-expert via cumsum over the group
    combine = jnp.zeros((G, g, E, C), jnp.float32)
    prev_counts = jnp.zeros((G, E), jnp.int32)
    for choice in range(k):
        e_onehot = jax.nn.one_hot(gate_idx[..., choice], E, dtype=jnp.int32)
        pos = jnp.cumsum(e_onehot, axis=1) - 1 + prev_counts[:, None, :]
        prev_counts = prev_counts + e_onehot.sum(1)
        keep = (pos < C) & (e_onehot > 0)
        pos_oh = jax.nn.one_hot(jnp.clip(pos, 0, C - 1), C, dtype=jnp.float32)
        combine = combine + (
            keep[..., None] * pos_oh * gate_vals[..., choice, None, None]
        )
    dispatch = (combine > 0).astype(x.dtype)  # [G, g, E, C]

    def _pin(t, spec):
        if not md.ep_batch_axes:
            return t
        from jax.sharding import PartitionSpec as P

        bax = (md.ep_batch_axes if len(md.ep_batch_axes) > 1
               else md.ep_batch_axes[0])
        return jax.lax.with_sharding_constraint(t, P(bax, *spec))

    dispatch = _pin(dispatch, (None, None, None))  # [G(b), g, E, C]
    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xt)  # [G, E, C, d]
    xe = _pin(xe, ("model", None, None))  # explicit EP all-to-all boundary
    act = jax.nn.silu if md.mlp_act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("gecd,edf->gecf", xe, p["wg"])) * jnp.einsum(
        "gecd,edf->gecf", xe, p["wu"]
    )
    ye = jnp.einsum("gecf,efd->gecd", h, p["wd"])  # [G, E, C, d]
    ye = _pin(ye, ("model", None, None))
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ye)
    out = _pin(out, (None, None))

    if md.n_shared:
        ffs = md.d_ff_shared or md.n_shared * md.d_ff_expert
        shared_dims = ModelDims(
            d_model=d, n_heads=1, n_kv=1, head_dim=1, d_ff=ffs, mlp_act=md.mlp_act
        )
        out = out + mlp(p["shared"], shared_dims, xt)

    # GShard auxiliary load-balance loss
    me = probs.reshape(T, E).mean(0)  # [E]
    ce = jax.nn.one_hot(gate_idx[..., 0].reshape(T), E).mean(0)
    aux = E * jnp.sum(me * ce)
    return out.reshape(B, S, d), aux


def moe_ref_dense(p, md: MoEDims, x):
    """Oracle: compute every expert densely for every token, combine by the
    same normalized top-k gates (no capacity drops) — O(T·E·ff)."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate_vals, gate_idx = jax.lax.top_k(probs, md.top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    act = jax.nn.silu if md.mlp_act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("td,edf->tef", xt, p["wg"])) * jnp.einsum(
        "td,edf->tef", xt, p["wu"]
    )
    ye = jnp.einsum("tef,efd->ted", h, p["wd"])  # [T, E, d]
    gates = jnp.zeros((xt.shape[0], md.n_experts), jnp.float32)
    for c in range(md.top_k):
        gates = gates + jax.nn.one_hot(gate_idx[:, c], md.n_experts) * gate_vals[:, c:c + 1]
    out = jnp.einsum("te,ted->td", gates.astype(x.dtype), ye)
    if md.n_shared:
        ffs = md.d_ff_shared or md.n_shared * md.d_ff_expert
        shared_dims = ModelDims(
            d_model=d, n_heads=1, n_kv=1, head_dim=1, d_ff=ffs, mlp_act=md.mlp_act
        )
        out = out + mlp(p["shared"], shared_dims, xt)
    return out.reshape(B, S, d)
