"""Architecture assembly for the 10 assigned archs (6 families).

One ``LMConfig`` describes any of: dense decoder (qwen2 / mistral-large /
granite3), alternating local-global w/ softcap (gemma2), MoE with MLA or GQA
(deepseek-v2-lite, kimi-k2), pure SSM (mamba2), hybrid SSM + weight-shared
attention block (zamba2), cross-attention VLM backbone (llama-3.2-vision,
patch embeddings stubbed per the assignment) and enc-dec audio backbone
(whisper, conv frontend stubbed).

Layers are grouped so every stack is a homogeneous ``lax.scan``:
  * gemma2 scans over (local, global) layer *pairs*;
  * deepseek/kimi keep the first dense-MLP layer explicit and scan the MoE
    layers;
  * zamba2 scans mamba layers and applies the weight-tied shared attention
    block every ``hybrid_period`` layers (closure over shared params);
  * the VLM scans groups of (cross_attn_period-1 self + 1 cross) layers.
Remat (``jax.checkpoint``) wraps each scanned group for training.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import (
    ModelDims,
    _dense,
    attention,
    attention_decode,
    init_attention,
    init_kv_cache,
    init_mlp,
    init_rmsnorm,
    mlp,
    rmsnorm,
)
from repro.models.mamba import (
    MambaDims,
    init_mamba2,
    init_mamba2_cache,
    mamba2_apply,
    mamba2_step,
)
from repro.models.moe import MoEDims, init_moe, moe_apply
from jax.sharding import PartitionSpec as _P


def _constrain_batch(cfg, h):
    """Pin the activation batch dim to the data axes (scan-carry sharding).
    With ``seq_parallel`` (Megatron-SP, §Perf): additionally shard the
    sequence dim over "model" at block boundaries, turning each TP psum into
    reduce-scatter + all-gather (half the wire bytes on the dominant
    activation collectives)."""
    if not cfg.batch_axes or h.shape[0] == 1:
        return h
    b = cfg.batch_axes if len(cfg.batch_axes) > 1 else cfg.batch_axes[0]
    seq = "model" if (cfg.seq_parallel and h.ndim == 3
                      and h.shape[1] % 16 == 0) else None
    return jax.lax.with_sharding_constraint(
        h, _P(*((b, seq) + (None,) * (h.ndim - 2)))
    )


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str  # dense | gemma | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # gemma2
    window: Optional[int] = None  # local-layer sliding window
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    # moe
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_ff: int = 0
    moe_shared: int = 0
    moe_first_dense: int = 0  # leading dense-MLP layers
    moe_ep_constrain: bool = False  # §Perf: pin EP dispatch shardings
    # mla
    mla_kv_rank: Optional[int] = None
    mla_rope_dim: int = 64
    # ssm / hybrid
    ssm_state: int = 0
    hybrid_period: int = 0  # zamba: shared attn block every k layers
    ssm_chunk: int = 64
    ssm_bf16: bool = False  # §Perf: bf16 intra-chunk SSD tensors
    # vlm
    cross_attn_period: int = 0  # every k-th layer is cross-attention
    vision_dim: int = 0
    n_img_tokens: int = 0
    # audio (enc-dec)
    enc_layers: int = 0
    n_audio_frames: int = 0
    # numerics
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    remat: bool = True
    gqa_grouped: bool = True  # §Perf H2 (confirmed win): grouped GQA einsum
    seq_parallel: bool = False  # §Perf: shard S over "model" at block edges
    # dry-run/roofline: unroll scan-over-layers so XLA cost analysis counts
    # every layer (a `while` body is otherwise costed once)
    scan_unroll: int = 1
    # mesh axis names carrying the batch dim; when set, activations get
    # explicit with_sharding_constraint (sharding propagation does not reach
    # scan carries reliably)
    batch_axes: tuple = ()

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def dims(self, window: Optional[int] = None, cross: bool = False) -> ModelDims:
        return ModelDims(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv=self.n_kv,
            head_dim=self.hd,
            d_ff=self.d_ff,
            qkv_bias=self.qkv_bias,
            window=window,
            softcap=self.attn_softcap,
            rope_theta=self.rope_theta,
            mlp_act="gelu" if self.family == "gemma" else (
                "gelu_mlp" if self.family == "audio" else "silu"),
            mla_kv_rank=self.mla_kv_rank,
            mla_rope_dim=self.mla_rope_dim,
            gqa_grouped=self.gqa_grouped,
        )

    def moe_dims(self) -> MoEDims:
        return MoEDims(
            d_model=self.d_model,
            d_ff_expert=self.moe_ff,
            n_experts=self.moe_experts,
            top_k=self.moe_top_k,
            n_shared=self.moe_shared,
            d_ff_shared=self.moe_shared * self.moe_ff if self.moe_shared else None,
            ep_batch_axes=self.batch_axes if self.moe_ep_constrain else (),
        )

    def mamba_dims(self) -> MambaDims:
        return MambaDims(d_model=self.d_model, d_state=self.ssm_state,
                         chunk=self.ssm_chunk, ssd_bf16=self.ssm_bf16)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _init_attn_block(rng, cfg: LMConfig, window=None, moe=False, cross=False):
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    dims = cfg.dims(window)
    p = {
        "ln1": init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "attn": init_attention(k1, dims, cfg.param_dtype),
        "ln2": init_rmsnorm(cfg.d_model, cfg.param_dtype),
    }
    if moe:
        p["moe"] = init_moe(k2, cfg.moe_dims(), cfg.param_dtype)
    else:
        p["mlp"] = init_mlp(k3, dims, cfg.param_dtype)
    if cross:
        p["ln_x"] = init_rmsnorm(cfg.d_model, cfg.param_dtype)
        p["xattn"] = init_attention(k4, cfg.dims(), cfg.param_dtype)
        p["gate"] = jnp.zeros((1,), cfg.param_dtype)
    return p


def _attn_block(p, cfg: LMConfig, h, positions, window=None, moe=False,
                cross_src=None):
    """Pre-norm block.  Cross-attention layers (VLM image layers, whisper-
    style fused decoder blocks) gate the cross path; VLM cross layers replace
    self-attention entirely (Llama-3.2-Vision layout)."""
    dims = cfg.dims(window)
    aux = jnp.float32(0.0)
    if cross_src is None or cfg.family == "audio":
        h = h + attention(p["attn"], dims, rmsnorm(p["ln1"], h), positions)
    if cross_src is not None:
        x = attention(
            p["xattn"], cfg.dims(), rmsnorm(p["ln_x"], h), positions,
            cross_kv=(cross_src, cross_src),
        )
        h = h + jnp.tanh(p["gate"].astype(h.dtype)) * x
    if moe:
        y, aux = moe_apply(p["moe"], cfg.moe_dims(), rmsnorm(p["ln2"], h))
        h = h + y
    else:
        h = h + mlp(p["mlp"], dims, rmsnorm(p["ln2"], h))
    return h, aux


def _maybe_remat(cfg, f):
    return jax.checkpoint(f) if cfg.remat else f


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stacked(rng, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(rng, n))


def init_lm(rng, cfg: LMConfig) -> Dict:
    ks = jax.random.split(rng, 10)
    p: Dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), jnp.float32)
                  * 0.02).astype(cfg.param_dtype),
        "ln_f": init_rmsnorm(cfg.d_model, cfg.param_dtype),
    }
    fam = cfg.family
    if fam in ("dense",):
        p["layers"] = _stacked(ks[1], cfg.n_layers,
                               lambda k: _init_attn_block(k, cfg))
    elif fam == "gemma":
        assert cfg.n_layers % 2 == 0
        p["pairs"] = _stacked(
            ks[1], cfg.n_layers // 2,
            lambda k: {
                "local": _init_attn_block(jax.random.fold_in(k, 0), cfg,
                                          window=cfg.window),
                "global": _init_attn_block(jax.random.fold_in(k, 1), cfg),
            },
        )
    elif fam == "moe":
        nd = cfg.moe_first_dense
        p["first"] = _stacked(ks[1], nd, lambda k: _init_attn_block(k, cfg))
        p["layers"] = _stacked(ks[2], cfg.n_layers - nd,
                               lambda k: _init_attn_block(k, cfg, moe=True))
    elif fam == "ssm":
        md = cfg.mamba_dims()
        p["layers"] = _stacked(
            ks[1], cfg.n_layers,
            lambda k: {"ln": init_rmsnorm(cfg.d_model, cfg.param_dtype),
                       "mamba": init_mamba2(k, md, cfg.param_dtype)},
        )
    elif fam == "hybrid":
        md = cfg.mamba_dims()
        p["layers"] = _stacked(
            ks[1], cfg.n_layers,
            lambda k: {"ln": init_rmsnorm(cfg.d_model, cfg.param_dtype),
                       "mamba": init_mamba2(k, md, cfg.param_dtype)},
        )
        p["shared"] = _init_attn_block(ks[2], cfg)  # weight-tied attn block
    elif fam == "vlm":
        g = cfg.cross_attn_period
        assert cfg.n_layers % g == 0
        p["groups"] = _stacked(
            ks[1], cfg.n_layers // g,
            lambda k: {
                "selfs": _stacked(jax.random.fold_in(k, 0), g - 1,
                                  lambda kk: _init_attn_block(kk, cfg)),
                "cross": _init_attn_block(jax.random.fold_in(k, 1), cfg,
                                          cross=True),
            },
        )
        p["img_proj"] = _dense(ks[3], cfg.vision_dim, cfg.d_model,
                               cfg.param_dtype)
    elif fam == "audio":
        p["enc_layers"] = _stacked(ks[1], cfg.enc_layers,
                                   lambda k: _init_attn_block(k, cfg))
        p["enc_ln"] = init_rmsnorm(cfg.d_model, cfg.param_dtype)
        p["dec_layers"] = _stacked(
            ks[2], cfg.n_layers,
            lambda k: _init_attn_block(k, cfg, cross=True))
    else:
        raise ValueError(f"unknown family {fam}")
    return p


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _embed(p, cfg: LMConfig, tokens):
    h = p["embed"][tokens].astype(cfg.dtype)
    if cfg.family == "gemma":
        h = h * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)
    return h


def _unembed(p, cfg: LMConfig, h):
    h = rmsnorm(p["ln_f"], h)
    logits = h @ p["embed"].T.astype(cfg.dtype)
    if cfg.final_softcap:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits


def _encode_audio(p, cfg: LMConfig, frames):
    """Encoder over precomputed frame embeddings (conv frontend stubbed)."""
    h = frames.astype(cfg.dtype)
    B, T, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    dims = cfg.dims()

    def enc_block(h, lp):
        h = _constrain_batch(cfg, h)
        hn = rmsnorm(lp["ln1"], h)
        # bidirectional self-attention: zero mask
        att = attention(lp["attn"], dims, hn, positions, cross_kv=(hn, hn))
        h = h + att
        h = h + mlp(lp["mlp"], dims, rmsnorm(lp["ln2"], h))
        return h, None

    h, _ = jax.lax.scan(_maybe_remat(cfg, enc_block), h, p["enc_layers"], unroll=cfg.scan_unroll)
    return rmsnorm(p["enc_ln"], h)


def lm_apply(params, cfg: LMConfig, batch) -> jnp.ndarray:
    """Full forward to logits.  ``batch``: tokens [B,S] (+ img / frames)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = _embed(params, cfg, tokens)
    h = _constrain_batch(cfg, h)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    fam = cfg.family
    aux_total = jnp.float32(0.0)

    if fam == "dense":
        def block(h, lp):
            h = _constrain_batch(cfg, h)
            h, _ = _attn_block(lp, cfg, h, positions)
            return h, None
        h, _ = jax.lax.scan(_maybe_remat(cfg, block), h, params["layers"], unroll=cfg.scan_unroll)

    elif fam == "gemma":
        def pair(h, lp):
            h = _constrain_batch(cfg, h)
            h, _ = _attn_block(lp["local"], cfg, h, positions, window=cfg.window)
            h, _ = _attn_block(lp["global"], cfg, h, positions)
            return h, None
        h, _ = jax.lax.scan(_maybe_remat(cfg, pair), h, params["pairs"], unroll=cfg.scan_unroll)

    elif fam == "moe":
        def dense_block(h, lp):
            h, _ = _attn_block(lp, cfg, h, positions)
            return h, None
        h, _ = jax.lax.scan(dense_block, h, params["first"], unroll=cfg.scan_unroll)

        def moe_block(h, lp):
            h = _constrain_batch(cfg, h)
            h, aux = _attn_block(lp, cfg, h, positions, moe=True)
            return h, aux
        h, auxs = jax.lax.scan(_maybe_remat(cfg, moe_block), h, params["layers"], unroll=cfg.scan_unroll)
        aux_total = auxs.sum()

    elif fam in ("ssm", "hybrid"):
        md = cfg.mamba_dims()

        if fam == "ssm":
            def block(h, lp):
                h = _constrain_batch(cfg, h)
                h = h + mamba2_apply(lp["mamba"], md, rmsnorm(lp["ln"], h))
                return h, None
            h, _ = jax.lax.scan(_maybe_remat(cfg, block), h, params["layers"], unroll=cfg.scan_unroll)
        else:
            k = cfg.hybrid_period
            shared = params["shared"]

            def block(carry, inp):
                h, idx = carry
                lp = inp
                h = _constrain_batch(cfg, h)
                h = h + mamba2_apply(lp["mamba"], md, rmsnorm(lp["ln"], h))

                def with_shared(h):
                    out, _ = _attn_block(shared, cfg, h, positions)
                    return out

                h = jax.lax.cond((idx + 1) % k == 0, with_shared, lambda x: x, h)
                return (h, idx + 1), None

            (h, _), _ = jax.lax.scan(
                _maybe_remat(cfg, block), (h, jnp.int32(0)), params["layers"],
                unroll=cfg.scan_unroll,
            )

    elif fam == "vlm":
        img = (batch["images"].astype(cfg.dtype) @ params["img_proj"])

        def group(h, gp):
            h = _constrain_batch(cfg, h)
            g = cfg.cross_attn_period
            for i in range(g - 1):
                lp = jax.tree_util.tree_map(lambda a: a[i], gp["selfs"])
                h, _ = _attn_block(lp, cfg, h, positions)
            h, _ = _attn_block(gp["cross"], cfg, h, positions, cross_src=img)
            return h, None

        h, _ = jax.lax.scan(_maybe_remat(cfg, group), h, params["groups"], unroll=cfg.scan_unroll)

    elif fam == "audio":
        enc = _encode_audio(params, cfg, batch["frames"])

        def dec_block(h, lp):
            h = _constrain_batch(cfg, h)
            h, _ = _attn_block(lp, cfg, h, positions, cross_src=enc)
            return h, None

        h, _ = jax.lax.scan(_maybe_remat(cfg, dec_block), h, params["dec_layers"], unroll=cfg.scan_unroll)

    logits = _unembed(params, cfg, h)
    return logits, aux_total


def lm_loss(params, cfg: LMConfig, batch):
    logits, aux = lm_apply(params, cfg, batch)
    targets = batch["tokens"][:, 1:]
    lg = logits[:, :-1]
    # vocab-sharding-friendly cross entropy: logsumexp + one-hot contraction
    # (both reduce over the sharded vocab axis — no gather / no [B,S,V] f32
    # materialization, XLA fuses the one_hot into the dot)
    lse = jax.scipy.special.logsumexp(lg.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(targets, lg.shape[-1], dtype=lg.dtype)
    correct = jnp.einsum("bsv,bsv->bs", onehot, lg).astype(jnp.float32)
    nll = lse - correct
    loss = nll.mean() + 0.01 * aux
    return loss, {"nll": nll.mean(), "aux": aux}


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------


def init_decode_cache(cfg: LMConfig, B: int, S_max: int):
    """Cache pytree for one-token decode with a pre-existing context."""
    fam = cfg.family
    cdt = cfg.dtype

    def kv(window=None):
        return init_kv_cache(cfg.dims(window), B, S_max, cdt)

    if fam == "dense":
        return {"layers": jax.vmap(lambda _: kv())(jnp.arange(cfg.n_layers))}
    if fam == "gemma":
        n = cfg.n_layers // 2
        return {
            "pairs": jax.vmap(lambda _: {"local": kv(cfg.window), "global": kv()})(
                jnp.arange(n)
            )
        }
    if fam == "moe":
        return {
            "first": jax.vmap(lambda _: kv())(jnp.arange(cfg.moe_first_dense)),
            "layers": jax.vmap(lambda _: kv())(
                jnp.arange(cfg.n_layers - cfg.moe_first_dense)
            ),
        }
    if fam == "ssm":
        md = cfg.mamba_dims()
        return {
            "layers": jax.vmap(lambda _: init_mamba2_cache(md, B, cdt))(
                jnp.arange(cfg.n_layers)
            )
        }
    if fam == "hybrid":
        md = cfg.mamba_dims()
        n_shared = cfg.n_layers // cfg.hybrid_period
        return {
            "layers": jax.vmap(lambda _: init_mamba2_cache(md, B, cdt))(
                jnp.arange(cfg.n_layers)
            ),
            "shared": jax.vmap(lambda _: kv())(jnp.arange(n_shared)),
        }
    if fam == "vlm":
        g = cfg.cross_attn_period
        return {
            "groups": jax.vmap(
                lambda _: {"selfs": jax.vmap(lambda __: kv())(jnp.arange(g - 1))}
            )(jnp.arange(cfg.n_layers // g)),
            "img": jnp.zeros((B, cfg.n_img_tokens, cfg.d_model), cdt),
        }
    if fam == "audio":
        return {
            "dec": jax.vmap(lambda _: kv())(jnp.arange(cfg.n_layers)),
            "enc": jnp.zeros((B, cfg.n_audio_frames, cfg.d_model), cdt),
        }
    raise ValueError(fam)


def _decode_block(lp, cfg, h, cache, pos, window=None, cross_src=None):
    dims = cfg.dims(window)
    att, cache = attention_decode(lp["attn"], dims, rmsnorm(lp["ln1"], h),
                                  cache, pos)
    h = h + att
    if cross_src is not None:
        B = h.shape[0]
        positions = jnp.full((B, 1), pos, jnp.int32)
        x = attention(lp["xattn"], cfg.dims(), rmsnorm(lp["ln_x"], h),
                      positions, cross_kv=(cross_src, cross_src))
        h = h + jnp.tanh(lp["gate"].astype(h.dtype)) * x
    if "moe" in lp:
        y, _ = moe_apply(lp["moe"], cfg.moe_dims(), rmsnorm(lp["ln2"], h))
        h = h + y
    else:
        h = h + mlp(lp["mlp"], dims, rmsnorm(lp["ln2"], h))
    return h, cache


def lm_decode_step(params, cfg: LMConfig, cache, token, pos):
    """One decode step: token [B] int32, pos scalar -> (logits [B,V], cache)."""
    B = token.shape[0]
    h = _embed(params, cfg, token[:, None])
    fam = cfg.family

    if fam == "dense":
        def step(h, xs):
            lp, c = xs
            h, c = _decode_block(lp, cfg, h, c, pos)
            return h, c
        h, new_cache = jax.lax.scan(step, h, (params["layers"], cache["layers"]))
        cache = {"layers": new_cache}

    elif fam == "gemma":
        def step(h, xs):
            lp, c = xs
            h, cl = _decode_block(lp["local"], cfg, h, c["local"], pos,
                                  window=cfg.window)
            h, cg = _decode_block(lp["global"], cfg, h, c["global"], pos)
            return h, {"local": cl, "global": cg}
        h, new_cache = jax.lax.scan(step, h, (params["pairs"], cache["pairs"]))
        cache = {"pairs": new_cache}

    elif fam == "moe":
        def step_d(h, xs):
            lp, c = xs
            return _decode_block(lp, cfg, h, c, pos)
        h, cf = jax.lax.scan(step_d, h, (params["first"], cache["first"]))

        def step_m(h, xs):
            lp, c = xs
            return _decode_block(lp, cfg, h, c, pos)
        h, cl = jax.lax.scan(step_m, h, (params["layers"], cache["layers"]))
        cache = {"first": cf, "layers": cl}

    elif fam == "ssm":
        md = cfg.mamba_dims()

        def step(h, xs):
            lp, c = xs
            y, c = mamba2_step(lp["mamba"], md, rmsnorm(lp["ln"], h), c)
            return h + y, c
        h, new_cache = jax.lax.scan(step, h, (params["layers"], cache["layers"]))
        cache = {"layers": new_cache}

    elif fam == "hybrid":
        md = cfg.mamba_dims()
        k = cfg.hybrid_period
        n_shared = cfg.n_layers // k
        shared = params["shared"]

        def step(carry, xs):
            h, shared_caches, idx = carry
            lp, c = xs
            y, c = mamba2_step(lp["mamba"], md, rmsnorm(lp["ln"], h), c)
            h = h + y

            def with_shared(args):
                h, shared_caches = args
                si = (idx + 1) // k - 1
                sc = jax.tree_util.tree_map(lambda a: a[si], shared_caches)
                h, sc = _decode_block(shared, cfg, h, sc, pos)
                shared_caches = jax.tree_util.tree_map(
                    lambda a, b: a.at[si].set(b), shared_caches, sc
                )
                return h, shared_caches

            h, shared_caches = jax.lax.cond(
                (idx + 1) % k == 0, with_shared, lambda a: a, (h, shared_caches)
            )
            return (h, shared_caches, idx + 1), c

        (h, shared_caches, _), new_layers = jax.lax.scan(
            step, (h, cache["shared"], jnp.int32(0)),
            (params["layers"], cache["layers"]),
        )
        cache = {"layers": new_layers, "shared": shared_caches}

    elif fam == "vlm":
        img = cache["img"]  # projected image tokens cached at prefill

        def group(h, xs):
            gp, gc = xs
            g = cfg.cross_attn_period
            new_selfs = []
            for i in range(g - 1):
                lp = jax.tree_util.tree_map(lambda a: a[i], gp["selfs"])
                c = jax.tree_util.tree_map(lambda a: a[i], gc["selfs"])
                h, c = _decode_block(lp, cfg, h, c, pos)
                new_selfs.append(c)
            stacked = jax.tree_util.tree_map(
                lambda *xs_: jnp.stack(xs_), *new_selfs
            )
            B = h.shape[0]
            positions = jnp.full((B, 1), pos, jnp.int32)
            # cross layer (no self-attention — Llama-3.2-Vision layout)
            h = h + jnp.tanh(gp["cross"]["gate"].astype(h.dtype)) * attention(
                gp["cross"]["xattn"], cfg.dims(), rmsnorm(gp["cross"]["ln_x"], h),
                positions, cross_kv=(img, img),
            )
            h = h + mlp(gp["cross"]["mlp"], cfg.dims(),
                        rmsnorm(gp["cross"]["ln2"], h))
            return h, {"selfs": stacked}

        h, new_groups = jax.lax.scan(
            group, h, (params["groups"], cache["groups"])
        )
        cache = {"groups": new_groups, "img": img}

    elif fam == "audio":
        enc = cache["enc"]

        def step(h, xs):
            lp, c = xs
            h, c = _decode_block(lp, cfg, h, c, pos, cross_src=enc)
            return h, c

        h, new_dec = jax.lax.scan(step, h, (params["dec_layers"], cache["dec"]))
        cache = {"dec": new_dec, "enc": enc}

    logits = _unembed(params, cfg, h)
    return logits[:, 0, :], cache
