"""Venice-scheduled conflict-free parallel shard reads.

The paper's contribution — reserve a conflict-free path per transfer over a
shared interconnect before moving data — transfers directly to the cluster
storage fabric: N hosts restoring a sharded checkpoint (or prefetching data
shards) from M storage nodes over a shared fabric suffer exactly the path
conflict problem (§1) when several hosts pull from the same storage channel.

``plan_reads`` maps (host, storage-node) transfer requests onto the Venice
mesh machinery (hosts = flash controllers on the west edge; storage nodes =
flash nodes) and runs scout-based path reservation round by round: each round
is a set of transfers whose paths are mutually conflict-free; transfers that
fail reservation wait for the next round.  The checkpoint loader consumes the
plan to order its reads.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core import build_mesh, scout_route_ref
from repro.core.rng import seed_for_scout


@dataclasses.dataclass
class IOPlan:
    rounds: List[List[int]]  # request indices per conflict-free round
    hops: List[int]  # path length per request
    paths: List[np.ndarray]  # reserved link ids per request
    n_conflicts: int  # reservation failures encountered while planning

    @property
    def n_rounds(self) -> int:
        return len(self.rounds)


def plan_reads(
    requests: Sequence[Tuple[int, int]],
    n_hosts: int,
    n_storage: int,
    seed: int = 0,
) -> IOPlan:
    """Schedule ``requests`` = [(host, storage_node), ...] into conflict-free
    rounds using Venice path reservation on an (n_hosts x cols) mesh."""
    cols = max(1, -(-n_storage // n_hosts))
    topo = build_mesh(n_hosts, cols)
    pending = list(range(len(requests)))
    rounds: List[List[int]] = []
    hops = [0] * len(requests)
    paths: List[np.ndarray] = [np.zeros((0,), np.int32)] * len(requests)
    conflicts = 0
    trial = 0
    while pending:
        busy = np.zeros((topo.n_links,), bool)
        this_round: List[int] = []
        still: List[int] = []
        for idx in pending:
            host, node = requests[idx]
            src = int(topo.fc_node[host % topo.rows])
            dst = int(node % topo.n_nodes)
            res = scout_route_ref(topo, src, dst, busy, seed_for_scout(seed, trial))
            trial += 1
            if res.success:
                busy[res.path_links] = True
                hops[idx] = res.hops
                paths[idx] = res.path_links
                this_round.append(idx)
            else:
                conflicts += 1
                still.append(idx)
        if not this_round:  # can't happen (empty net always routes) — guard
            this_round, still = [still[0]], still[1:]
        rounds.append(this_round)
        pending = still
    return IOPlan(rounds=rounds, hops=hops, paths=paths, n_conflicts=conflicts)
