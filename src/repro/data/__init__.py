"""Data pipeline: deterministic synthetic token stream (sharded by host) and
the Venice-scheduled conflict-free parallel shard-read planner."""
from repro.data.pipeline import SyntheticTokens, make_batch_iterator
from repro.data.venice_io import IOPlan, plan_reads

__all__ = ["SyntheticTokens", "make_batch_iterator", "IOPlan", "plan_reads"]
