"""Deterministic synthetic token pipeline.

Batches are a pure function of (seed, step, shard) — every host can generate
its own shard with no coordination, restarts reproduce the same stream
(checkpoint stores only the step counter), and elastic re-sharding is just a
different (shard, n_shards) split of the same global stream.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> np.ndarray:
        """Tokens [global_batch // n_shards, seq_len] for this host shard."""
        assert self.global_batch % n_shards == 0
        b = self.global_batch // n_shards
        # counter-based: philox-like mixing of (seed, step, shard, row)
        rs = np.random.Generator(
            np.random.Philox(key=self.seed, counter=[step, shard, 0, 0])
        )
        # a crude "language": zipf-ish unigram + short-range repetition
        z = rs.zipf(1.3, size=(b, self.seq_len)).astype(np.int64)
        toks = z % self.vocab
        rep = rs.random((b, self.seq_len)) < 0.2
        toks[:, 1:] = np.where(rep[:, 1:], toks[:, :-1], toks[:, 1:])
        return toks.astype(np.int32)


def make_batch_iterator(vocab, seq_len, global_batch, seed=0, shard=0,
                        n_shards=1, start_step=0):
    src = SyntheticTokens(vocab, seq_len, global_batch, seed)
    step = start_step
    while True:
        yield step, src.batch(step, shard, n_shards)
        step += 1
