"""Three-term roofline from the dry-run's compiled artifact (§Roofline).

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = wire_bytes_per_device / link_bw

Sources: ``compiled.cost_analysis()`` (the post-SPMD per-device module) gives
FLOPs and bytes; collective bytes are parsed from the optimized HLO text —
result-shard shapes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, with ring-wire factors (all-reduce moves
~2x its payload: reduce-scatter + all-gather phases).

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import functools
import re
from typing import Dict

import numpy as np

HW = {
    "peak_flops": 197e12,  # bf16 / chip
    "hbm_bw": 819e9,  # B/s / chip
    "link_bw": 50e9,  # B/s / link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# ring wire factor per element of the *result* shard
_WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_INSTR_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\("
)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum result-shard bytes of every collective in the optimized HLO
    (handles async `-start`/`-done` pairs by counting `-start` only)."""
    out: Dict[str, float] = {op: 0.0 for op in _COLL_OPS}
    counts: Dict[str, int] = {op: 0 for op in _COLL_OPS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        type_str, opname = m.group(1), m.group(2)
        if opname.endswith("-done"):
            continue
        base = opname[:-6] if opname.endswith("-start") else opname
        if base in _COLL_OPS:
            out[base] += _shape_bytes(type_str)
            counts[base] += 1
    out["counts"] = counts  # type: ignore
    return out


@functools.lru_cache(maxsize=None)
def param_counts(arch: str):
    """(total_params, active_params) from the real init shapes."""
    import jax

    from repro.configs import get_config
    from repro.models.lm import init_lm

    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.PRNGKey(0))
    total = 0
    routed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        keys = [str(getattr(k, "key", k)) for k in path]
        # routed-expert weights: 3D (E, d, ff) under a "moe" scope
        if "moe" in keys and keys[-1] in ("wg", "wu", "wd"):
            routed += n
    active = total - routed
    if cfg.moe_experts:
        active += routed * cfg.moe_top_k / cfg.moe_experts
    return int(total), int(active)


def model_flops(arch: str, shape: str) -> float:
    """6·N·D (dense) or 6·N_active·D (MoE) — useful-compute reference."""
    from repro.configs import SHAPES

    seq, batch, kind = SHAPES[shape]
    total, active = param_counts(arch)
    tokens = seq * batch if kind in ("train", "prefill") else batch
    mult = 6.0 if kind == "train" else 2.0  # fwd-only for prefill/decode
    return mult * active * tokens


def roofline_terms(rec: Dict, arch: str) -> Dict:
    """Compute the three terms (seconds) for one dry-run record."""
    chips = rec.get("devices", 1)
    cost = rec.get("cost", {})
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll = rec.get("collectives", {})
    wire = sum(
        float(coll.get(op, 0.0)) * _WIRE_FACTOR[op] for op in _COLL_OPS
    )
    t_compute = flops / HW["peak_flops"]
    t_memory = bytes_acc / HW["hbm_bw"]
    t_coll = wire / HW["link_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(arch, rec["shape"])
    useful = mf / max(flops * chips, 1.0)
    bound = max(t_compute, t_memory, t_coll)
    frac = t_compute / bound if bound > 0 else 0.0
    terms.update(
        dominant=dom.replace("_s", ""),
        model_flops=mf,
        useful_flop_frac=useful,
        roofline_frac=frac,
        step_time_lb_s=bound,
    )
    return terms
