"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun JSONL.

  PYTHONPATH=src python -m repro.roofline.report results/dryrun.jsonl
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict


def load(path: str):
    recs = [json.loads(l) for l in open(path)]
    return [r for r in recs if "error" not in r]


def dryrun_table(recs) -> str:
    out = [
        "| arch | shape | mesh | lower s | compile s | temp/dev GiB | "
        "HLO GFLOP/dev | coll MB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        coll = r.get("collectives", {})
        cb = sum(v for k, v in coll.items() if isinstance(v, (int, float)))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['lower_s']} | "
            f"{r['compile_s']} | "
            f"{r.get('memory', {}).get('temp_size_in_bytes', 0)/2**30:.2f} | "
            f"{r.get('cost', {}).get('flops', 0)/1e9:.1f} | {cb/2**20:.1f} |"
        )
    return "\n".join(out)


def roofline_table(recs, mesh="16x16") -> str:
    out = [
        "| arch | shape | compute ms | memory ms | collective ms | dominant |"
        " roofline frac | useful-FLOP frac | one-line lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    levers = {
        ("memory", "train"): "cast large scan intermediates to bf16 / fuse",
        ("memory", "prefill"): "fuse attention epilogue; bf16 intermediates",
        ("memory", "decode"): "batch more sequences per chip (cache-bw bound)",
        ("collective", "train"): "overlap grad reduce-scatter with backward",
        ("collective", "prefill"): "reorder EP dispatch; shard activations",
        ("collective", "decode"): "avoid KV head-expansion resharding (GQA einsum)",
        ("compute", "train"): "already compute-bound: raise MXU utilization",
        ("compute", "prefill"): "already compute-bound: raise MXU utilization",
        ("compute", "decode"): "increase batch to amortize weights",
    }
    for r in recs:
        if r["mesh"] != mesh:
            continue
        t = r["roofline"]
        lever = levers.get((t["dominant"], r["kind"]), "-")
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']*1e3:.2f} | "
            f"{t['memory_s']*1e3:.2f} | {t['collective_s']*1e3:.2f} | "
            f"{t['dominant']} | {t['roofline_frac']*100:.1f}% | "
            f"{min(t['useful_flop_frac'], 9.99)*100:.0f}% | {lever} |"
        )
    return "\n".join(out)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.jsonl"
    recs = load(path)
    print("### Dry-run (all cells, both meshes)\n")
    print(dryrun_table(recs))
    print("\n### Roofline (single-pod 16x16)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
