"""Sharded checkpoint store.

Layout:  <dir>/step_<N>/shard_<i>.npz  +  <dir>/step_<N>/MANIFEST.json

* every leaf is split along its largest axis into ``n_shards`` chunks
  (ZeRO-style: each "host" persists only its chunk);
* the manifest (tree structure, shapes, dtypes, shard map, step) is written
  LAST and atomically (tmp + rename) — a crashed save is invisible;
* restore works under any shard count ("elastic re-shard"): chunks are
  re-concatenated from whatever layout was saved, optionally through a
  Venice-scheduled read plan (``repro.data.venice_io``) ordering the
  shard fetches conflict-free across storage channels.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Optional

import jax
import numpy as np

from repro.data.venice_io import plan_reads

_MANIFEST = "MANIFEST.json"


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", k)) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def _split_axis(shape) -> int:
    return int(np.argmax(shape)) if len(shape) else -1


def save(directory: str, step: int, tree: Any, n_shards: int = 4) -> str:
    """Write a sharded checkpoint; returns the step directory."""
    names, leaves, _ = _leaf_paths(tree)
    step_dir = os.path.join(directory, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)

    manifest = {"step": step, "n_shards": n_shards, "leaves": {}}
    shards: list = [dict() for _ in range(n_shards)]
    for name, leaf in zip(names, leaves):
        arr = np.asarray(leaf)
        ax = _split_axis(arr.shape)
        if ax < 0 or arr.shape[ax] < n_shards:
            chunks = [arr] + [np.zeros((0,), arr.dtype)] * (n_shards - 1)
            ax = -1
        else:
            chunks = np.array_split(arr, n_shards, axis=ax)
        manifest["leaves"][name] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "axis": ax,
        }
        for i, c in enumerate(chunks):
            shards[i][name] = c
    for i, payload in enumerate(shards):
        np.savez(os.path.join(tmp_dir, f"shard_{i}.npz"), **payload)
    with open(os.path.join(tmp_dir, _MANIFEST + ".tmp"), "w") as f:
        json.dump(manifest, f)
    os.replace(
        os.path.join(tmp_dir, _MANIFEST + ".tmp"),
        os.path.join(tmp_dir, _MANIFEST),
    )
    os.replace(tmp_dir, step_dir)  # atomic publish
    return step_dir


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, _MANIFEST)):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, step: int, like: Any, venice_ordered: bool = True):
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    step_dir = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(step_dir, _MANIFEST)) as f:
        manifest = json.load(f)
    n_shards = manifest["n_shards"]

    # Venice-ordered shard fetches: model "hosts" pulling "storage nodes"
    order = list(range(n_shards))
    if venice_ordered and n_shards > 1:
        plan = plan_reads(
            [(i % 4, i) for i in range(n_shards)], n_hosts=4,
            n_storage=max(n_shards, 4),
        )
        order = [i for rnd in plan.rounds for i in rnd]

    payloads = {}
    for i in order:
        with np.load(os.path.join(step_dir, f"shard_{i}.npz")) as z:
            payloads[i] = {k: z[k] for k in z.files}

    names, leaves, treedef = _leaf_paths(like)
    out = []
    for name, leaf in zip(names, leaves):
        meta = manifest["leaves"][name]
        ax = meta["axis"]
        chunks = [payloads[i][name] for i in range(n_shards)]
        if ax < 0:
            arr = chunks[0]
        else:
            arr = np.concatenate(chunks, axis=ax)
        assert list(arr.shape) == meta["shape"], (name, arr.shape, meta)
        assert tuple(arr.shape) == tuple(np.shape(leaf)), name
        out.append(arr.astype(meta["dtype"]))
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_latest(directory: str, like: Any):
    step = latest_step(directory)
    if step is None:
        return None, None
    return step, restore(directory, step, like)
