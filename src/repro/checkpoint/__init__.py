"""Sharded checkpointing: per-host files, atomic manifest, restart-from-
latest, elastic re-shard."""
from repro.checkpoint.store import (
    latest_step,
    restore,
    restore_latest,
    save,
)

__all__ = ["latest_step", "restore", "restore_latest", "save"]
