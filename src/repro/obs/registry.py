"""Structured metrics registry behind the process-wide ``bench.PERF`` dict.

``bench.PERF`` grew organically as a free-form dict; every figure phase,
cache layer and pipeline stage writes counters into it and
``benchmarks/run.py`` snapshots deltas around each phase.  This module
keeps that exact surface — ``PERF`` stays a real dict (a subclass), every
``perf["x"] += 1`` / ``.get`` / ``.setdefault`` / ``.update`` call site and
the BENCH_*.json schema are untouched — while adding what a free dict
cannot offer:

* **typed declarations**: every metric is declared once with a kind
  (counter / gauge / timer / object) and a default, so a typo'd key is
  distinguishable from a declared metric and tools can enumerate the
  schema (``MetricsRegistry.schema()``);
* **reset/snapshot semantics**: ``PerfDict.reset()`` restores the declared
  defaults in place (same object identity — every module that did
  ``from ... import PERF`` keeps a live view), ``snapshot()`` deep-copies
  the current state, and ``delta(before)`` subtracts two snapshots'
  numeric fields — the primitive scenario engines use to report per-run
  counters instead of process-cumulative ones.
"""
from __future__ import annotations

import copy
import threading

__all__ = ["MetricsRegistry", "PerfDict"]

_KINDS = ("counter", "gauge", "timer", "object")


class MetricsRegistry:
    """Declaration table: metric name -> (kind, default value).

    A registry is the *schema*; :class:`PerfDict` (from :meth:`view`) is
    the live store.  Multiple views share the declarations but not the
    values (the harness uses exactly one, ``bench.PERF``).
    """

    def __init__(self):
        self._decls: dict[str, tuple[str, object]] = {}
        self._lock = threading.Lock()

    def declare(self, name: str, kind: str, default) -> str:
        if kind not in _KINDS:
            raise ValueError(f"unknown metric kind {kind!r}; one of {_KINDS}")
        with self._lock:
            prev = self._decls.get(name)
            if prev is not None and prev[0] != kind:
                raise ValueError(
                    f"metric {name!r} already declared as {prev[0]}, "
                    f"not {kind}")
            self._decls[name] = (kind, default)
        return name

    def counter(self, name: str, default: int = 0) -> str:
        return self.declare(name, "counter", default)

    def gauge(self, name: str, default=None) -> str:
        return self.declare(name, "gauge", default)

    def timer(self, name: str, default: float = 0.0) -> str:
        return self.declare(name, "timer", default)

    def object(self, name: str, default) -> str:
        """Structured payloads (lists/dicts) that ride along the scoreboard
        — e.g. the per-group records under ``PERF["groups"]``."""
        return self.declare(name, "object", default)

    def schema(self) -> dict:
        """{name: kind} for every declared metric (stable snapshot)."""
        with self._lock:
            return {k: v[0] for k, v in self._decls.items()}

    def defaults(self) -> dict:
        with self._lock:
            return {k: copy.deepcopy(v[1]) for k, v in self._decls.items()}

    def view(self) -> "PerfDict":
        return PerfDict(self)


class PerfDict(dict):
    """A live metrics store that is also a plain dict.

    Undeclared keys still work (a dict is a dict — ad-hoc keys written by
    older call sites or tests are tolerated), but only declared keys come
    back after :meth:`reset` and only numeric values participate in
    :meth:`delta`.
    """

    def __init__(self, registry: MetricsRegistry):
        self._registry = registry
        super().__init__(registry.defaults())

    def reset(self) -> None:
        """Restore declared defaults *in place* (object identity kept)."""
        self.clear()
        self.update(self._registry.defaults())

    def snapshot(self) -> dict:
        """Deep copy of the current state (safe to mutate / diff later)."""
        return copy.deepcopy(dict(self))

    def delta(self, before: dict) -> dict:
        """Numeric field-wise ``self - before`` (int/float/bool leaves).

        Keys absent from ``before`` diff against the declared default when
        numeric, else 0 — so a counter born after the snapshot still
        reports its full increment.  Non-numeric fields (lists, dicts,
        strings, None) are skipped: deltas are for counters/timers/gauges.
        """
        defaults = self._registry.defaults()
        out = {}
        for k, v in self.items():
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            b = before.get(k, defaults.get(k, 0))
            if isinstance(b, bool) or not isinstance(b, (int, float)):
                b = 0
            out[k] = v - b
        return out
