"""Unified observability: device flight recorder + harness telemetry.

Two layers exporting into ONE Chrome-trace-event JSON (Perfetto /
``chrome://tracing`` loadable):

* **Layer 1 — device flight recorder** (``events.py``): per-transaction
  timelines and per-resource occupancy intervals, reconstructed *host-side*
  from the scan's existing ``StepOut`` arrays after execution.  The jitted
  step carries nothing new — executables, cache keys and every figure CSV
  are byte-identical with the recorder on or off.
* **Layer 2 — harness telemetry** (``spans.py`` + ``registry.py``): span
  instrumentation of the plan → lower → compile → dispatch pipeline and the
  streaming window loop, plus a structured metrics registry backing the
  process-wide ``bench.PERF`` scoreboard (``PERF`` stays a dict view, so
  the BENCH_*.json schema is unchanged).

Both layers are **off by default** and cost one ``is None`` check at each
hook site when disabled.  ``enable_tracing()`` arms them;
``export_trace()`` writes the combined trace (and optionally the
resource-utilization heatmap CSV).  This package imports only numpy and
the stdlib — never jax — so hooking it into the hot modules is free.
"""
from __future__ import annotations

import os

from repro.obs import events as _events
from repro.obs import heatmap as _heatmap
from repro.obs import spans as _spans
from repro.obs.export import TraceBuilder, validate_trace

__all__ = [
    "enable_tracing", "disable_tracing", "tracing_enabled",
    "export_trace", "validate_trace", "TraceBuilder",
]

# Environment handshake with the out-of-process compile server: when the
# parent is tracing, it points this variable at a sidecar file and the
# worker (which cannot share the parent's tracer) appends epoch-stamped
# span records there; ``export_trace`` merges them onto an "xc_worker"
# track.  See ``ssd/xc_worker.py``.
XC_SPANS_ENV = "REPRO_XC_SPANS"


def enable_tracing(max_txn_events: int | None = None,
                   xc_sidecar: str | None = None) -> None:
    """Arm both layers: install the global device recorder and span tracer.

    ``max_txn_events`` caps the number of per-transaction device events
    retained (runs past the cap are recorded as dropped, never silently
    truncated mid-run).  ``xc_sidecar`` (a file path) additionally asks any
    compile server spawned after this call to log its compile spans there.
    """
    kwargs = {}
    if max_txn_events is not None:
        kwargs["max_txns"] = max_txn_events
    _events.RECORDER = _events.DeviceRecorder(**kwargs)
    _spans.TRACER = _spans.SpanTracer()
    if xc_sidecar is not None:
        os.environ[XC_SPANS_ENV] = xc_sidecar


def disable_tracing() -> None:
    """Disarm both layers (hook sites return to the no-op path)."""
    _events.RECORDER = None
    _spans.TRACER = None
    os.environ.pop(XC_SPANS_ENV, None)


def tracing_enabled() -> bool:
    return _events.RECORDER is not None or _spans.TRACER is not None


def export_trace(path: str, heatmap_csv: str | None = None,
                 bucket_us: float | None = None) -> dict:
    """Write the combined trace JSON (device + harness tracks) to ``path``.

    Returns a summary dict (event/track counts).  ``heatmap_csv`` also
    writes the resource x time-bucket utilization/conflict matrices
    (``heatmap.write_heatmap_csv``); ``bucket_us`` overrides the bucket
    width (default: ~120 buckets across the longest run).
    """
    builder = TraceBuilder()
    tracer = _spans.TRACER
    if tracer is not None:
        builder.add_harness_spans(tracer.drain())
    sidecar = os.environ.get(XC_SPANS_ENV)
    if sidecar and tracer is not None:
        builder.add_xc_sidecar(sidecar, tracer.t0_wall)
    recorder = _events.RECORDER
    runs = recorder.finalized_runs() if recorder is not None else []
    for run in runs:
        builder.add_device_run(run)
    summary = builder.write(path)
    if heatmap_csv is not None:
        _heatmap.write_heatmap_csv(heatmap_csv, runs, bucket_us=bucket_us)
        summary["heatmap_csv"] = heatmap_csv
    return summary
