"""Chrome-trace-event JSON export (Perfetto / ``chrome://tracing``).

One file carries both layers:

* **pid 1 "harness"** — span tracks (one trace row per ``(track, thread)``
  so B/E pairs nest properly): plan/compile/dispatch/stream/scenario/
  watchdog events, plus compile-server spans merged from the ``xc_worker``
  sidecar (epoch-stamped, rebased onto the tracer's wall-clock anchor).
* **pid 10+k, one per device run** — the flight recorder's reconstruction:
  per-plane transaction slices (``tid = plane``; the scan serializes each
  plane, so slices never overlap within a row), chip-occupancy tracks
  (``tid = 10000 + node``) and, for shared-bus designs, channel-bus tracks
  (``tid = 20000 + row``).  Device timestamps are ticks converted to
  microseconds (``ticks * TICK_NS / 1e3``).

``validate_trace`` is the schema checker shared by the test suite and the
CI step (``python -m repro.obs.export <file>``): well-formed JSON, finite
non-negative timestamps sorted nondecreasing, every B matched by an E on
its ``(pid, tid)`` in LIFO order, and non-negative X durations.
"""
from __future__ import annotations

import json
import sys

import numpy as np

from repro.obs import events as _events
from repro.ssd.config import TICK_NS

__all__ = ["TraceBuilder", "validate_trace", "main"]

HARNESS_PID = 1
DEVICE_PID0 = 10
_TID_CHIP = 10_000
_TID_CHAN = 20_000

_US_PER_TICK = TICK_NS / 1e3


class TraceBuilder:
    def __init__(self, max_device_events: int = 2_000_000):
        self.events: list[dict] = []
        self.max_device_events = max_device_events
        self._device_pid = DEVICE_PID0
        self._harness_tids: dict = {}
        self._meta: list[dict] = []

    # ---- low-level emitters --------------------------------------------
    def _name(self, pid: int, tid: int, process: str | None,
              thread: str | None, sort_index: int | None = None) -> None:
        if process is not None:
            self._meta.append({"ph": "M", "pid": pid, "tid": 0,
                               "name": "process_name",
                               "args": {"name": process}})
        if thread is not None:
            self._meta.append({"ph": "M", "pid": pid, "tid": tid,
                               "name": "thread_name",
                               "args": {"name": thread}})
        if sort_index is not None:
            self._meta.append({"ph": "M", "pid": pid, "tid": tid,
                               "name": "thread_sort_index",
                               "args": {"sort_index": sort_index}})

    def _x(self, pid, tid, name, ts, dur, cat, args=None):
        ev = {"ph": "X", "pid": pid, "tid": tid, "name": name,
              "cat": cat, "ts": round(float(ts), 3),
              "dur": round(float(dur), 3)}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def _instant(self, pid, tid, name, ts, cat, args=None):
        ev = {"ph": "i", "pid": pid, "tid": tid, "name": name, "cat": cat,
              "ts": round(float(ts), 3), "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    # ---- layer 2: harness spans ----------------------------------------
    def _harness_tid(self, track: str, thread: int) -> int:
        key = (track, thread)
        tid = self._harness_tids.get(key)
        if tid is None:
            tid = len(self._harness_tids) + 1
            self._harness_tids[key] = tid
            nth = sum(1 for (t, _th) in self._harness_tids if t == track)
            label = track if nth == 1 else f"{track} #{nth}"
            self._name(HARNESS_PID, tid, None, label, sort_index=tid)
        return tid

    def add_harness_spans(self, spans: list) -> None:
        """``SpanTracer.drain()`` output -> B/E pairs + instants.

        A sub-resolution span (duration rounds to 0 at µs.3) becomes an X
        event — its E would otherwise sort before its own B at the shared
        timestamp.  The per-pair ``seq`` tiebreaker pairs identical-bounds
        nested spans LIFO: Bs in emission order, their Es in reverse."""
        self._name(HARNESS_PID, 0, "harness", None)
        for seq, (kind, track, name, ts, dur, args, thread) in \
                enumerate(spans):
            tid = self._harness_tid(track, thread)
            if kind == "instant":
                self._instant(HARNESS_PID, tid, name, ts, "harness", args)
            elif round(float(ts + dur), 3) <= round(float(ts), 3):
                self._x(HARNESS_PID, tid, name, ts, 0.0, "harness", args)
            else:
                ev_b = {"ph": "B", "pid": HARNESS_PID, "tid": tid,
                        "name": name, "cat": "harness",
                        "ts": round(float(ts), 3), "_k": (1, -dur, seq)}
                if args:
                    ev_b["args"] = args
                self.events.append(ev_b)
                self.events.append({"ph": "E", "pid": HARNESS_PID,
                                    "tid": tid, "name": name,
                                    "cat": "harness",
                                    "ts": round(float(ts + dur), 3),
                                    "_k": (0, dur, -seq)})

    def add_xc_sidecar(self, path: str, t0_wall: float) -> int:
        """Merge the compile server's epoch-stamped span log (JSON lines
        ``{"name", "t0_epoch", "dur_s", ...extras}``) onto an
        ``xc_worker`` track; returns the number of spans merged."""
        try:
            with open(path) as fh:
                lines = fh.readlines()
        except OSError:
            return 0
        n = 0
        for line in lines:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
                ts = (float(rec["t0_epoch"]) - t0_wall) * 1e6
                dur = float(rec["dur_s"]) * 1e6
            except (ValueError, KeyError, TypeError):
                continue
            tid = self._harness_tid("xc_worker", -1)
            args = {k: v for k, v in rec.items()
                    if k not in ("t0_epoch", "dur_s")}
            self._x(HARNESS_PID, tid, rec.get("name", "compile"),
                    max(ts, 0.0), dur, "xc_worker", args or None)
            n += 1
        return n

    # ---- layer 1: device runs ------------------------------------------
    def add_device_run(self, run: dict) -> None:
        """One finalized flight-recorder run -> transaction + occupancy
        tracks (see ``events.derive_timeline`` for the reconstruction)."""
        pid = self._device_pid
        self._device_pid += 1
        label = f"device: {run['design']}"
        if run["label"]:
            label += f" [{run['label']}]"
        self._name(pid, 0, label, None)
        n = run["n"]
        if n == 0:
            return
        if n > self.max_device_events:
            self._instant(pid, 1, "run_dropped", 0.0, "meta",
                          {"n_txns": int(n)})
            return
        tl = _events.derive_timeline(run)
        comp = run["completion"]
        t0 = tl["t0"]
        kind_name = np.array(["read", "write", "erase"])
        knames = kind_name[np.minimum(run["kind"], 2)]
        failed = run["failed"]

        planes = np.unique(run["plane"])
        for p in planes:
            self._name(pid, int(p) + 1, None, f"plane {int(p)}",
                       sort_index=int(p) + 1)
        phase_items = list(tl["phases"].items())
        for i in range(n):
            args = {
                "arrival_us": round(run["arrival"][i] * _US_PER_TICK, 3),
                "queue_us": round(int(tl["queue"][i]) * _US_PER_TICK, 3),
                "wait_us": round(int(run["wait"][i]) * _US_PER_TICK, 3),
                "conflict": bool(run["conflict"][i]),
                "hops": int(run["hops"][i]),
                "tries": int(run["tries"][i]),
                "chip": int(run["node"][i]),
                "chan": int(run["row"][i]),
            }
            for pname, arr in phase_items:
                args[f"{pname}_us"] = round(int(arr[i]) * _US_PER_TICK, 3)
            name = str(knames[i])
            if failed[i]:
                name = "FAILED " + name
                args["timeout_us"] = round(
                    _events.FAIL_TIMEOUT * _US_PER_TICK, 3)
            self._x(pid, int(run["plane"][i]) + 1, name,
                    t0[i] * _US_PER_TICK,
                    max(int(comp[i] - t0[i]), 0) * _US_PER_TICK,
                    "txn", args)

        chips = np.unique(run["node"])
        for c in chips:
            self._name(pid, _TID_CHIP + int(c), None, f"chip {int(c)}",
                       sort_index=_TID_CHIP + int(c))
        count_bus = run["scalars"]["count_bus"]
        if count_bus:
            for r in np.unique(run["row"]):
                self._name(pid, _TID_CHAN + int(r), None,
                           f"chan {int(r)} (bus)",
                           sort_index=_TID_CHAN + int(r))
        for s, e, mask in tl["occ"]:
            idx = np.flatnonzero(mask & (e > s))
            for i in idx:
                ts = s[i] * _US_PER_TICK
                dur = int(e[i] - s[i]) * _US_PER_TICK
                self._x(pid, _TID_CHIP + int(run["node"][i]), "xfer",
                        ts, dur, "occ")
                if count_bus:
                    self._x(pid, _TID_CHAN + int(run["row"][i]), "xfer",
                            ts, dur, "occ")

        for marker in run["faults"]:
            t_us = marker["t_tick"] * _US_PER_TICK
            for c in marker["dead_chips"]:
                self._instant(pid, _TID_CHIP + int(c), "DEAD", t_us,
                              "fault", {"t_tick": marker["t_tick"]})
            if marker["n_dead_other"] or not marker["dead_chips"]:
                self._instant(pid, 1, "fault_arrival", t_us, "fault",
                              {"dead_chips": len(marker["dead_chips"]),
                               "dead_links_fcs": marker["n_dead_other"]})

    # ---- output ---------------------------------------------------------
    def write(self, path: str) -> dict:
        recorder = _events.RECORDER
        meta = {"tick_ns": TICK_NS}
        if recorder is not None and recorder.dropped_runs:
            meta["dropped_runs"] = recorder.dropped_runs
            meta["dropped_txns"] = recorder.dropped_txns
        # secondary key breaks same-timestamp ties: E before B (a span
        # ending exactly where another begins closes first), inner E
        # (smaller dur) before outer E, outer B (larger dur) before inner
        # B — keeps every (pid, tid) stack LIFO-consistent post-sort
        ordered = sorted(self.events,
                         key=lambda e: (e["ts"], e.get("_k", (1, 0.0))))
        for ev in ordered:
            ev.pop("_k", None)
        events = self._meta + ordered
        doc = {"traceEvents": events, "displayTimeUnit": "ms",
               "otherData": meta}
        with open(path, "w") as fh:
            json.dump(doc, fh, separators=(",", ":"))
        return {
            "path": path,
            "n_events": len(events),
            "n_txn": sum(1 for e in self.events if e.get("cat") == "txn"),
            "n_device_pids": self._device_pid - DEVICE_PID0,
            "n_harness_tracks": len(self._harness_tids),
        }


def validate_trace(path_or_doc) -> dict:
    """Schema-validate a trace file (or parsed doc); raises ValueError on
    the first violation, returns a summary dict on success."""
    if isinstance(path_or_doc, (str, bytes)):
        with open(path_or_doc) as fh:
            doc = json.load(fh)
    else:
        doc = path_or_doc
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace: missing traceEvents")
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("trace: traceEvents empty")
    stacks: dict = {}
    last_ts = None
    counts = {"X": 0, "B": 0, "E": 0, "i": 0, "M": 0}
    n_txn = 0
    pids = set()
    for k, ev in enumerate(events):
        ph = ev.get("ph")
        if ph not in ("X", "B", "E", "i", "M"):
            raise ValueError(f"trace[{k}]: unknown ph {ph!r}")
        counts[ph] += 1
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or not np.isfinite(ts) or ts < 0:
            raise ValueError(f"trace[{k}]: bad ts {ts!r}")
        if last_ts is not None and ts < last_ts:
            raise ValueError(
                f"trace[{k}]: ts not monotonic ({ts} < {last_ts})")
        last_ts = ts
        pids.add(ev.get("pid"))
        key = (ev.get("pid"), ev.get("tid"))
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"trace[{k}]: bad X dur {dur!r}")
            if ev.get("cat") == "txn":
                n_txn += 1
        elif ph == "B":
            stacks.setdefault(key, []).append(ev.get("name"))
        elif ph == "E":
            stack = stacks.get(key)
            if not stack:
                raise ValueError(f"trace[{k}]: E without B on {key}")
            top = stack.pop()
            if ev.get("name") not in (None, top):
                raise ValueError(
                    f"trace[{k}]: E {ev.get('name')!r} closes B {top!r}")
    open_spans = {k: v for k, v in stacks.items() if v}
    if open_spans:
        raise ValueError(f"trace: unclosed B spans on {open_spans}")
    return {"n_events": len(events), "n_txn": n_txn, "counts": counts,
            "n_pids": len(pids)}


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.obs.export TRACE.json", file=sys.stderr)
        return 2
    try:
        summary = validate_trace(argv[0])
    except (ValueError, OSError, json.JSONDecodeError) as e:
        print(f"INVALID trace {argv[0]}: {e}", file=sys.stderr)
        return 1
    print(f"OK {argv[0]}: {summary}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
