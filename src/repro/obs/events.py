"""Device flight recorder: per-transaction timelines from post-scan arrays.

The jitted scan already emits everything a timeline needs — per-transaction
``completion``, ``wait``, ``hops``, ``tries``, ``scout_steps`` — and the
step's timing algebra is deterministic, so the recorder reconstructs event
timelines *after* the scan from those outputs plus the lane's lowered
timing scalars.  Nothing is added to the scan carry: executables, cache
keys and figure CSVs are byte-identical with the recorder on or off (the
hook sites in ``sweep_plan``/``stream`` are one ``is None`` check).

Reconstruction (exact, vectorized numpy; see DESIGN.md §9):

* ``t0`` (service-candidate time, ``max(arrival, plane_free)``) is replayed
  host-side: within each plane, the scan serializes transactions —
  ``plane_free`` after a transaction is its ``done`` — so a grouped
  shift of completions reproduces every ``t0`` bit-exactly for both step
  kinds.
* **Statically-routed lanes**: phase durations come straight from the step
  formulas (``d0 = ovh + cmd (+xfer for writes)``, flash op, ``d1 = ovh +
  xfer`` for reads) and ``completion = t0 + wait + d0 + op (+ d1)`` holds
  identically.  Only the *placement* of ``wait`` is canonicalized (all of
  it immediately after ``t0``; the scan may split it across the two bus
  phases of a read) — durations are exact.
* **Scout lanes (venice)**: the committed circuit is
  ``[t_resv, commit_end)`` with ``commit_end = completion`` for reads and
  ``completion - op`` for writes/erases, circuit length from the same
  cmd/xfer algebra, and the scout round-trip from ``scout_steps``/``hops``
  — all recovered from outputs.  FC/chip availability stalls that the
  scan folds into the schedule (not into ``wait``) appear as the residual
  between arrival and reservation.
* **Failed transactions** (dead path, ISSUE 8) occupy nothing and render
  as a ``FAIL_TIMEOUT``-long "timeout" slice.

Per-window streamed runs append with their absolute int64 tick base; the
concatenation of a stream's windows is the monolithic nominal order, so a
streamed trace is event-identical to the monolithic trace of the same
prefix (pinned by ``tests/test_obs.py``).
"""
from __future__ import annotations

import threading

import numpy as np

from repro.ssd.config import TICK_NS

__all__ = ["DeviceRecorder", "RECORDER", "derive_timeline"]

# Mirrors ``sim.FAIL_TIMEOUT`` (obs never imports sim: sim imports jax and
# also hooks back into this module).  Pinned equal by tests/test_obs.py.
FAIL_TIMEOUT = 1 << 20

KIND_READ = 0

# LaneTables per-design scalars the reconstruction needs.
_SCALARS = ("ovh", "cmd_base_ns", "xfer_num", "xfer_den", "hop_ns",
            "count_bus", "hold", "fc_nearest")

_ARRAY_FIELDS = ("arrival", "completion", "wait", "conflict", "hops",
                 "tries", "scout_steps", "misroutes", "failed", "kind",
                 "op", "node", "row", "plane", "nbytes")


def _scalars_of(tables_row) -> dict:
    out = {}
    for name in _SCALARS:
        v = np.asarray(getattr(tables_row, name))
        out[name] = bool(v) if v.dtype == bool else int(v)
    return out


class DeviceRecorder:
    """Accumulates per-run (or per-stream-window) transaction arrays.

    ``max_txns`` bounds memory and trace size: a run that would cross the
    budget is counted in ``dropped_runs`` instead of being truncated
    mid-run (a partial timeline is worse than an honest gap); the export
    surfaces the drop in the trace metadata.
    """

    def __init__(self, max_txns: int = 400_000):
        self.max_txns = max_txns
        self.dropped_runs = 0
        self.dropped_txns = 0
        self._runs: list[dict] = []
        self._streams: dict = {}  # (stream_id, design) -> run dict
        self._total = 0
        self._next_stream = 0
        self._pending_faults: dict = {}
        self._lock = threading.Lock()

    # ---- identity -------------------------------------------------------
    def stream_token(self) -> int:
        with self._lock:
            self._next_stream += 1
            return self._next_stream

    # ---- recording ------------------------------------------------------
    def _admit(self, n: int) -> bool:
        with self._lock:
            if self._total + n > self.max_txns:
                self.dropped_runs += 1
                self.dropped_txns += n
                return False
            self._total += n
            return True

    def record_run(self, cfg, design: str, txns, order, op, outs, n: int,
                   tables_row, is_scout: bool, label: str = "") -> None:
        """One monolithic lane result, in scan (nominal-ordered) space —
        called from ``sweep_plan.execute_sim_runs`` next to
        ``_finish_result`` with the same ingredients."""
        if n == 0 or not self._admit(n):
            return

        def f(name):
            return np.asarray(txns[name])[order].astype(np.int64)

        run = self._new_run(cfg, design, tables_row, is_scout, label)
        self._append(run, {
            "arrival": f("arrival"),
            "kind": f("kind"),
            "node": f("node"),
            "row": f("row"),
            "plane": f("plane"),
            "nbytes": f("nbytes"),
            "op": np.asarray(op[:n], np.int64),
        }, outs, n, base=0)
        with self._lock:
            self._runs.append(run)

    def record_window(self, cfg, design: str, packed, op, out_row,
                      base: int, n: int, arrival_abs, tables_row,
                      is_scout: bool, stream_id: int,
                      label: str = "") -> None:
        """One streamed window for one design lane; ``base = w * W`` shifts
        window-frame completions to absolute int64 ticks.  Windows of one
        ``(stream_id, design)`` accumulate into a single run whose
        concatenation equals the monolithic timeline."""
        if n == 0 or not self._admit(n):
            return
        key = (stream_id, design)
        with self._lock:
            run = self._streams.get(key)
            if run is None:
                run = self._new_run(cfg, design, tables_row, is_scout,
                                    label or f"stream{stream_id}")
                self._streams[key] = run
                self._runs.append(run)
        self._append(run, {
            "arrival": np.asarray(arrival_abs, np.int64),
            "kind": np.asarray(packed.kind[:n], np.int64),
            "node": np.asarray(packed.node[:n], np.int64),
            "row": np.asarray(packed.row[:n], np.int64),
            "plane": np.asarray(packed.plane[:n], np.int64),
            "nbytes": np.asarray(packed.nbytes[:n], np.int64),
            "op": np.asarray(op[:n], np.int64),
        }, out_row, n, base=base)

    def record_fault_swap(self, design: str, t_tick: int, tables_row,
                          n_nodes: int, stream_id: int | None = None) -> None:
        """A FaultSpec took effect at ``t_tick``: note the dead chips (their
        tracks render a termination marker) and the count of dead
        links/FCs."""
        res_dead = np.asarray(tables_row.res_dead, bool)
        dead_chips = np.flatnonzero(res_dead[-n_nodes:]) if n_nodes else []
        marker = {
            "t_tick": int(t_tick),
            "dead_chips": [int(c) for c in dead_chips],
            "n_dead_other": int(res_dead[:-n_nodes].sum()) if n_nodes
            else int(res_dead.sum()),
        }
        with self._lock:
            if stream_id is not None:
                run = self._streams.get((stream_id, design))
                if run is not None:
                    run["faults"].append(marker)
                    return
            self._pending_faults.setdefault(design, []).append(marker)

    # ---- internals ------------------------------------------------------
    def _new_run(self, cfg, design, tables_row, is_scout, label) -> dict:
        run = {
            "design": design,
            "label": label,
            "is_scout": bool(is_scout),
            "rows": cfg.rows,
            "cols": cfg.cols,
            "n_nodes": cfg.rows * cfg.cols,
            "n_planes": cfg.n_planes,
            "scout_hop_ns": int(round(cfg.scout_flit_ns)),
            "scalars": _scalars_of(tables_row),
            "faults": list(self._pending_faults.pop(design, ())),
            "chunks": {f: [] for f in _ARRAY_FIELDS},
        }
        return run

    def _append(self, run: dict, fields: dict, outs, n: int,
                base: int) -> None:
        ch = run["chunks"]
        ch["completion"].append(
            np.asarray(outs.completion[:n], np.int64) + base)
        ch["wait"].append(np.asarray(outs.wait[:n], np.int64))
        ch["conflict"].append(np.asarray(outs.conflict[:n], bool))
        ch["hops"].append(np.asarray(outs.hops[:n], np.int64))
        ch["tries"].append(np.asarray(outs.tries[:n], np.int64))
        ch["scout_steps"].append(np.asarray(outs.scout_steps[:n], np.int64))
        ch["misroutes"].append(np.asarray(outs.misroutes[:n], np.int64))
        failed = getattr(outs, "failed", None)
        ch["failed"].append(np.asarray(failed[:n], bool) if failed is not None
                            else np.zeros((n,), bool))
        for name, arr in fields.items():
            ch[name].append(arr)

    def finalized_runs(self) -> list[dict]:
        """Concatenate each run's window chunks into flat arrays (idempotent
        — safe to export more than once)."""
        with self._lock:
            runs = list(self._runs)
        out = []
        for run in runs:
            r = dict(run)
            r.pop("chunks")
            for f in _ARRAY_FIELDS:
                chunks = run["chunks"][f]
                r[f] = (np.concatenate(chunks) if chunks
                        else np.zeros((0,), np.int64))
            r["n"] = len(r["completion"])
            out.append(r)
        return out


# The one process-wide recorder; None = disabled (see ``repro.obs``).
# Hook sites read this global and skip everything when it is None.
RECORDER: DeviceRecorder | None = None


def _ceil_div(a, b):
    return -(-a // b)


def _tcand(plane: np.ndarray, arrival: np.ndarray,
           completion: np.ndarray) -> np.ndarray:
    """Replay ``t0 = max(arrival, plane_free)`` from completions.

    The scan serializes each plane: ``plane_free`` seen by a transaction is
    the ``done`` of the previous transaction on its plane (in scan order).
    A stable plane-grouped shift of ``completion`` therefore reproduces
    every candidate time exactly, for both step kinds."""
    n = len(plane)
    if n == 0:
        return np.zeros((0,), np.int64)
    idx = np.argsort(plane, kind="stable")  # groups planes, keeps scan order
    p = plane[idx]
    prev = np.empty((n,), np.int64)
    prev[0] = 0
    prev[1:] = completion[idx][:-1]
    first = np.empty((n,), bool)
    first[0] = True
    first[1:] = p[1:] != p[:-1]
    prev[first] = 0
    t0 = np.maximum(arrival[idx], prev)
    out = np.empty((n,), np.int64)
    out[idx] = t0
    return out


def derive_timeline(run: dict) -> dict:
    """Exact per-transaction phase/interval reconstruction for one
    finalized run (see module docstring for the algebra).

    Returns numpy arrays (ticks, int64):
      ``t0``            candidate/service-queue exit time per txn
      ``queue``         ``t0 - arrival``
      ``phases``        dict of canonical phase durations
      ``occ``           list of ``(start, end, mask)`` resource-occupancy
                        segments — held on the chip (and, for bus designs,
                        the channel) during ``[start, end)`` where ``mask``
    """
    sc = run["scalars"]
    kind = run["kind"]
    read = kind == KIND_READ
    hops = run["hops"]
    op = run["op"]
    completion = run["completion"]
    failed = run["failed"]
    ok = ~failed

    cmd = np.maximum(
        _ceil_div(sc["cmd_base_ns"] + hops * sc["hop_ns"], TICK_NS), 1)
    xfer = _ceil_div(
        _ceil_div(run["nbytes"] * sc["xfer_num"], sc["xfer_den"])
        + hops * sc["hop_ns"], TICK_NS)
    t0 = _tcand(run["plane"], run["arrival"], completion)
    queue = t0 - run["arrival"]

    if not run["is_scout"]:
        d0 = sc["ovh"] + cmd + np.where(read, 0, xfer)
        d1 = np.where(read, sc["ovh"] + xfer, 0)
        # canonical wait-first placement: phase-0 runs back-to-back with
        # the flash op and the (read) return transfer ending at completion
        e0 = completion - d1 - op
        s0 = e0 - d0
        occ = [(s0, e0, ok)]
        if bool(read.any()):
            occ.append((completion - d1, completion, ok & read))
        # fc_nearest lanes (nossd) wait for the selected FC *before* the
        # step's t0, outside the scan's ``wait`` — it falls out as the
        # exact residual of the completion identity (0 for fixed-FC lanes)
        fc_stall = np.where(
            ok, completion - (t0 + run["wait"] + d0 + op + d1), 0)
        phases = {"fc_stall": fc_stall, "wait": run["wait"],
                  "cmd_data": d0, "flash": op, "read_xfer": d1}
    else:
        hold = sc["hold"]
        if hold:
            dur = np.where(read, cmd + op + xfer, cmd + xfer)
        else:
            dur = np.where(read, xfer, cmd + xfer)
        commit_end = completion - np.where(read, 0, op)
        rtt = _ceil_div((run["scout_steps"] + hops) * run["scout_hop_ns"],
                        TICK_NS)
        t_resv = commit_end - dur - rtt
        occ = [(t_resv, commit_end, ok)]
        phases = {"wait": run["wait"], "scout_rtt": rtt, "circuit": dur,
                  "flash": op}

    return {"t0": t0, "queue": queue, "phases": phases, "occ": occ,
            "cmd": cmd, "xfer": xfer}
