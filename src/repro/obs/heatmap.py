"""Resource x time-bucket heatmaps from flight-recorder runs.

Turns the recorder's exact occupancy intervals into two matrices per run:

* **utilization** — busy ticks per (chip, bucket), spread *exactly*: an
  interval contributes its precise overlap with every bucket it crosses
  (partial edges + a difference-array cumsum for the full middle buckets),
  so each row's sum equals the chip's total held ticks to the tick.
* **conflicts** — transaction conflict counts per (chip, bucket), binned
  at the transaction's service start ``t0``.

Exported as one long-format CSV (and optionally JSON) so a spreadsheet or
the EXPERIMENTS.md walkthrough can pivot it:
``run,design,metric,resource,bucket,bucket_start_us,value``.
"""
from __future__ import annotations

import csv
import json

import numpy as np

from repro.obs import events as _events
from repro.ssd.config import TICK_NS

__all__ = ["bucket_matrix", "run_heatmaps", "write_heatmap_csv"]


def bucket_matrix(starts: np.ndarray, ends: np.ndarray,
                  resource: np.ndarray, n_resources: int,
                  bucket_ticks: int, n_buckets: int) -> np.ndarray:
    """Exact busy-ticks per (resource, bucket) for intervals [start, end).

    Vectorized: single-bucket intervals add their full length via
    ``np.add.at``; multi-bucket intervals add partial head/tail overlaps
    plus a per-row difference array (cumsum = ``bucket_ticks`` for every
    interior bucket).  Intervals outside [0, n_buckets*bucket_ticks) are
    clipped."""
    out = np.zeros((n_resources, n_buckets), np.int64)
    if len(starts) == 0 or n_buckets == 0:
        return out
    span = n_buckets * bucket_ticks
    s = np.clip(starts, 0, span).astype(np.int64)
    e = np.clip(ends, 0, span).astype(np.int64)
    keep = e > s
    s, e, r = s[keep], e[keep], np.asarray(resource)[keep]
    if len(s) == 0:
        return out
    b0 = s // bucket_ticks
    b1 = (e - 1) // bucket_ticks  # last bucket touched
    flat = out.reshape(-1)
    one = b0 == b1
    np.add.at(flat, r[one] * n_buckets + b0[one], (e - s)[one])
    multi = ~one
    if multi.any():
        rm, b0m, b1m = r[multi], b0[multi], b1[multi]
        head = (b0m + 1) * bucket_ticks - s[multi]
        tail = e[multi] - b1m * bucket_ticks
        np.add.at(flat, rm * n_buckets + b0m, head)
        np.add.at(flat, rm * n_buckets + b1m, tail)
        # full interior buckets (b0+1 .. b1-1) via difference array
        diff = np.zeros((n_resources, n_buckets + 1), np.int64)
        dflat = diff.reshape(-1)
        np.add.at(dflat, rm * (n_buckets + 1) + b0m + 1, 1)
        np.add.at(dflat, rm * (n_buckets + 1) + b1m, -1)
        out += np.cumsum(diff[:, :-1], axis=1) * bucket_ticks
    return out


def _pick_bucket_ticks(runs: list[dict], bucket_us: float | None,
                       target_buckets: int = 120) -> int:
    if bucket_us is not None:
        return max(int(round(bucket_us * 1e3 / TICK_NS)), 1)
    hi = 0
    for run in runs:
        if run["n"]:
            hi = max(hi, int(run["completion"].max()))
    return max(hi // target_buckets, 1)


def run_heatmaps(run: dict, bucket_ticks: int) -> dict:
    """Utilization + conflict matrices for one finalized run."""
    n_nodes = run["n_nodes"]
    hi = int(run["completion"].max()) if run["n"] else 0
    n_buckets = hi // bucket_ticks + 1 if run["n"] else 0
    tl = _events.derive_timeline(run)
    util = np.zeros((n_nodes, n_buckets), np.int64)
    for s, e, mask in tl["occ"]:
        util += bucket_matrix(s[mask], e[mask], run["node"][mask],
                              n_nodes, bucket_ticks, n_buckets)
    conflicts = np.zeros((n_nodes, n_buckets), np.int64)
    csel = run["conflict"] & ~run["failed"]
    if csel.any() and n_buckets:
        b = np.clip(tl["t0"][csel] // bucket_ticks, 0, n_buckets - 1)
        np.add.at(conflicts.reshape(-1),
                  run["node"][csel] * n_buckets + b, 1)
    return {"util_ticks": util, "conflicts": conflicts,
            "bucket_ticks": bucket_ticks, "n_buckets": n_buckets}


def write_heatmap_csv(path: str, runs: list[dict],
                      bucket_us: float | None = None,
                      json_path: str | None = None) -> dict:
    """Long-format CSV across every run; returns a summary.  Zero cells are
    skipped (the matrices are sparse in time); a run's busy-tick total is
    preserved exactly (see :func:`bucket_matrix`)."""
    bucket_ticks = _pick_bucket_ticks(runs, bucket_us)
    bucket_out_us = bucket_ticks * TICK_NS / 1e3
    n_rows = 0
    jdoc = []
    with open(path, "w", newline="") as fh:
        w = csv.writer(fh)
        w.writerow(["run", "design", "metric", "resource", "bucket",
                    "bucket_start_us", "value"])
        for k, run in enumerate(runs):
            if not run["n"]:
                continue
            hm = run_heatmaps(run, bucket_ticks)
            tag = run["label"] or str(k)
            for metric, mat in (("util_ticks", hm["util_ticks"]),
                                ("conflicts", hm["conflicts"])):
                res, buck = np.nonzero(mat)
                for r, b in zip(res, buck):
                    w.writerow([
                        tag, run["design"], metric, f"chip{int(r)}",
                        int(b), round(float(b) * bucket_out_us, 3),
                        int(mat[r, b]),
                    ])
                    n_rows += 1
            if json_path is not None:
                jdoc.append({
                    "run": tag, "design": run["design"],
                    "bucket_us": bucket_out_us,
                    "util_ticks": hm["util_ticks"].tolist(),
                    "conflicts": hm["conflicts"].tolist(),
                })
    if json_path is not None:
        with open(json_path, "w") as fh:
            json.dump(jdoc, fh)
    return {"path": path, "rows": n_rows, "bucket_us": bucket_out_us,
            "runs": len(runs)}
