"""Harness span tracer: wall-clock instrumentation of the pipeline.

Hook sites (``sweep_plan``, ``sim``, ``stream``, ``scenario``,
``benchmarks/run.py``) call the module-level :func:`span` /
:func:`instant` helpers, which are no-ops while ``TRACER`` is None — the
disabled cost is one global read per call.  Enabled, every span records
``(track, name, t_start, duration, args, thread)`` against a monotonic
clock anchored at the tracer's creation; ``t0_wall`` (epoch seconds at the
same instant) lets out-of-process sidecar events (the ``xc_worker``
compile server) land on the same timeline.

Spans from different threads go to different trace rows (the exporter
keys tracks by ``(track, thread)``), so B/E pairs always nest properly —
a ``with span(...)`` block *is* the nesting.
"""
from __future__ import annotations

import contextlib
import threading
import time

__all__ = ["SpanTracer", "TRACER", "span", "instant"]


class SpanTracer:
    def __init__(self, max_events: int = 200_000):
        self.t0_wall = time.time()
        self.t0_perf = time.perf_counter()
        self.max_events = max_events
        self._events: list = []
        self._dropped = 0
        self._lock = threading.Lock()

    def now_us(self) -> float:
        return (time.perf_counter() - self.t0_perf) * 1e6

    def _add(self, ev: tuple) -> None:
        with self._lock:
            if len(self._events) >= self.max_events:
                self._dropped += 1
                return
            self._events.append(ev)

    def complete(self, track: str, name: str, ts_us: float, dur_us: float,
                 args: dict | None = None) -> None:
        """One finished span (exported as a B/E pair)."""
        self._add(("span", track, name, ts_us, max(dur_us, 0.0), args,
                   threading.get_ident()))

    def instant(self, track: str, name: str, args: dict | None = None,
                ts_us: float | None = None) -> None:
        ts = self.now_us() if ts_us is None else ts_us
        self._add(("instant", track, name, ts, 0.0, args,
                   threading.get_ident()))

    @contextlib.contextmanager
    def span(self, track: str, name: str, **args):
        t0 = self.now_us()
        try:
            yield
        finally:
            self.complete(track, name, t0, self.now_us() - t0,
                          args or None)

    def drain(self) -> list:
        """All recorded events (sorted by start time); tracer keeps them —
        export is repeatable."""
        with self._lock:
            evs = sorted(self._events, key=lambda e: e[3])
            if self._dropped:
                evs.append(("instant", "tracer", "events_dropped",
                            self.now_us(), 0.0,
                            {"dropped": self._dropped},
                            threading.get_ident()))
            return evs


# The one process-wide tracer; None = disabled (see ``repro.obs``).
TRACER: SpanTracer | None = None


@contextlib.contextmanager
def span(track: str, name: str, **args):
    """``with span("compile", "ensure_compiled", key=...):`` — no-op when
    tracing is off."""
    tr = TRACER
    if tr is None:
        yield
        return
    t0 = tr.now_us()
    try:
        yield
    finally:
        tr.complete(track, name, t0, tr.now_us() - t0, args or None)


def instant(track: str, name: str, **args) -> None:
    tr = TRACER
    if tr is not None:
        tr.instant(track, name, args or None)
