"""Jit'd wrappers and the batched-DFS driver around the scout-step kernel.

``route_batch`` routes a whole batch of scouts to their destinations by
iterating the Algorithm-1 step (Pallas kernel or jnp reference) inside a
``lax.while_loop``, with the DFS backtracking stack kept in regular JAX.
This is the building block for the design-space sweeps (§6.5) and the
beyond-paper k-scout variant (launch k candidate scouts, keep the best).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import MeshTopology
from repro.kernels.backend import default_interpret
from repro.kernels.ref import scout_step_ref
from repro.kernels.scout_step import LINK_PAD, STATE_W, pack_tables, scout_step_pallas


class BatchRouteOut(NamedTuple):
    success: jnp.ndarray  # bool [B]
    path_mask: jnp.ndarray  # bool [B, LINK_PAD]
    hops: jnp.ndarray  # int32 [B]
    steps: jnp.ndarray  # int32 [B]
    misroutes: jnp.ndarray  # int32 [B]


def _pad_b(x, b_tile):
    B = x.shape[0]
    pad = (-B) % b_tile
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], 0)
    return x


def route_dfs(step_fn, port_link, src, dst, busy0, seeds, *, n_pad, b_tile):
    """Route a batch of scouts to their destinations: the DFS driver core.

    ``step_fn(state, busy, tried) -> (state', busy', tried')`` is one
    Algorithm-1 decision step (Pallas kernel or the jnp reference); this
    function supplies the backtracking memory around it — driver-resident
    DFS stacks, push on advance, pop (and link release) on backtrack —
    inside a ``lax.while_loop``.  Plain traceable JAX: callers jit it (or
    embed it in a larger jitted program, as the batched scout lane runner
    does).

    ``busy0`` is bool/int [B, L]; columns are padded to ``LINK_PAD`` when
    narrower (wider maps pass through untouched), rows to a multiple of
    ``b_tile`` with src == dst == 0 scouts that finish on the first step.
    ``n_pad`` is the packed-table row count (``pack_tables(topo).shape[0]``)
    sizing the tried bitmap.  Returned ``path_mask`` is the links this
    walk reserved (final busy minus initial busy), full padded width.
    """
    n_nodes = port_link.shape[0]
    cap = 4 * n_nodes
    B = src.shape[0]
    Bp = B + ((-B) % b_tile)
    state = jnp.zeros((Bp, STATE_W), jnp.int32)
    state = state.at[:B, 0].set(src)
    state = state.at[:B, 1].set(dst)
    state = state.at[:, 2].set(-1)
    state = state.at[:B, 3].set(seeds.astype(jnp.int32))
    busy = _pad_b(busy0.astype(jnp.int32), b_tile)
    if busy.shape[1] < LINK_PAD:
        busy = jnp.pad(busy, ((0, 0), (0, LINK_PAD - busy.shape[1])))
    busy0_p = busy.astype(bool)
    tried = jnp.zeros((Bp, 4 * n_pad), jnp.int32)

    stack_node = jnp.zeros((Bp, cap), jnp.int32)
    stack_entry = jnp.zeros((Bp, cap), jnp.int32)
    stack_exit = jnp.zeros((Bp, cap), jnp.int32)
    stack_mis = jnp.zeros((Bp, cap), jnp.int32)
    depth = jnp.zeros((Bp,), jnp.int32)
    done = jnp.zeros((Bp,), bool)
    success = jnp.zeros((Bp,), bool)
    steps = jnp.zeros((Bp,), jnp.int32)

    def cond(c):
        return ~jnp.all(c[0])

    def body(c):
        (done, success, state, busy, tried, stack_node, stack_entry,
         stack_exit, stack_mis, depth, steps) = c
        prev_state, prev_busy = state, busy
        cur_prev = state[:, 0]
        entry_prev = state[:, 2]
        s2, b2, t2 = step_fn(state, busy, tried)
        act = ~done
        flags = s2[:, 4]
        advanced = act & (flags == 1)
        at_dst = act & (flags == 2)
        backtrack = act & (flags == 0)

        rows = jnp.arange(Bp)
        # push on advance
        d = depth
        stack_node = stack_node.at[rows, d].set(
            jnp.where(advanced, cur_prev, stack_node[rows, d])
        )
        stack_entry = stack_entry.at[rows, d].set(
            jnp.where(advanced, entry_prev, stack_entry[rows, d])
        )
        stack_exit = stack_exit.at[rows, d].set(
            jnp.where(advanced, s2[:, 5], stack_exit[rows, d])
        )
        stack_mis = stack_mis.at[rows, d].set(
            jnp.where(advanced, s2[:, 6], stack_mis[rows, d])
        )
        # pop on backtrack
        can_pop = backtrack & (depth > 0)
        fail = backtrack & (depth == 0)
        dm1 = jnp.maximum(depth - 1, 0)
        pnode = stack_node[rows, dm1]
        pentry = stack_entry[rows, dm1]
        pexit = stack_exit[rows, dm1]
        plink = port_link[pnode, pexit]
        busy_new = jnp.where(
            can_pop[:, None]
            & (jax.lax.broadcasted_iota(jnp.int32, b2.shape, 1) == plink[:, None]),
            0,
            b2,
        )
        state_new = jnp.where(act[:, None], s2, prev_state)
        state_new = state_new.at[:, 0].set(
            jnp.where(can_pop, pnode, state_new[:, 0])
        )
        state_new = state_new.at[:, 2].set(
            jnp.where(can_pop, pentry, state_new[:, 2])
        )
        busy_new = jnp.where(act[:, None], busy_new, prev_busy)
        tried_new = jnp.where(act[:, None], t2, tried)
        depth = depth + advanced.astype(jnp.int32) - can_pop.astype(jnp.int32)
        steps = steps + act.astype(jnp.int32)
        done = done | at_dst | fail
        success = success | at_dst
        return (done, success, state_new, busy_new, tried_new, stack_node,
                stack_entry, stack_exit, stack_mis, depth, steps)

    init = (done, success, state, busy, tried, stack_node, stack_entry,
            stack_exit, stack_mis, depth, steps)
    (done, success, state, busy, tried, stack_node, stack_entry,
     stack_exit, stack_mis, depth, steps) = jax.lax.while_loop(cond, body, init)

    path_mask = busy.astype(bool) & ~busy0_p
    in_path = jax.lax.broadcasted_iota(jnp.int32, stack_mis.shape, 1) < depth[:, None]
    mis = jnp.sum(stack_mis * in_path, axis=1)
    return BatchRouteOut(
        success=success[:B],
        path_mask=path_mask[:B],
        hops=depth[:B],
        steps=steps[:B],
        misroutes=mis[:B],
    )


def make_route_batch(
    topo: MeshTopology,
    use_pallas: bool = True,
    interpret: bool | None = None,
    b_tile: int = 256,
    allow_nonminimal: bool = True,
    dead_links=None,
):
    """Build a jitted ``(src, dst, busy0, seeds) -> BatchRouteOut``.

    ``interpret=None`` (the default) picks interpreter mode from the
    actual JAX backend — compiled on GPU/TPU, interpreted on CPU — so
    the kernel is never silently interpreted on a real accelerator.
    Pass ``True``/``False`` to force either mode.

    ``dead_links`` (bool [n_links] or None) bakes a failed-link mask into
    the router: dead links look permanently busy to every scout — the DFS
    routes around them — and are excluded from the returned ``path_mask``
    (a scout never reserves a dead link).  None or all-False is the
    fault-free router, bit-identical to omitting the argument.
    """
    interpret = default_interpret(interpret)
    dead_row = None
    if dead_links is not None and np.any(dead_links):
        dead_row = jnp.asarray(np.asarray(dead_links, bool)[None, :],
                               jnp.int32)
    tables = jnp.asarray(pack_tables(topo))
    n_nodes = topo.n_nodes
    n_pad = tables.shape[0]
    cols = topo.cols
    port_link = jnp.asarray(topo.port_link, jnp.int32)

    if use_pallas:
        step = functools.partial(
            scout_step_pallas,
            cols=cols,
            n_nodes=n_nodes,
            allow_nonminimal=allow_nonminimal,
            interpret=interpret,
            b_tile=b_tile,
        )

        def step_fn(state, busy, tried):
            return step(state, busy, tried, tables)

    else:
        pl_, pn_ = tables[:n_nodes, 0:4], tables[:n_nodes, 4:8]

        def step_fn(state, busy, tried):
            return scout_step_ref(state, busy, tried, pl_, pn_, cols,
                                  allow_nonminimal)

    @jax.jit
    def route(src, dst, busy0, seeds):
        if dead_row is not None:
            # dead links join the global reservation state, so path_mask
            # (reserved minus initially-busy) can never include them
            busy0 = (busy0.astype(jnp.int32) | dead_row).astype(busy0.dtype)
        return route_dfs(step_fn, port_link, src, dst, busy0, seeds,
                         n_pad=n_pad, b_tile=b_tile)

    return route
