"""Pure-jnp oracle for the batched scout-step kernel.

Deliberately written WITHOUT the kernel's one-hot-matmul tricks: plain
``take``/indexing gathers, so a bug in the kernel's TPU-native formulation
cannot hide in a shared implementation.  Decision semantics (candidate
ordering, xorshift32 tie-break, unsigned modulo) mirror
``repro.core.routing.scout_route_ref``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.scout_step import umod, xorshift32_i32

RIGHT, UP, LEFT, DOWN = 0, 1, 2, 3


def scout_step_ref(state, busy, tried, port_link, port_neighbor, cols,
                   allow_nonminimal=True):
    """Reference step: same signature semantics as ``step_math`` but with
    gather-based lookups. state [B,8]; busy [B,L] 0/1; tried [B,4N] 0/1."""
    cur, dst, entry, rng = state[:, 0], state[:, 1], state[:, 2], state[:, 3]
    links4 = port_link[cur]  # [B, 4] gather
    nbrs4 = port_neighbor[cur]

    busyb = busy.astype(bool)
    triedb = tried.astype(bool)
    B = cur.shape[0]
    rows = jnp.arange(B)
    busy4 = busyb[rows[:, None], jnp.clip(links4, 0, busy.shape[1] - 1)]
    tried4 = triedb[rows[:, None], cur[:, None] * 4 + jnp.arange(4)[None, :]]
    free4 = (links4 >= 0) & ~busy4 & ~tried4

    at_dst = cur == dst
    diffx = dst % cols - cur % cols
    diffy = dst // cols - cur // cols
    px = jnp.where(diffx > 0, RIGHT, jnp.where(diffx < 0, LEFT, -1))
    py = jnp.where(diffy > 0, UP, jnp.where(diffy < 0, DOWN, -1))

    def port_free(p):
        return (p >= 0) & free4[rows, jnp.clip(p, 0, 3)]

    fmin = jnp.stack([port_free(px), port_free(py)], axis=1)
    n_min = fmin.sum(1)
    iota4 = jnp.arange(4)[None, :]
    fmis = free4 & (iota4 != entry[:, None])
    if not allow_nonminimal:
        fmis = jnp.zeros_like(fmis)
    n_mis = fmis.sum(1)

    use_min = n_min > 0
    count = jnp.where(use_min, n_min, n_mis).astype(jnp.int32)
    need_rng = (~at_dst) & (count > 1)
    rng_next = jnp.where(need_rng, xorshift32_i32(rng), rng)
    idx = umod(rng_next, jnp.maximum(count, 1))

    cand_ports = jnp.concatenate(
        [px[:, None], py[:, None], jnp.broadcast_to(iota4, (B, 4))], axis=1
    )
    cand_flags = jnp.concatenate(
        [fmin & use_min[:, None], fmis & ~use_min[:, None]], axis=1
    )
    cum = jnp.cumsum(cand_flags, axis=1)
    sel = cand_flags & (cum - 1 == idx[:, None])
    pick = jnp.sum(jnp.where(sel, cand_ports, 0), axis=1).astype(jnp.int32)
    has_pick = (count > 0) & ~at_dst

    link_pick = links4[rows, jnp.clip(pick, 0, 3)]
    nbr_pick = nbrs4[rows, jnp.clip(pick, 0, 3)]
    new_cur = jnp.where(has_pick, nbr_pick, cur)
    new_entry = jnp.where(has_pick, (pick + 2) % 4, entry)
    flags = jnp.where(at_dst, 2, jnp.where(has_pick, 1, 0)).astype(jnp.int32)
    out_pick = jnp.where(has_pick, pick, -1)
    is_mis = (has_pick & ~use_min).astype(jnp.int32)

    state_out = jnp.stack(
        [new_cur, dst, new_entry, rng_next, flags, out_pick, is_mis,
         jnp.where(has_pick, link_pick, 0)],
        axis=1,
    )
    busy_out = busyb.at[rows, jnp.clip(link_pick, 0, busy.shape[1] - 1)].set(
        busyb[rows, jnp.clip(link_pick, 0, busy.shape[1] - 1)] | has_pick
    )
    tried_out = triedb.at[rows, cur * 4 + jnp.clip(pick, 0, 3)].set(
        triedb[rows, cur * 4 + jnp.clip(pick, 0, 3)] | has_pick
    )
    return state_out, busy_out.astype(jnp.int32), tried_out.astype(jnp.int32)
