"""Lane-tiled Pallas wrapper for the batched static step.

The batched runner in ``ssd.sim`` scans a per-tick step function
``step(sp, state, xs) -> (state', out)`` over time-major transaction
tables, where every pytree leaf carries the lane batch ``B`` as its
leading axis and all math is per-lane (element-wise plus reductions over
trailing axes only — the one-hot/bit-unpack lookups from
``kernels.onehot`` replace every gather).  That shape is exactly a Pallas
grid program: tile the lane axis over the grid, hand each program
instance a ``(b_tile, ...)`` block of every operand (scalars, carried
state, and the pre-gathered bit-packed node tables from
``designs.pregather_node_tables``), and run the *same* step closure on
the block.

``lane_tiled_step`` is deliberately generic: it takes the step function
built by ``sim._make_batched_static_step`` (or any step with the same
contract) and returns a drop-in replacement whose body is a
``pl.pallas_call``.  Because the kernel body *is* the original step —
flatten, block, unflatten, call — bit-exactness against the XLA path is
by construction, not by re-implementation; the parity tests pin it
anyway.  Invalid steps stay no-ops for free: the masked-arithmetic
validity path (``enable`` lanes, ``where``-substituted outputs) rides
along inside the step closure untouched.

On CPU the wrapper runs in interpreter mode (Pallas has no CPU
compiler); the kernel body is traced into the surrounding jitted scan,
so CI exercises the identical program structure without an accelerator.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import default_interpret

# Default lane tile.  The step math is purely per-lane, so any tiling of
# the batch axis is value-identical; 256 lanes keeps the per-instance
# working set (state + one tick of tables) comfortably inside VMEM-scale
# scratch for every geometry in the registry.
B_TILE = 256


def _pick_tile(B: int, b_tile: int | None) -> int:
    if b_tile is not None and b_tile > 0 and B % b_tile == 0:
        return b_tile
    if b_tile is None and B % B_TILE == 0:
        return B_TILE
    return B  # grid of 1 — still a valid (and bit-exact) layout


def lane_tiled_step(step_fn, *, b_tile: int | None = None,
                    interpret: bool | None = None):
    """Wrap ``step_fn(sp, state, xs) -> (state', out)`` in a lane-tiled
    ``pl.pallas_call``.

    Every leaf of ``(sp, state, xs)`` and of the result must carry the
    lane batch as its leading axis.  ``interpret=None`` resolves via
    :func:`repro.kernels.backend.default_interpret`.
    """
    interp = default_interpret(interpret)

    def call(sp, state, xs):
        in_leaves, in_tree = jax.tree_util.tree_flatten((sp, state, xs))
        B = in_leaves[0].shape[0]
        bt = _pick_tile(B, b_tile)
        out_avatars = jax.eval_shape(step_fn, sp, state, xs)
        out_leaves, out_tree = jax.tree_util.tree_flatten(out_avatars)
        n_in = len(in_leaves)

        def kernel(*refs):
            vals = [r[...] for r in refs[:n_in]]
            sp_b, state_b, xs_b = jax.tree_util.tree_unflatten(in_tree, vals)
            new_state, out = step_fn(sp_b, state_b, xs_b)
            res = jax.tree_util.tree_leaves((new_state, out))
            for r, v in zip(refs[n_in:], res):
                r[...] = v.astype(r.dtype)

        def spec(leaf):
            nd = leaf.ndim
            return pl.BlockSpec(
                (bt,) + tuple(leaf.shape[1:]),
                lambda i, _nd=nd: (i,) + (0,) * (_nd - 1),
            )

        outs = pl.pallas_call(
            kernel,
            grid=(B // bt,),
            in_specs=[spec(l) for l in in_leaves],
            out_specs=[spec(l) for l in out_leaves],
            out_shape=[jax.ShapeDtypeStruct(l.shape, l.dtype)
                       for l in out_leaves],
            interpret=interp,
        )(*in_leaves)
        return jax.tree_util.tree_unflatten(out_tree, list(outs))

    return call
