"""Backend selection for the Pallas kernels.

Two independent knobs live here:

* ``default_interpret`` — should a ``pl.pallas_call`` run in interpreter
  mode?  Pallas has no CPU compiler, so on the CPU backend the only way to
  execute a kernel is ``interpret=True`` (the kernel body is traced into
  the surrounding XLA program).  On GPU/TPU the compiled path is the whole
  point.  Callers may force either mode explicitly; otherwise we ask JAX.

* ``resolve_lane_backend`` lives in ``ssd.sim`` (it feeds executable-cache
  keys); this module only answers the interpret question so the kernels
  package stays free of simulator imports.
"""
from __future__ import annotations

import os

_ACCELERATORS = ("gpu", "tpu", "cuda", "rocm")


def default_interpret(override: bool | None = None) -> bool:
    """Pick Pallas interpret mode.

    Priority: explicit ``override`` > ``REPRO_PALLAS_INTERPRET`` env var
    ("0"/"1") > the actual JAX backend (interpret everywhere except a real
    accelerator).
    """
    if override is not None:
        return bool(override)
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None and env != "":
        return env not in ("0", "false", "False")
    import jax

    return jax.default_backend() not in _ACCELERATORS
