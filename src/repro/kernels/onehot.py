"""Gather-free lookup primitives: one-hot compare-and-reduce.

The trick behind the Pallas scout kernel (``kernels/scout_step.py``): a
per-element table lookup ``table[idx]`` over a *batch* lowers on CPU/TPU to
a generic gather — the exact lowering that made vmap-batched simulator
lanes ~50x slower in the PR-3 measurement.  Reformulated as a broadcast
compare against an iota followed by a masked reduction, the same lookup is
pure elementwise/reduce work (VPU-friendly, no scatter/gather kernels),
and it is *exact*: precisely one slot of the one-hot is set, so the integer
sum returns that slot's value bit-for-bit.

These helpers are the building blocks of the batched small-lane runner in
``repro.ssd.sim`` (``_make_batched_static_step``); the Pallas kernel keeps
its own fused formulation (its value is layout/tiling, see its docstring).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["onehot", "take", "unpack_bits"]


def onehot(idx, size: int):
    """bool [..., size]: slot ``idx`` set (all-false when idx out of range)."""
    return idx[..., None] == jnp.arange(size, dtype=idx.dtype)


def take(table, idx):
    """Batched ``table[b, idx[b], ...]`` without a gather.

    ``table`` [B, K, ...], ``idx`` int [B] -> [B, ...].  Integer tables
    only (the masked sum over the one-hot axis is exact because exactly
    one slot contributes).
    """
    k = table.shape[1]
    sel = onehot(idx, k).reshape(idx.shape + (k,) + (1,) * (table.ndim - 2))
    return jnp.sum(jnp.where(sel, table, 0), axis=1)


def unpack_bits(words, nbits: int):
    """bool [..., nbits] from little-endian packed bytes [..., W].

    Inverse of ``np.packbits(..., axis=-1, bitorder="little")`` for
    ``W = ceil(nbits / 8)``.
    """
    bits = (words[..., None].astype(jnp.int32) >> jnp.arange(8)) & 1
    return bits.reshape(words.shape[:-1] + (-1,))[..., :nbits].astype(bool)
