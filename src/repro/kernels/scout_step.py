"""Pallas TPU kernel: batched Algorithm-1 scout routing step.

The paper's perf-critical compute is stepping many scout state machines
against the link-occupancy map (§4.3: every in-flight I/O request runs the
routing algorithm, and the design-space sweeps in §6.5 step millions of
scouts).  A GPU port would chase pointers per packet; the TPU-native
formulation instead makes every per-node table lookup a *compare-and-reduce
against broadcast iotas* over the whole scout batch — pure VPU/MXU work with
no gathers:

  * ``port_link[cur, p]`` becomes ``one_hot(cur) · port_link`` (a [B,N]×[N,4]
    matmul on the MXU),
  * per-port busy/tried tests become ``(ids[...,None] == iota) & bitmap``
    reductions over the lane dimension.

Layout: scout state is packed into an int32 ``[B, 8]`` array (cur, dst,
entry, rng, 4 pad lanes); busy is ``[B, 128]`` (112 mesh links + pad) and
tried is ``[B, 256]`` (64 nodes x 4 ports).  The batch is tiled over the grid
with explicit VMEM BlockSpecs; one tile's working set at B_TILE=256 is
256x(8+128+256+128+8)x4B ≈ 541 KiB < 1 MiB VMEM in fp32 words — comfortably
resident, with the lane dimension 128-aligned for the VPU.

The kernel computes the *decision* of Algorithm 1 (minimal-adaptive with
random tie-break, else misroute, else backtrack) plus the state advance;
the DFS stack (backtracking memory) lives in the driver (``ops.py``), which
is regular JAX.  ``ref.py`` is the pure-jnp oracle; tests sweep shapes,
meshes and occupancy densities in ``interpret=True`` mode and also replay
full DFS walks against ``repro.core.routing.scout_route_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.topology import MeshTopology
from repro.kernels.backend import default_interpret

RIGHT, UP, LEFT, DOWN = 0, 1, 2, 3
LINK_PAD = 128  # lane-aligned link bitmap (8x8 mesh has 112 links)
STATE_W = 8  # cur, dst, entry, rng, flags(out), pick(out), pad, pad
B_TILE = 256


def umod(x, m):
    """Unsigned mod of the int32 bit-pattern ``x`` by ``m`` (element-wise).

    x_u = hi·2^31 + lo with hi = logical msb, lo = low 31 bits, so
    x_u mod m = (lo mod m + hi·(2^31 mod m)) mod m — all in int32.
    """
    hi = jax.lax.shift_right_logical(x, 31)
    lo = x & jnp.int32(0x7FFFFFFF)
    c = (jnp.int32(2**30) % m) * 2 % m  # 2^31 mod m without overflow
    return (lo % m + hi * c) % m


def xorshift32_i32(x):
    """xorshift32 on int32 bit patterns (logical right shifts)."""
    x = x ^ (x << 13)
    x = x ^ jax.lax.shift_right_logical(x, 17)
    x = x ^ (x << 5)
    return x


def step_math(state, busy, tried, port_link, port_neighbor, cols, allow_nonminimal):
    """Algorithm-1 decision + state advance for a batch of scouts.

    Shared by the Pallas kernel body and the jnp reference — the kernel's
    value is the *layout/tiling*; the math must be identical by construction.
    All inputs are int32/bool jnp arrays:
      state [B, 8], busy [B, L], tried [B, 4N],
      port_link [N, 4], port_neighbor [N, 4].
    ``allow_nonminimal`` may be a static bool or a per-scout bool vector
    [B] (the table-driven design sweep batches scouts whose routing mode
    differs).  Degenerate/padded scouts are fine: ``cur == dst`` finishes
    immediately and off-mesh ports (link id -1) are never free.
    Returns (state', busy', tried').
    """
    cur = state[:, 0]
    dst = state[:, 1]
    entry = state[:, 2]
    rng = state[:, 3]
    B = cur.shape[0]
    n_nodes = port_link.shape[0]
    iota_n = jax.lax.broadcasted_iota(jnp.int32, (B, n_nodes), 1)
    one_hot_cur = (iota_n == cur[:, None]).astype(jnp.int32)  # [B, N]
    # MXU gathers: per-port link ids / neighbor ids for each scout's node
    links4 = jax.lax.dot(one_hot_cur, port_link.astype(jnp.int32))  # [B, 4]
    nbrs4 = jax.lax.dot(one_hot_cur, port_neighbor.astype(jnp.int32))

    # per-port busy: does links4[b,p] index a set bit of busy[b]?
    L = busy.shape[1]
    iota_l = jax.lax.broadcasted_iota(jnp.int32, (B, 4, L), 2)
    sel_l = iota_l == links4[:, :, None]
    busy4 = jnp.any(sel_l & busy[:, None, :].astype(bool), axis=2)
    # per-port tried: bit cur*4+p
    T = tried.shape[1]
    tried_idx = cur[:, None] * 4 + jax.lax.broadcasted_iota(jnp.int32, (B, 4), 1)
    iota_t = jax.lax.broadcasted_iota(jnp.int32, (B, 4, T), 2)
    sel_t = iota_t == tried_idx[:, :, None]
    tried4 = jnp.any(sel_t & tried[:, None, :].astype(bool), axis=2)

    free4 = (links4 >= 0) & ~busy4 & ~tried4  # [B, 4]

    at_dst = cur == dst
    diffx = dst % cols - cur % cols
    diffy = dst // cols - cur // cols
    px = jnp.where(diffx > 0, RIGHT, jnp.where(diffx < 0, LEFT, -1))
    py = jnp.where(diffy > 0, UP, jnp.where(diffy < 0, DOWN, -1))

    iota4 = jax.lax.broadcasted_iota(jnp.int32, (B, 4), 1)
    fmin0 = (px[:, None] == iota4) & free4
    fmin1 = (py[:, None] == iota4) & free4
    fmin = jnp.stack([jnp.any(fmin0, 1), jnp.any(fmin1, 1)], axis=1)  # [B, 2]
    n_min = jnp.sum(fmin.astype(jnp.int32), axis=1)
    fmis = free4 & (iota4 != entry[:, None])
    allow = jnp.asarray(allow_nonminimal)
    fmis &= allow.reshape(-1, 1)  # scalar or per-scout [B] flag
    n_mis = jnp.sum(fmis.astype(jnp.int32), axis=1)

    use_min = n_min > 0
    count = jnp.where(use_min, n_min, n_mis)
    need_rng = (~at_dst) & (count > 1)
    rng_next = jnp.where(need_rng, xorshift32_i32(rng), rng)
    idx = umod(rng_next, jnp.maximum(count, 1))

    cand_ports = jnp.concatenate([px[:, None], py[:, None], iota4], axis=1)  # [B,6]
    cand_flags = jnp.concatenate(
        [fmin & use_min[:, None], fmis & ~use_min[:, None]], axis=1
    )
    cum = jnp.cumsum(cand_flags.astype(jnp.int32), axis=1)
    sel = cand_flags & (cum - 1 == idx[:, None])
    pick = jnp.sum(jnp.where(sel, cand_ports, 0), axis=1)
    has_pick = (count > 0) & ~at_dst

    # advance
    iota4b = iota4
    link_pick = jnp.sum(jnp.where(iota4b == pick[:, None], links4, 0), axis=1)
    nbr_pick = jnp.sum(jnp.where(iota4b == pick[:, None], nbrs4, 0), axis=1)
    opposite = (pick + 2) % 4

    new_cur = jnp.where(has_pick, nbr_pick, cur)
    new_entry = jnp.where(has_pick, opposite, entry)
    # flags: 0 = backtrack, 1 = advanced, 2 = at destination
    flags = jnp.where(at_dst, 2, jnp.where(has_pick, 1, 0)).astype(jnp.int32)
    out_pick = jnp.where(has_pick, pick, -1)
    is_mis = (has_pick & ~use_min).astype(jnp.int32)

    state_out = jnp.stack(
        [new_cur, dst, new_entry, rng_next, flags, out_pick, is_mis,
         jnp.where(has_pick, link_pick, 0)],
        axis=1,
    )
    # set busy/tried bits for the traversed port
    L_iota = jax.lax.broadcasted_iota(jnp.int32, busy.shape, 1)
    busy_out = busy.astype(bool) | (
        has_pick[:, None] & (L_iota == link_pick[:, None])
    )
    T_iota = jax.lax.broadcasted_iota(jnp.int32, tried.shape, 1)
    tried_bit = cur * 4 + pick
    tried_out = tried.astype(bool) | (
        has_pick[:, None] & (T_iota == tried_bit[:, None])
    )
    return state_out, busy_out.astype(jnp.int32), tried_out.astype(jnp.int32)


def _kernel(state_ref, busy_ref, tried_ref, tables_ref, state_o, busy_o, tried_o,
            *, cols, n_nodes, allow_nonminimal):
    state = state_ref[...]
    busy = busy_ref[...]
    tried = tried_ref[...]
    tables = tables_ref[...]  # [N_pad, 128]: cols 0-3 port_link, 4-7 neighbor
    port_link = tables[:n_nodes, 0:4]
    port_neighbor = tables[:n_nodes, 4:8]
    s, b, t = step_math(
        state, busy, tried, port_link, port_neighbor, cols, allow_nonminimal
    )
    state_o[...] = s
    busy_o[...] = b
    tried_o[...] = t


def _kernel_vec(state_ref, busy_ref, tried_ref, tables_ref, allow_ref,
                state_o, busy_o, tried_o, *, cols, n_nodes):
    """Per-scout ``allow_nonminimal`` variant: the flag rides in as a
    traced ``[B, 1]`` int32 operand instead of a compile-time constant —
    one executable serves pools that mix minimal-only and adaptive
    scouts (the batched scout lane runner batches across designs)."""
    state = state_ref[...]
    busy = busy_ref[...]
    tried = tried_ref[...]
    tables = tables_ref[...]
    allow = allow_ref[...][:, 0].astype(bool)
    port_link = tables[:n_nodes, 0:4]
    port_neighbor = tables[:n_nodes, 4:8]
    s, b, t = step_math(
        state, busy, tried, port_link, port_neighbor, cols, allow
    )
    state_o[...] = s
    busy_o[...] = b
    tried_o[...] = t


def pack_tables(topo: MeshTopology) -> np.ndarray:
    n_pad = -(-topo.n_nodes // 8) * 8
    t = np.full((n_pad, 128), -1, dtype=np.int32)
    t[: topo.n_nodes, 0:4] = topo.port_link
    t[: topo.n_nodes, 4:8] = topo.port_neighbor
    return t


@functools.partial(
    jax.jit,
    static_argnames=("cols", "n_nodes", "allow_nonminimal", "interpret", "b_tile"),
)
def scout_step_pallas(
    state,
    busy,
    tried,
    tables,
    allow_vec=None,
    *,
    cols: int,
    n_nodes: int,
    allow_nonminimal: bool = True,
    interpret: bool | None = None,
    b_tile: int = B_TILE,
):
    """Run one Algorithm-1 step for a batch of scouts via pallas_call.

    state [B, 8] int32; busy [B, LINK_PAD] int32 (0/1); tried [B, 4*N_pad]
    int32 (0/1); tables from ``pack_tables``.  B must be a multiple of
    ``b_tile`` (pad with dummy scouts).  ``interpret=None`` resolves from
    the actual JAX backend (compiled on GPU/TPU, interpreted on CPU).

    ``allow_vec`` (int32/bool [B] or [B, 1], traced) carries a per-scout
    ``allow_nonminimal`` flag for pools that mix routing modes; when given
    it supersedes the static ``allow_nonminimal`` constant (which stays
    the cheaper choice for uniform pools — no extra operand to stream).
    """
    interpret = default_interpret(interpret)
    B = state.shape[0]
    assert B % b_tile == 0, "pad the scout batch to a multiple of b_tile"
    T = tried.shape[1]
    grid = (B // b_tile,)
    in_specs = [
        pl.BlockSpec((b_tile, STATE_W), lambda i: (i, 0)),
        pl.BlockSpec((b_tile, busy.shape[1]), lambda i: (i, 0)),
        pl.BlockSpec((b_tile, T), lambda i: (i, 0)),
        pl.BlockSpec((tables.shape[0], 128), lambda i: (0, 0)),
    ]
    if allow_vec is None:
        kernel = functools.partial(
            _kernel, cols=cols, n_nodes=n_nodes,
            allow_nonminimal=allow_nonminimal,
        )
        operands = (state, busy, tried, tables)
    else:
        kernel = functools.partial(_kernel_vec, cols=cols, n_nodes=n_nodes)
        in_specs.append(pl.BlockSpec((b_tile, 1), lambda i: (i, 0)))
        operands = (state, busy, tried, tables,
                    allow_vec.astype(jnp.int32).reshape(B, 1))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((b_tile, STATE_W), lambda i: (i, 0)),
            pl.BlockSpec((b_tile, busy.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((b_tile, T), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, STATE_W), jnp.int32),
            jax.ShapeDtypeStruct((B, busy.shape[1]), jnp.int32),
            jax.ShapeDtypeStruct((B, T), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
