"""Pallas kernels for the simulator's two hot paths.

- ``scout_step`` / ``ref`` / ``ops``: the Algorithm-1 scout routing step
  (one DFS decision per scout per call) — Pallas kernel, gather-based
  jnp oracle, and the jitted batched-DFS driver around them.
- ``batched_step``: the lane-tiled wrapper that runs the batched static
  step from ``ssd.sim`` as a ``pl.pallas_call`` (lanes on the grid,
  pre-gathered node tables in per-instance blocks).
- ``onehot``: gather-free one-hot compare-and-reduce lookups shared by
  the XLA and Pallas paths.
- ``backend``: interpret-mode selection (Pallas has no CPU compiler, so
  CPU runs interpret=True; accelerators compile).
"""
