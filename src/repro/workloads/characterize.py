"""Workload characterization: Table-2-style statistics from any trace.

The paper summarizes each of its 19 real workloads by a (read %, mean
request size, mean inter-arrival time) triple — Table 2 — and the synthetic
generator is calibrated to exactly those triples.  :func:`characterize`
closes the loop: it extracts the same triple (as the shared
:class:`repro.traces.WorkloadStats` structure) **plus** the distributional
parameters the generator exposes as knobs (size spread, sequentiality,
hot-set concentration, burstiness, footprint) from any canonical byte
trace — synthetic or ingested — so the generator can be *re-fit* to an
arbitrary real workload (:func:`register_workload`) and so ingested traces
are auditable against the paper's table.

The round trip ``characterize(gen_trace(stats)) ≈ stats`` is pinned within
tolerance by ``tests/test_workloads.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.traces.generator import WORKLOADS, WorkloadStats

__all__ = ["WorkloadProfile", "characterize", "register_workload"]


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    """Measured statistics of one trace.

    ``stats`` is the Table-2 core (the structure the generator registry
    holds); the remaining fields describe the distributions behind the
    means, in the units of the matching ``gen_trace`` knobs.
    """

    name: str
    stats: WorkloadStats  # read %, mean size KB, mean IAT us
    n_requests: int
    footprint_bytes: int
    span_us: float  # arrival span
    seq_frac: float  # requests continuing another request's address run
    size_sigma: float  # std of log request size (lognormal shape)
    size_p50_kb: float
    size_p99_kb: float
    iat_cv: float  # IAT coefficient of variation (burstiness; exp = 1)
    hot_frac: float  # access-coverage skew in [0, 1] (0 uniform, 1 hot)

    def gen_kwargs(self) -> Dict:
        """Keyword arguments re-fitting ``gen_trace`` to this workload."""
        return {
            "stats": self.stats,
            "footprint_bytes": max(1 << 20, int(self.footprint_bytes)),
            "seq_frac": float(np.clip(self.seq_frac, 0.0, 1.0)),
            "hot_weight": float(np.clip(self.hot_frac, 0.0, 0.95)),
        }


def characterize(trace: Dict[str, np.ndarray],
                 name: str | None = None) -> WorkloadProfile:
    """Extract a :class:`WorkloadProfile` from a canonical byte trace."""
    arrival = np.asarray(trace["arrival_us"], np.float64)
    is_read = np.asarray(trace["is_read"], bool)
    off = np.asarray(trace["offset_bytes"], np.int64)
    size = np.asarray(trace["size_bytes"], np.int64)
    n = len(arrival)
    if n == 0:
        raise ValueError("cannot characterize an empty trace")

    # the Table-2 triple, with the generator's own IAT convention
    # (iat[0] = first arrival, so mean == span/n for a 0-based trace)
    iat = np.diff(arrival, prepend=0.0)
    stats = WorkloadStats(
        read_pct=float(100.0 * is_read.mean()),
        avg_kb=float(size.mean() / 1024.0),
        avg_iat_us=float(iat.mean()),
    )

    # sequentiality: a request whose offset exactly continues some other
    # request's byte run (stream-interleaved traces keep several cursors,
    # so adjacency to *any* other request — not just the previous one —
    # is the right notion; exact-end matching keeps this O(n log n))
    seq_frac = float(np.isin(off, off + size).mean()) if n > 1 else 0.0

    # hot-set concentration, as access-coverage skew: let k be the minimal
    # number of (most-popular) touched 4K start pages covering HALF the
    # requests.  A uniform trace needs ~half its touched pages (k/u ≈ 0.5
    # → 0); a hot-extent trace covers half its requests with a small page
    # set (k/u → 0 → 1).  This is the knob ``gen_trace(hot_weight=…)``
    # turns, scale-free in trace length.
    pages = off // 4096
    counts = np.sort(np.unique(pages, return_counts=True)[1])[::-1]
    k = int(np.searchsorted(np.cumsum(counts), n / 2.0)) + 1
    hot_frac = float(np.clip(1.0 - 2.0 * k / len(counts), 0.0, 1.0))

    footprint = int(trace.get("footprint_bytes", int((off + size).max())))
    iat_pos = iat[iat > 0]
    return WorkloadProfile(
        name=name or str(trace.get("name", "trace")),
        stats=stats,
        n_requests=n,
        footprint_bytes=footprint,
        span_us=float(arrival[-1] - arrival[0]),
        seq_frac=seq_frac,
        size_sigma=float(np.std(np.log(np.maximum(size, 1)))),
        size_p50_kb=float(np.percentile(size, 50) / 1024.0),
        size_p99_kb=float(np.percentile(size, 99) / 1024.0),
        iat_cv=float(iat_pos.std() / iat_pos.mean()) if len(iat_pos) else 0.0,
        hot_frac=hot_frac,
    )


def register_workload(name: str, profile: WorkloadProfile | WorkloadStats
                      ) -> WorkloadStats:
    """Add a characterized workload to the generator registry.

    After registration ``gen_trace(name, n)`` synthesizes
    statistically-matched traces of the measured workload exactly like the
    19 built-in Table-2 entries.  Returns the registered stats triple.
    """
    stats = profile.stats if isinstance(profile, WorkloadProfile) else profile
    WORKLOADS[name] = WorkloadStats(*map(float, stats))
    return WORKLOADS[name]
