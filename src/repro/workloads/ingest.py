"""Streamed, memory-bounded ingestion of real storage traces.

Two wire formats are understood:

* **MSR-Cambridge CSV** (the paper's primary suite): positional columns
  ``Timestamp,Hostname,DiskNumber,Type,Offset,Size,ResponseTime`` with the
  timestamp in Windows FILETIME units (100 ns ticks) and ``Type`` one of
  ``Read``/``Write``.
* **blktrace-style CSV**: ``time,op,offset,size`` where ``time`` is seconds
  (float), ``op`` contains ``R`` or ``W`` (blkparse RWBS convention — e.g.
  ``R``, ``WS``, ``RA``), ``offset`` is the start *sector* (512 B) and
  ``size`` the sector count.

Both parse to the canonical byte-trace dict the rest of the repo consumes
(``arrival_us`` f64 starting at 0, ``is_read`` bool, ``offset_bytes`` /
``size_bytes`` int64, ``footprint_bytes``) — the same schema
``repro.traces.generator.gen_trace`` emits, so an ingested trace drops into
``to_pages`` → FTL → sweep unchanged.

Parsing is **streamed**: :func:`iter_trace_csv` reads line-by-line and
yields fixed-size numpy batches, holding at most ``batch_requests`` rows in
Python lists at any time, so week-long multi-GB traces ingest in bounded
memory.  :func:`load_trace` is the whole-file convenience built on the same
row parser; the two paths are pinned identical on the bundled fixture by
``tests/test_workloads.py``.

Real traces address a whole LUN (offsets up to hundreds of GB) while the
simulator's FTL allocates physical pages for the entire footprint, so
:func:`compact_footprint` remaps the sparse touched address set onto a
dense range by merging touched extents: page-adjacency *within* an extent
(the sequentiality that matters to striping and channel skew) is preserved,
untouched gaps between extents are dropped.
"""
from __future__ import annotations

import gzip
import os
import warnings
from typing import Dict, Iterator

import numpy as np

from repro.ssd.config import TICK_NS
from repro.traces.generator import register_trace

__all__ = [
    "sniff_format", "iter_trace_csv", "load_trace", "compact_footprint",
    "write_msr_csv", "ingest_file", "arrival_ticks_i64",
    "iter_trace_windows",
]

_FILETIME_PER_US = 10.0  # Windows FILETIME = 100 ns ticks
_SECTOR = 512


def _open_text(path: str):
    """Text handle for a trace file; ``.gz`` paths stream through gzip
    transparently (real MSR distributions ship as ``.csv.gz``)."""
    if path.endswith(".gz"):
        return gzip.open(path, "rt")
    return open(path)


def _parse_rows_msr(rows: list, base: int | None) -> tuple:
    """Columns (ts_us, is_read, offset, size, base) from split MSR fields.

    FILETIME values (~1.3e17) exceed float64's exact-integer range, so the
    timestamp is rebased to the file's FIRST row in int64 arithmetic before
    the float conversion — a week-long trace spans ≪ 2^53 after rebasing.
    """
    ticks = np.array([int(r[0]) for r in rows], np.int64)
    if base is None:
        base = int(ticks[0])
    ts = (ticks - base) / _FILETIME_PER_US
    is_read = np.array([r[3].strip().lower().startswith("r") for r in rows],
                       bool)
    off = np.array([int(r[4]) for r in rows], np.int64)
    size = np.array([int(r[5]) for r in rows], np.int64)
    return ts, is_read, off, size, base


def _parse_rows_blk(rows: list, base: int | None) -> tuple:
    ts = np.array([float(r[0]) for r in rows], np.float64) * 1e6  # s -> us
    is_read = np.array(["r" in r[1].strip().lower() for r in rows], bool)
    off = np.array([int(r[2]) for r in rows], np.int64) * _SECTOR
    size = np.array([int(r[3]) for r in rows], np.int64) * _SECTOR
    return ts, is_read, off, size, base


_PARSERS = {"msr": _parse_rows_msr, "blktrace": _parse_rows_blk}

# cheap per-row validity probes (same conversions the batch parsers apply,
# scalar) — a row that passes its probe cannot fail the vectorized parse
_VALIDATORS = {
    "msr": lambda r: (int(r[0]), int(r[4]), int(r[5])),
    "blktrace": lambda r: (float(r[0]), int(r[2]), int(r[3])),
}


def _is_header(line: str) -> bool:
    first = line.split(",", 1)[0].strip()
    try:
        float(first)
        return False
    except ValueError:
        return True


def sniff_format(path: str) -> str:
    """``"msr"`` or ``"blktrace"`` from the first data line's shape."""
    with _open_text(path) as f:
        for line in f:
            line = line.strip()
            if not line or _is_header(line):
                continue
            fields = line.split(",")
            if len(fields) >= 6 and fields[3].strip().lower() in (
                    "read", "write"):
                return "msr"
            if len(fields) >= 4:
                return "blktrace"
            break
    raise ValueError(f"cannot sniff trace format of {path}")


def iter_trace_csv(
    path: str, fmt: str = "auto", batch_requests: int = 65536,
    on_error: str = "raise", stats: dict | None = None,
) -> Iterator[Dict[str, np.ndarray]]:
    """Stream a trace CSV as numpy batches of ≤ ``batch_requests`` rows.

    Each batch is a dict with raw (un-normalized) columns ``arrival_us``
    (rebased to the file's first data row), ``is_read``, ``offset_bytes``,
    ``size_bytes``.  Memory is bounded by the batch size — the file is
    never read whole.

    Corrupted rows (too few fields, or unparseable numeric columns) are
    governed by ``on_error``: ``"raise"`` (default) raises ``ValueError``
    naming the line, ``"skip"`` drops the row and counts it in
    ``stats["skipped_rows"]`` (pass a dict to read the count back; clean
    input is bit-identical under both modes).  Header/blank lines are
    never errors.
    """
    if on_error not in ("raise", "skip"):
        raise ValueError(f"on_error must be 'raise' or 'skip', got {on_error!r}")
    if fmt == "auto":
        fmt = sniff_format(path)
    parse = _PARSERS[fmt]
    check = _VALIDATORS[fmt]
    min_fields = 6 if fmt == "msr" else 4
    base = None
    if stats is not None:
        stats.setdefault("skipped_rows", 0)

    def flush(rows):
        nonlocal base
        ts, is_read, off, size, base = parse(rows, base)
        return {"arrival_us": ts, "is_read": is_read,
                "offset_bytes": off, "size_bytes": size}

    rows: list = []
    with _open_text(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line or _is_header(line):
                continue
            fields = line.split(",")
            try:
                if len(fields) < min_fields:
                    raise ValueError(
                        f"{len(fields)} fields, {fmt!r} needs >= {min_fields}"
                    )
                check(fields)
            except ValueError as e:
                if on_error == "raise":
                    raise ValueError(
                        f"{path}:{lineno}: corrupted trace row "
                        f"{line[:80]!r} ({e})"
                    ) from None
                if stats is not None:
                    stats["skipped_rows"] += 1
                continue
            rows.append(fields)
            if len(rows) >= batch_requests:
                yield flush(rows)
                rows = []
    if rows:
        yield flush(rows)


def _normalize(batches: list, name: str) -> Dict[str, np.ndarray]:
    """Concatenate raw batches into the canonical byte-trace dict."""
    if not batches:
        raise ValueError(f"trace {name!r} has no parseable requests")
    ts = np.concatenate([b["arrival_us"] for b in batches])
    is_read = np.concatenate([b["is_read"] for b in batches])
    off = np.concatenate([b["offset_bytes"] for b in batches])
    size = np.maximum(1, np.concatenate([b["size_bytes"] for b in batches]))
    order = np.argsort(ts, kind="stable")  # some traces log out of order
    ts, is_read, off, size = ts[order], is_read[order], off[order], size[order]
    end = int((off + size).max())
    return {
        "name": name,
        "arrival_us": ts - ts[0],
        "is_read": is_read,
        "offset_bytes": off,
        "size_bytes": size,
        "footprint_bytes": end,
    }


def load_trace(
    path: str,
    fmt: str = "auto",
    name: str | None = None,
    compact: bool = True,
    batch_requests: int | None = None,
    on_error: str = "raise",
) -> Dict[str, np.ndarray]:
    """Parse a whole trace file to the canonical byte-trace dict.

    ``batch_requests=None`` parses the file in one pass (whole-file path);
    any integer routes through the streamed iterator — both are pinned
    identical by the test suite.  ``compact=True`` remaps the sparse LUN
    address space onto a dense footprint (:func:`compact_footprint`).
    ``on_error="skip"`` drops corrupted rows instead of raising; the drop
    count is returned as ``trace["skipped_rows"]`` (0 on clean input).
    """
    if fmt == "auto":
        fmt = sniff_format(path)
    if name is None:
        base = os.path.basename(path)
        if base.endswith(".gz"):
            base = base[:-3]
        name = os.path.splitext(base)[0]
    if batch_requests is None:
        batch_requests = 1 << 62  # one flush == whole file
    stats: dict = {}
    batches = list(iter_trace_csv(path, fmt, batch_requests,
                                  on_error=on_error, stats=stats))
    trace = _normalize(batches, name)
    if compact:
        trace = compact_footprint(trace)
    trace["skipped_rows"] = int(stats.get("skipped_rows", 0))
    if trace["skipped_rows"]:
        _report_skipped(path, trace["skipped_rows"])
    return trace


# files already warned about this process — silent drops should be loud,
# but once per file, not once per re-ingest of the same fixture
_WARNED_SKIPS: set = set()


def _report_skipped(path: str, count: int) -> None:
    """Surface silently-dropped rows: one warning per file per process,
    plus the ``ingest_skipped_rows`` counter in ``bench.PERF`` (always
    incremented, so harness telemetry sees every drop even after the
    warning deduplicates)."""
    from repro.ssd import bench  # lazy: keep ingest importable without jax

    bench.PERF["ingest_skipped_rows"] += count
    if path not in _WARNED_SKIPS:
        _WARNED_SKIPS.add(path)
        warnings.warn(
            f"load_trace({path!r}): skipped {count} corrupted row"
            f"{'s' if count != 1 else ''} under on_error='skip'",
            stacklevel=3,
        )


def compact_footprint(
    trace: Dict[str, np.ndarray], align: int = 4096
) -> Dict[str, np.ndarray]:
    """Remap the touched address set onto a dense footprint.

    Touched byte ranges are rounded out to ``align`` boundaries and merged
    into maximal extents; each extent is then packed back-to-back.  The
    remap is monotone and gap-free inside an extent, so sequential runs,
    overlaps and re-references — everything the FTL's striping and the
    channel-skew analysis care about — are preserved; only never-touched
    gaps are dropped.  Offsets keep their intra-page byte remainder.
    """
    off = np.asarray(trace["offset_bytes"], np.int64)
    size = np.asarray(trace["size_bytes"], np.int64)
    s = off // align
    e = (off + size + align - 1) // align  # exclusive, align units
    order = np.argsort(s, kind="stable")
    s_s, e_s = s[order], e[order]
    # merged extents: a new extent starts where the running max end < start
    run_end = np.maximum.accumulate(e_s)
    new_ext = np.concatenate(([True], s_s[1:] > run_end[:-1]))
    ext_start = s_s[new_ext]
    ext_id = np.cumsum(new_ext) - 1
    # extent end = running max at the last member of each extent
    last = np.concatenate((np.flatnonzero(new_ext)[1:] - 1, [len(s_s) - 1]))
    ext_end = run_end[last]
    ext_len = ext_end - ext_start
    ext_base = np.concatenate(([0], np.cumsum(ext_len)[:-1]))
    # map each request through its extent
    req_ext = np.empty(len(off), np.int64)
    req_ext[order] = ext_id
    new_off = (ext_base[req_ext] + (s - ext_start[req_ext])) * align \
        + (np.asarray(trace["offset_bytes"], np.int64) % align)
    out = dict(trace)
    out["offset_bytes"] = new_off
    out["footprint_bytes"] = int(ext_len.sum()) * align
    return out


def write_msr_csv(trace: Dict[str, np.ndarray], path: str,
                  hostname: str = "anon") -> None:
    """Serialize a canonical byte trace as MSR-Cambridge CSV (the format
    :func:`load_trace` parses) — used to build anonymized test fixtures."""
    base_ft = 129_000_000_000_000_000  # arbitrary FILETIME epoch offset
    # ticks first, THEN the epoch offset, all in int64: FILETIME magnitudes
    # exceed float64's exact-integer range (ulp 16 at 1.3e17)
    ts = np.round(
        np.asarray(trace["arrival_us"], np.float64) * _FILETIME_PER_US
    ).astype(np.int64) + base_ft
    with open(path, "w") as f:
        for t, r, o, s in zip(ts, trace["is_read"], trace["offset_bytes"],
                              trace["size_bytes"]):
            typ = "Read" if r else "Write"
            f.write(f"{t},{hostname},0,{typ},{int(o)},{int(s)},0\n")


def ingest_file(path: str, fmt: str = "auto", name: str | None = None,
                compact: bool = True, on_error: str = "raise") -> str:
    """Load + register a trace for replay-by-name; returns the name under
    which ``bench.run_workload`` / the scenario engine can now replay it."""
    trace = load_trace(path, fmt=fmt, name=name, compact=compact,
                       on_error=on_error)
    register_trace(trace["name"], trace)
    return trace["name"]


# ---------------------------------------------------------------------------
# int64 window slicing — the ingestion half of the streaming engine
# ---------------------------------------------------------------------------


def arrival_ticks_i64(arrival_us: np.ndarray) -> np.ndarray:
    """Absolute int64 arrival ticks from float microseconds.

    The EXACT float64 op sequence of ``repro.ssd.config.us_to_ticks``
    (``ceil(us * 1e3 / TICK_NS)``) so window-rebased ticks reproduce what a
    monolithic decomposition would derive — the bit-exactness contract of
    the streaming engine hangs on this identity."""
    us = np.asarray(arrival_us, np.float64)
    return np.ceil(us * 1e3 / TICK_NS).astype(np.int64)


def iter_trace_windows(
    path: str,
    window_s: float = 10.0,
    fmt: str = "auto",
    batch_requests: int = 65536,
) -> Iterator[Dict[str, np.ndarray]]:
    """Stream a trace file as fixed-span time windows in bounded memory.

    Rides :func:`iter_trace_csv` (so ``.csv`` and ``.csv.gz`` both work)
    and regroups its batches by arrival time: each yielded dict carries the
    canonical raw columns for one ``window_s``-second span plus
    ``window_index``, ``base_ticks`` (the window's absolute tick origin)
    and ``arrival_ticks`` (int64, rebased to ``base_ticks`` — each value
    fits the int32 tick budget by construction).  Empty interior windows
    are yielded (zero-length arrays) so consumers can hold their
    window-count invariants; arrivals are assumed nondecreasing (MSR and
    blktrace logs are time-ordered after ingest normalization).
    """
    window_ticks = int(round(window_s * 1e9 / TICK_NS))
    if window_ticks <= 0:
        raise ValueError(f"window_s {window_s!r} must be positive")

    cols = ("arrival_us", "is_read", "offset_bytes", "size_bytes")
    empty = {k: np.zeros(0, np.float64 if k == "arrival_us" else np.int64)
             for k in cols}
    empty["is_read"] = np.zeros(0, bool)
    pend = dict(empty)  # joined not-yet-emitted rows (bounded: <1 window +
    pend_ticks = np.zeros(0, np.int64)  # 1 batch of rows at any time)
    widx = 0
    t0_us: float | None = None

    def cut_window():
        """Pop window ``widx``'s rows off the pending buffer."""
        nonlocal pend, pend_ticks, widx
        hi = (widx + 1) * window_ticks
        cut = int(np.searchsorted(pend_ticks, hi, side="left"))
        win = {"window_index": widx,
               "base_ticks": widx * window_ticks,
               "arrival_ticks": pend_ticks[:cut] - widx * window_ticks}
        for k in cols:
            win[k] = pend[k][:cut]
        pend = {k: pend[k][cut:] for k in cols}
        pend_ticks = pend_ticks[cut:]
        widx += 1
        return win

    for batch in iter_trace_csv(path, fmt, batch_requests):
        ts = np.asarray(batch["arrival_us"], np.float64)
        if len(ts) == 0:
            continue
        if t0_us is None:
            t0_us = float(ts[0])
        ts = ts - t0_us
        batch = dict(batch, arrival_us=ts)
        pend_ticks = np.concatenate((pend_ticks, arrival_ticks_i64(ts)))
        for k in cols:
            pend[k] = np.concatenate((pend[k], np.asarray(batch[k])))
        # every window ending at or before the last seen tick is complete
        # (arrivals are time-ordered), including empty interior windows
        while (widx + 1) * window_ticks <= int(pend_ticks[-1]):
            yield cut_window()
    if len(pend["arrival_us"]):
        yield cut_window()
