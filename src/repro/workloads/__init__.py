"""Workloads subsystem: real-trace ingestion, workload characterization, and
the declarative QoS scenario engine.

Three layers (DESIGN.md §6):

* **Ingestion** (``repro.workloads.ingest``): streamed, memory-bounded
  parsers for MSR-Cambridge CSV and generic blktrace-style CSV, address
  compaction, and ``register_trace`` so an ingested real trace replays
  by name through the whole bench/cache/planner pipeline.
* **Characterization** (``repro.workloads.characterize``): extracts the
  Table-2-style statistics (read ratio, size/IAT distributions, footprint,
  sequentiality) from any trace as a :class:`WorkloadProfile` whose core is
  the same :class:`repro.traces.WorkloadStats` the synthetic generator is
  calibrated to — so the generator can be re-fit to arbitrary real
  workloads (``register_workload``).
* **Scenario engine** (``repro.workloads.scenario``): declarative
  :class:`QueueDepthSweep` / :class:`MultiTenantMix` / :class:`BurstScale`
  specs that lower onto ``repro.ssd.sweep_plan.execute_sim_runs`` — the
  multi-core planner pools their lanes like any other run — and emit the
  tail-latency / fairness surface (per-design p50/p95/p99, per-tenant
  slowdown-vs-solo, max/min fairness).
"""
from repro.traces.generator import WorkloadStats, register_trace

from repro.workloads.characterize import (
    WorkloadProfile,
    characterize,
    register_workload,
)
from repro.workloads.ingest import (
    arrival_ticks_i64,
    compact_footprint,
    ingest_file,
    iter_trace_csv,
    iter_trace_windows,
    load_trace,
    sniff_format,
    write_msr_csv,
)
from repro.workloads.scenario import (
    BurstScale,
    MultiTenantMix,
    QueueDepthSweep,
    StreamReplay,
    run_scenario,
)

__all__ = [
    "WorkloadStats", "WorkloadProfile", "characterize", "register_workload",
    "register_trace", "arrival_ticks_i64", "compact_footprint",
    "ingest_file", "iter_trace_csv", "iter_trace_windows", "load_trace",
    "sniff_format", "write_msr_csv", "BurstScale", "MultiTenantMix",
    "QueueDepthSweep", "StreamReplay", "run_scenario",
]
