"""Declarative QoS scenarios lowered onto the sweep planner.

The repo's figure phases replay *open-loop* accelerated traces and report
mean throughput; this module adds the complementary QoS surface — the one
Sprinkler/PALP argue conflict-resolution mechanisms must be evaluated on:

* :class:`QueueDepthSweep` — **closed-loop** depth sweeps (QD 1→64).  A
  closed-loop submitter keeps exactly QD requests outstanding: request
  ``k`` is issued when request ``k-QD`` completes.  The completion times
  depend on the design being simulated, so the scenario iterates: start
  from saturation (all requests at t=0), simulate, regenerate arrivals
  from the previous round's per-request completion feedback
  (``SimResult.req_completion``), and repeat ``iters`` times — each
  (design, QD) converging to its own steady queue.  This is the standard
  fixed-point approximation of a closed loop on a batch simulator; the
  feedback identity is pinned by tests.
* :class:`MultiTenantMix` — tenants overlaid on one timeline with disjoint
  address ranges and per-request attribution threaded to
  ``SimResult.req_tenant``.  Reports per-tenant p50/p95/p99, slowdown
  versus the tenant running *solo* (same arrival schedule and addresses,
  interfering tenants removed), and max/min fairness.
* :class:`BurstScale` — open-loop burst stress: the same trace replayed at
  increasing acceleration factors.
* :class:`StreamReplay` — windowed replay of traces beyond the int32 tick
  budget through ``repro.ssd.stream.stream_simulate``: per-design QoS
  metrics over the full span plus per-window throughput telemetry.
* :class:`DegradedModeSweep` — hardware-fault degradation curves (ISSUE
  8): the same workload replayed under growing ``FaultSpec``s, reporting
  each design's throughput **retention** (``iops_ok`` vs its own
  fault-free run — timed-out requests are not service).  Placements map
  the paper's degraded-mode asymmetry: one dead link per channel row
  wipes out a shared-bus design's whole channels while Venice's adaptive
  DFS routes around the same faults.  ``mid_trace_window`` instead
  injects the faults at a streaming window boundary
  (``stream_simulate(fault_schedule=...)``), modelling mid-trace fault
  arrival with in-flight state carried across the failure.

Every scenario lowers to ``repro.ssd.sweep_plan.execute_sim_runs`` batches
— one planner call per feedback round — so its lanes pool into the same
sharded multi-core groups as any bench run, and every decomposition goes
through ``bench.decompose_cached`` (the content-digest LRU).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Sequence

import numpy as np

from repro.obs import spans as obs_spans
from repro.ssd import bench
from repro.ssd.config import TICK_NS
from repro.ssd.sim import SimResult
from repro.traces.generator import (
    MIXES,
    default_n_requests,
    mix_traces,
    to_pages,
    trace_for,
)

__all__ = [
    "QueueDepthSweep", "MultiTenantMix", "BurstScale", "StreamReplay",
    "DegradedModeSweep", "degraded_fault_spec",
    "run_scenario", "run_queue_depth_sweeps", "run_stream_replay",
    "run_degraded_mode", "design_metrics", "closed_loop_arrivals",
    "last_run_perf",
]

DEFAULT_QDS = (1, 2, 4, 8, 16, 32, 64)

# Per-run telemetry of the most recent scenario-engine call: the
# ``bench.PERF`` counter/timer *delta* attributable to that run alone
# (ISSUE 9 satellite — PERF is process-cumulative, so engines that read it
# directly leak state between runs).  Kept OUT of the returned records on
# purpose: scenario records are pinned bit-identical across re-runs and
# merge orders by tests/test_scenarios.py, and wall-clock-derived keys
# would break that.  Read it via :func:`last_run_perf`.
LAST_RUN_PERF: Dict | None = None


def last_run_perf() -> Dict | None:
    """PERF delta of the most recent scenario-engine run (None before any)."""
    return LAST_RUN_PERF


def _perf_scoped(fn):
    """Engine decorator: snapshot ``bench.PERF`` around the run and publish
    the per-run delta to ``LAST_RUN_PERF``, with a harness span on the
    ``scenario`` track.  Nested engine calls (``run_queue_depth_sweep`` →
    ``run_queue_depth_sweeps``) leave the *outermost* delta in place."""

    @functools.wraps(fn)
    def wrapped(cfg, scn, designs):
        global LAST_RUN_PERF
        before = bench.PERF.snapshot()
        name = (type(scn).__name__ if not isinstance(scn, (tuple, list))
                else f"{len(scn)}x{type(scn[0]).__name__}" if scn
                else "empty")
        with obs_spans.span("scenario", f"{fn.__name__}:{name}"):
            out = fn(cfg, scn, designs)
        LAST_RUN_PERF = bench.PERF.delta(before)
        return out

    return wrapped


@dataclasses.dataclass(frozen=True)
class QueueDepthSweep:
    """Closed-loop queue-depth sweep of one workload (QD 1→64).

    ``iters`` is the number of completion-feedback rounds after the
    saturation bootstrap.  Arrivals only ever move later round over round,
    so the iteration converges to the true closed loop from the saturated
    side — reported latencies are upper bounds that tighten with ``iters``
    (shallow depths need the most rounds; ~6 is where the QD-1 tail
    flattens on the full geometry, see EXPERIMENTS.md).  Each round's
    residual is exported as ``arrival_drift_us``.
    """

    workload: str
    qds: tuple = DEFAULT_QDS
    n_requests: int | None = None
    iters: int = 6
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class MultiTenantMix:
    """Tenant workloads overlaid on one device, attribution threaded."""

    workloads: tuple  # constituent workload names (or one Table-3 mix name)
    n_requests_each: int = 300
    target_util: float | None = 1.5
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class StreamReplay:
    """Windowed replay of a (possibly streaming-only) registered trace."""

    workload: str
    window_s: float = 10.0
    n_requests: int | None = None
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class BurstScale:
    """Open-loop burst stress: arrival acceleration factor sweep."""

    workload: str
    factors: tuple = (1.0, 2.0, 4.0, 8.0)
    n_requests: int | None = None
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class DegradedModeSweep:
    """Hardware-fault degradation sweep: throughput retention vs faults.

    ``fault_counts`` are the sweep points (0 is the retention anchor and
    is always run).  ``placement`` picks which links die at count ``k``:

    * ``"per_channel"`` — the first ``k`` channel rows each lose one
      horizontal link (the column is a seeded draw per row).  This is the
      paper's asymmetry probe: a bus design loses the whole channel, a
      mesh design loses one hop.
    * ``"spread"`` — ``k`` links sampled without replacement mesh-wide.
    * ``"clustered"`` — ``k`` consecutive link ids from a seeded start
      (a localized failure region, the hardest case for minimal routing).

    ``mid_trace_window`` (with ``window_s``) switches each point to a
    windowed replay with the faults arriving at that window's start.
    """

    workload: str
    fault_counts: tuple = (0, 1, 2, 4)
    placement: str = "per_channel"
    mid_trace_window: int | None = None
    window_s: float = 10.0
    n_requests: int | None = None
    seed: int = 0


# ---------------------------------------------------------------------------
# shared lowering helpers
# ---------------------------------------------------------------------------


def _decompose(cfg, trace):
    """Trace → Transactions through the bench digest cache (PERF-timed)."""
    pages = to_pages(trace, cfg.page_bytes)
    t0 = time.perf_counter()
    txns = bench.decompose_cached(cfg, pages, int(pages["footprint_pages"]))
    bench.PERF["ftl_s"] += time.perf_counter() - t0
    return txns


def _simulate_batch(runs: list) -> list:
    """One planner pass over many (cfg, txns, designs, seeds) runs."""
    from repro.ssd.sweep_plan import execute_sim_runs

    t0 = time.perf_counter()
    out = execute_sim_runs(runs)
    bench.PERF["sim_s"] += time.perf_counter() - t0
    return out


def design_metrics(res: SimResult, tenant_names: tuple = ()) -> Dict:
    """JSON-ready tail-latency record of one lane (us; GC excluded)."""
    scale = TICK_NS * 1e-3
    lat = res.req_latency * scale
    out = {
        "n_requests": int(len(lat)),
        "mean_us": round(float(lat.mean()), 3) if len(lat) else 0.0,
        **{k + "_us": round(v, 3)
           for k, v in res.latency_percentiles_us().items()},
        "iops": round(res.iops(), 1),
        "conflict_pct": round(res.conflict_rate() * 100, 3),
    }
    if res.req_tenant is not None:
        tl = res.tenant_latencies()
        out["tenants"] = {
            (tenant_names[t] if t < len(tenant_names) else str(t)): {
                "n_requests": int(len(v)),
                "mean_us": round(float(v.mean() * scale), 3),
                "p50_us": round(float(np.percentile(v, 50)) * scale, 3),
                "p95_us": round(float(np.percentile(v, 95)) * scale, 3),
                "p99_us": round(float(np.percentile(v, 99)) * scale, 3),
            }
            for t, v in tl.items() if len(v)
        }
    return out


# ---------------------------------------------------------------------------
# closed-loop queue-depth sweep
# ---------------------------------------------------------------------------


def closed_loop_arrivals(completion_ticks: np.ndarray, qd: int) -> np.ndarray:
    """Arrivals (us) of the next feedback round: request ``k`` is issued
    when request ``k-qd`` completed in the previous round.  The running max
    keeps the FIFO submitter causal (a request is never issued before its
    predecessor)."""
    us = np.asarray(completion_ticks, np.float64) * (TICK_NS * 1e-3)
    a = np.zeros(len(us), np.float64)
    if 0 < qd < len(us):
        a[qd:] = us[:-qd]
    return np.maximum.accumulate(a)


@_perf_scoped
def run_queue_depth_sweeps(cfg, scns: Sequence[QueueDepthSweep],
                           designs: Sequence[str]) -> list:
    """Round-merged execution of several closed-loop QD sweeps.

    Feedback round ``k`` of EVERY (sweep, design, QD) cell runs as one
    planner batch: the cells are independent fixed-point iterations, so
    merging changes nothing about any cell's arrival/completion sequence
    (bit-identical to running the sweeps one after another — pinned in
    tests/test_scenarios.py), but the planner sees
    ``len(scns) * len(designs) * len(qds)`` lanes per round instead of
    ``len(designs) * len(qds)`` — small-lane groups get fuller and the
    dispatch-bound tail phase pays the per-round barrier once, not per
    sweep.  Returns one record per sweep, in order.
    """
    designs = tuple(designs)
    states = []
    for scn in scns:
        n_req = scn.n_requests or default_n_requests(scn.workload)
        # closed-loop rounds discard the recorded arrivals (round 0 submits
        # everything at t=0, later rounds re-issue from completions), so a
        # streaming-only trace's span never reaches the simulator
        base = trace_for(scn.workload, n_req, scn.seed, monolithic=False)
        n = len(base["arrival_us"])
        keys = [(d, q) for d in designs for q in scn.qds]
        # saturation bootstrap: round 0 submits everything at t=0
        # (≡ QD = n); each feedback round re-issues from the previous
        # completions
        states.append(dict(
            scn=scn, base=base, n=n, keys=keys,
            arrivals={k: np.zeros(n, np.float64) for k in keys},
            results={}, drift={k: 0.0 for k in keys},
        ))
    for r in range(max(max(1, st["scn"].iters) for st in states)):
        runs, owners = [], []
        for st in states:
            if r >= max(1, st["scn"].iters):
                continue
            for (d, q) in st["keys"]:
                tr = dict(st["base"])
                tr["arrival_us"] = st["arrivals"][(d, q)]
                runs.append((cfg, _decompose(cfg, tr), (d,),
                             (st["scn"].seed + 7,), "auto"))
                owners.append((st, (d, q)))
        if not runs:
            break
        out = _simulate_batch(runs)
        for (st, key), res in zip(owners, out):
            st["results"][key] = res[0]
            nxt = closed_loop_arrivals(res[0].req_completion, key[1])
            st["drift"][key] = float(
                np.abs(nxt - st["arrivals"][key]).mean()
            )
            st["arrivals"][key] = nxt

    records = []
    for st in states:
        scn = st["scn"]
        tenant_names = tuple(st["base"].get("tenant_names", ()))

        def metrics(d, q, st=st, tenant_names=tenant_names):
            m = design_metrics(st["results"][(d, q)], tenant_names)
            # last round's mean arrival residual: distance from the
            # fixed point
            m["arrival_drift_us"] = round(st["drift"][(d, q)], 2)
            return m

        records.append({
            "scenario": "queue_depth_sweep",
            "workload": scn.workload,
            "n_requests": st["n"],
            "iters": scn.iters,
            "qds": list(scn.qds),
            "designs": {
                d: {str(q): metrics(d, q) for q in scn.qds}
                for d in designs
            },
        })
    return records


def run_queue_depth_sweep(cfg, scn: QueueDepthSweep,
                          designs: Sequence[str]) -> Dict:
    """Run one closed-loop QD sweep; returns the per-design QoS surface."""
    return run_queue_depth_sweeps(cfg, (scn,), designs)[0]


# ---------------------------------------------------------------------------
# multi-tenant mix with slowdown-vs-solo fairness
# ---------------------------------------------------------------------------


def _tenant_filter(merged: Dict, t: int) -> Dict:
    """Tenant ``t``'s requests alone: same arrival schedule, same (merged)
    addresses and footprint — only the interfering tenants removed."""
    keep = np.asarray(merged["tenant"]) == t
    out = dict(merged)
    for k in ("arrival_us", "is_read", "offset_bytes", "size_bytes",
              "tenant"):
        out[k] = np.asarray(merged[k])[keep]
    return out


@_perf_scoped
def run_multi_tenant(cfg, scn: MultiTenantMix,
                     designs: Sequence[str]) -> Dict:
    designs = tuple(designs)
    names = tuple(scn.workloads)
    if len(names) == 1 and names[0] in MIXES:  # Table-3 mix by name
        mix_name, names = names[0], MIXES[names[0]]
    else:
        mix_name = "+".join(names)
    merged = mix_traces(mix_name, scn.n_requests_each, scn.seed)
    offered = bench.offered_utilization(merged, cfg)
    accel = 1.0
    if scn.target_util is not None:
        merged, accel = bench.accelerate(merged, cfg, scn.target_util)
    bench.record_accel(mix_name, cfg, accel, offered, scn.target_util)
    # mix + one solo run per tenant, all designs, ONE planner batch
    seeds = ((scn.seed + 7),) * len(designs)
    runs = [(cfg, _decompose(cfg, merged), designs, seeds, "auto")]
    for t in range(len(names)):
        runs.append((cfg, _decompose(cfg, _tenant_filter(merged, t)),
                     designs, seeds, "auto"))
    out = _simulate_batch(runs)
    mix_res, solo_res = out[0], out[1:]

    per_design: Dict = {}
    scale = TICK_NS * 1e-3
    for i, d in enumerate(designs):
        rec = design_metrics(mix_res[i], names)
        slowdowns = {}
        for t, tname in enumerate(names):
            mix_lat = mix_res[i].tenant_latencies().get(t)
            solo_lat = solo_res[t][i].req_latency
            if mix_lat is None or not len(mix_lat) or not len(solo_lat):
                continue
            slowdowns[tname] = {
                "mean": round(float(mix_lat.mean() / solo_lat.mean()), 4),
                "p99": round(float(
                    np.percentile(mix_lat, 99)
                    / max(np.percentile(solo_lat, 99), 1e-9)), 4),
                "solo_mean_us": round(float(solo_lat.mean() * scale), 3),
            }
            rec["tenants"][tname]["slowdown_vs_solo"] = \
                slowdowns[tname]["mean"]
        sd = [v["mean"] for v in slowdowns.values()]
        rec["slowdowns"] = slowdowns
        # max/min fairness (1.0 = all tenants slowed equally)
        rec["fairness"] = round(min(sd) / max(sd), 4) if sd else 1.0
        per_design[d] = rec
    return {
        "scenario": "multi_tenant",
        "mix": mix_name,
        "tenants": list(names),
        "accel_factor": round(accel, 4),
        "offered_util": round(offered, 5),
        "designs": per_design,
    }


# ---------------------------------------------------------------------------
# windowed replay of beyond-budget traces
# ---------------------------------------------------------------------------


@_perf_scoped
def run_stream_replay(cfg, scn: StreamReplay,
                      designs: Sequence[str]) -> Dict:
    """Replay one workload through the chunked streaming engine."""
    from repro.ssd.stream import stream_simulate

    designs = tuple(designs)
    n_req = scn.n_requests or default_n_requests(scn.workload)
    trace = trace_for(scn.workload, n_req, scn.seed, monolithic=False)
    tenant_names = tuple(trace.get("tenant_names", ()))
    t0 = time.perf_counter()
    sr = stream_simulate(cfg, trace, designs,
                         seeds=((scn.seed + 7),) * len(designs),
                         window_s=scn.window_s)
    bench.PERF["sim_s"] += time.perf_counter() - t0
    return {
        "scenario": "stream_replay",
        "workload": scn.workload,
        "n_requests": sr.n_requests,
        "window_s": float(scn.window_s),
        "n_windows": sr.n_windows,
        "windows": sr.windows,
        "throughput_flatness": round(sr.throughput_flatness(), 4),
        "designs": {
            d: design_metrics(sr.results[i], tenant_names)
            for i, d in enumerate(designs)
        },
    }


# ---------------------------------------------------------------------------
# burst scaling stress
# ---------------------------------------------------------------------------


@_perf_scoped
def run_burst_scale(cfg, scn: BurstScale, designs: Sequence[str]) -> Dict:
    designs = tuple(designs)
    n_req = scn.n_requests or default_n_requests(scn.workload)
    base = trace_for(scn.workload, n_req, scn.seed)
    offered = bench.offered_utilization(base, cfg)
    seeds = ((scn.seed + 7),) * len(designs)
    runs = []
    for f in scn.factors:
        tr = dict(base)
        tr["arrival_us"] = np.asarray(base["arrival_us"], np.float64) / f
        runs.append((cfg, _decompose(cfg, tr), designs, seeds, "auto"))
    out = _simulate_batch(runs)
    tenant_names = tuple(base.get("tenant_names", ()))
    return {
        "scenario": "burst_scale",
        "workload": scn.workload,
        "n_requests": len(base["arrival_us"]),
        "factors": [float(f) for f in scn.factors],
        "offered_util_base": round(offered, 5),
        "designs": {
            d: {str(float(f)): design_metrics(res[i], tenant_names)
                for f, res in zip(scn.factors, out)}
            for i, d in enumerate(designs)
        },
    }


# ---------------------------------------------------------------------------
# degraded-mode fault sweep
# ---------------------------------------------------------------------------


def degraded_fault_spec(cfg, count: int, placement: str = "per_channel",
                        seed: int = 0):
    """Lower one sweep point to a ``FaultSpec`` (deterministic in seed).

    Exposed for benchmarks/tests so a CSV row and an assertion can name
    the exact same failed links."""
    from repro.core.topology import build_mesh
    from repro.ssd.designs import FaultSpec

    if count <= 0:
        return None
    topo = build_mesh(cfg.rows, cfg.cols)
    rng = np.random.default_rng(seed + 0xFA)
    n_h = cfg.rows * (cfg.cols - 1)
    if placement == "per_channel":
        if cfg.cols < 2:
            raise ValueError("per_channel placement needs cols >= 2")
        rows = [r % cfg.rows for r in range(count)]
        links = tuple(
            int(r * (cfg.cols - 1) + rng.integers(0, cfg.cols - 1))
            for r in rows
        )
    elif placement == "spread":
        links = tuple(
            int(x) for x in
            rng.choice(topo.n_links, size=min(count, topo.n_links),
                       replace=False)
        )
    elif placement == "clustered":
        start = int(rng.integers(0, max(n_h - count, 1)))
        links = tuple(range(start, min(start + count, topo.n_links)))
    else:
        raise ValueError(f"unknown placement {placement!r}")
    return FaultSpec(failed_links=links)


@_perf_scoped
def run_degraded_mode(cfg, scn: DegradedModeSweep,
                      designs: Sequence[str]) -> Dict:
    """Run one degradation sweep; returns per-design retention curves."""
    designs = tuple(designs)
    n_req = scn.n_requests or default_n_requests(scn.workload)
    counts = tuple(dict.fromkeys((0,) + tuple(scn.fault_counts)))
    specs = {k: degraded_fault_spec(cfg, k, scn.placement, scn.seed)
             for k in counts}
    seeds = ((scn.seed + 7),) * len(designs)
    per_count: Dict[int, list] = {}
    if scn.mid_trace_window is None:
        trace = trace_for(scn.workload, n_req, scn.seed)
        txns = _decompose(cfg, trace)
        runs = []
        for k in counts:
            run = (cfg, txns, designs, seeds, "auto")
            runs.append(run if specs[k] is None else run + (specs[k],))
        out = _simulate_batch(runs)
        per_count = dict(zip(counts, out))
    else:
        from repro.ssd.stream import stream_simulate

        trace = trace_for(scn.workload, n_req, scn.seed, monolithic=False)
        t0 = time.perf_counter()
        for k in counts:
            schedule = ({} if specs[k] is None
                        else {scn.mid_trace_window: specs[k]})
            sr = stream_simulate(cfg, trace, designs, seeds=seeds,
                                 window_s=scn.window_s,
                                 fault_schedule=schedule)
            per_count[k] = sr.results
        bench.PERF["sim_s"] += time.perf_counter() - t0

    base = {d: per_count[0][i].iops_ok() for i, d in enumerate(designs)}
    per_design: Dict = {}
    for i, d in enumerate(designs):
        curve = {}
        for k in counts:
            res = per_count[k][i]
            ok = res.iops_ok()
            curve[str(k)] = {
                "iops_ok": round(ok, 1),
                "retention": round(ok / max(base[d], 1e-9), 4),
                "failure_pct": round(res.failure_rate() * 100, 3),
                "failed_links": list(getattr(specs[k], "failed_links", ())),
            }
        per_design[d] = curve
    return {
        "scenario": "degraded_mode",
        "workload": scn.workload,
        "placement": scn.placement,
        "fault_counts": [int(k) for k in counts],
        "mid_trace_window": scn.mid_trace_window,
        "n_requests": n_req,
        "designs": per_design,
    }


def run_scenario(cfg, scenario, designs: Sequence[str]) -> Dict:
    """Dispatch a declarative scenario spec to its engine."""
    if isinstance(scenario, QueueDepthSweep):
        return run_queue_depth_sweep(cfg, scenario, designs)
    if isinstance(scenario, MultiTenantMix):
        return run_multi_tenant(cfg, scenario, designs)
    if isinstance(scenario, BurstScale):
        return run_burst_scale(cfg, scenario, designs)
    if isinstance(scenario, StreamReplay):
        return run_stream_replay(cfg, scenario, designs)
    if isinstance(scenario, DegradedModeSweep):
        return run_degraded_mode(cfg, scenario, designs)
    raise TypeError(f"unknown scenario {type(scenario).__name__}")
