"""Synthetic I/O trace generation calibrated to the paper's Table 2/3."""
from repro.traces.generator import (
    MIXES,
    WORKLOADS,
    gen_trace,
    mix_traces,
    trace_for,
)

__all__ = ["MIXES", "WORKLOADS", "gen_trace", "mix_traces", "trace_for"]
