"""Synthetic I/O trace generation calibrated to the paper's Table 2/3."""
from repro.traces.generator import (
    CUSTOM_TRACES,
    MIXES,
    WORKLOADS,
    WorkloadStats,
    gen_trace,
    mix_traces,
    overlay_traces,
    register_trace,
    trace_for,
)

__all__ = [
    "CUSTOM_TRACES", "MIXES", "WORKLOADS", "WorkloadStats", "gen_trace",
    "mix_traces", "overlay_traces", "register_trace", "trace_for",
]
