"""Synthetic storage traces calibrated to paper Table 2 (19 real workloads)
and Table 3 (6 mixed workloads).

The original MSR/YCSB/Slacker/SYSTOR/RocksDB traces are not redistributable
inside this container, so we synthesize statistically-matched replacements:
per workload we reproduce the *read ratio*, *mean request size* and *mean
inter-request arrival time* from Table 2 exactly (in expectation), with
heavy-tailed size and arrival distributions and a hot/cold zipf-like address
mixture typical of the original suites.  Tests validate the statistics
converge to the table's targets.
"""
from __future__ import annotations

import zlib
from typing import Dict, NamedTuple

import numpy as np


class WorkloadStats(NamedTuple):
    """The Table-2 summary triple every synthetic workload is calibrated to.

    The same structure is produced by the workload characterizer
    (``repro.workloads.characterize``) when it re-fits the generator to an
    *ingested real* trace, so registry entries and measured workloads are
    interchangeable everywhere a stats triple is accepted.  A plain
    NamedTuple keeps the historical tuple protocol (unpacking, ``[2]``)
    working for existing callers.
    """

    read_pct: float  # % of requests that are reads
    avg_kb: float  # mean request size, KB
    avg_iat_us: float  # mean inter-request arrival time, us


# name -> WorkloadStats, verbatim from Table 2
WORKLOADS: Dict[str, WorkloadStats] = {
    "hm_0": WorkloadStats(36, 8.8, 58),
    "mds_0": WorkloadStats(12, 9.6, 268),
    "proj_3": WorkloadStats(95, 9.6, 19),
    "prxy_0": WorkloadStats(3, 7.2, 242),
    "rsrch_0": WorkloadStats(9, 9.6, 129),
    "src1_0": WorkloadStats(56, 43.2, 49),
    "src2_1": WorkloadStats(98, 59.2, 50),
    "usr_0": WorkloadStats(40, 22.8, 98),
    "wdev_0": WorkloadStats(20, 9.2, 162),
    "web_1": WorkloadStats(54, 29.6, 67),
    "YCSB_B": WorkloadStats(99, 65.7, 13),
    "YCSB_D": WorkloadStats(99, 62, 14),
    "jenkins": WorkloadStats(94, 33.4, 615),
    "postgres": WorkloadStats(82, 13.3, 382),
    "LUN0": WorkloadStats(76, 20.4, 218),
    "LUN2": WorkloadStats(73, 16, 320),
    "LUN3": WorkloadStats(7, 7.7, 3127),
    "ssd-00": WorkloadStats(91, 90, 5),
    "ssd-10": WorkloadStats(99, 11.5, 2),
}

# Table 3: mix name -> constituent workloads
MIXES: Dict[str, tuple] = {
    "mix1": ("src2_1", "proj_3"),
    "mix2": ("src2_1", "proj_3", "YCSB_D"),
    "mix3": ("prxy_0", "rsrch_0"),
    "mix4": ("prxy_0", "rsrch_0", "mds_0"),
    "mix5": ("prxy_0", "src2_1"),
    "mix6": ("prxy_0", "src2_1", "usr_0"),
}

_ALIGN = 4096  # requests are 4KB-aligned multiples (block-device granularity)

# Ingested *real* traces registered for replay-by-name (populated by
# ``repro.workloads.register_trace``): ``trace_for`` serves a registered
# name by slicing the literal trace, so the whole bench/cache/planner
# pipeline treats a real workload exactly like a synthetic one.
CUSTOM_TRACES: Dict[str, Dict[str, np.ndarray]] = {}


# Simulator time is int32 ticks of 10 ns (repro.ssd.config.TICK_NS):
# arrivals beyond ~21 s would wrap negative in the transaction arrays.
# Synthetic traces are clamped to this budget by default_n_requests; an
# ingested real trace must be sliced or rescaled before registration.
_MAX_SPAN_US = (2**31 - 1) * 10e-3  # ≈ 21.47 s


def register_trace(name: str, trace: Dict[str, np.ndarray]) -> None:
    """Register an ingested trace (canonical byte-trace dict) for replay.

    A trace whose arrivals span more than the int32 tick budget (~21 s) is
    accepted but tagged ``streaming_only``: the streaming engine
    (``repro.ssd.stream.stream_simulate``) replays it in tick-rebased
    windows, and closed-loop consumers (QD sweeps) replace arrivals anyway.
    Only a *monolithic* replay of the full span is refused — at
    :func:`trace_for` time, naming the streaming path."""
    for key in ("arrival_us", "is_read", "offset_bytes", "size_bytes"):
        if key not in trace:
            raise ValueError(f"trace missing field {key!r}")
    arr = np.asarray(trace["arrival_us"], np.float64)
    span = float(arr[-1] - arr[0]) if len(arr) else 0.0
    out = dict(trace, name=name)
    if span > _MAX_SPAN_US:
        out["streaming_only"] = True
    CUSTOM_TRACES[name] = out


def _require_monolithic(trace: Dict[str, np.ndarray], name: str) -> None:
    """Refuse a monolithic replay of a streaming-only span.

    The check re-derives the span from the (possibly sliced) arrivals, so a
    prefix that fits the budget replays monolithically even when the full
    registered trace is streaming-only."""
    arr = np.asarray(trace["arrival_us"], np.float64)
    span = float(arr[-1] - arr[0]) if len(arr) else 0.0
    if span <= _MAX_SPAN_US:
        return
    raise ValueError(
        f"trace {name!r} spans {span/1e6:.1f} s of arrivals — beyond the "
        f"simulator's int32 tick budget ({_MAX_SPAN_US/1e6:.1f} s) for a "
        "monolithic replay.  Stream it instead: "
        "repro.ssd.stream.stream_simulate replays it in tick-rebased "
        "windows (repro.workloads.iter_trace_windows for file-level "
        "slicing), or slice a fitting prefix via trace_for(name, n)."
    )


def _slice_trace(trace: Dict[str, np.ndarray], n: int | None):
    full = len(trace["arrival_us"])
    if n is None or n >= full:
        return dict(trace)
    out = dict(trace)
    for k in ("arrival_us", "is_read", "offset_bytes", "size_bytes",
              "tenant"):
        if k in out:
            out[k] = out[k][:n]
    return out


def _seq_stream_offsets(
    off: np.ndarray,
    sz_align: np.ndarray,
    is_seq: np.ndarray,
    stream_of: np.ndarray,
    n_align: int,
) -> np.ndarray:
    """Resolve sequential-stream addresses without a per-request loop.

    Semantics (the former scalar loop): every request advances its stream's
    cursor to ``offset + size``; a sequential request first *reads* the
    cursor (mod ``n_align``) as its offset, a random request resets the
    cursor to its own random offset.  Because ``(x % n + s) % n == (x + s)
    % n``, a run of sequential requests between two resets is a prefix sum:
    ``offset_k = (base + sum of sizes of earlier seq requests in the run)
    % n_align`` where ``base`` is the cursor left by the last reset (0 at
    stream start).  That turns the whole recurrence into one grouped
    cumulative sum over (stream, arrival-order) — pinned bit-exactly to the
    scalar loop by ``tests/test_traces.py``.
    """
    n = len(off)
    if n == 0 or not is_seq.any():
        return off
    order = np.argsort(stream_of, kind="stable")  # stream-major, arrival order
    s_s = stream_of[order]
    seq_s = is_seq[order]
    off_s = off[order].copy()
    sz_s = sz_align[order]
    # exclusive prefix sum of seq sizes (within the stream-major layout)
    excl = np.concatenate(([0], np.cumsum(np.where(seq_s, sz_s, 0))))[:-1]
    idx = np.arange(n, dtype=np.int64)
    # latest reset (= non-seq request) at or before each position …
    reset_at = np.maximum.accumulate(np.where(~seq_s, idx, -1))
    # … clipped to the current stream: positions before the stream's first
    # request belong to another stream ⇒ base cursor 0
    starts = np.concatenate(([0], np.flatnonzero(s_s[1:] != s_s[:-1]) + 1))
    counts = np.diff(np.concatenate((starts, [n])))
    start_of = np.repeat(starts, counts)
    in_stream = reset_at >= start_of
    r = np.clip(reset_at, 0, None)
    base = np.where(in_stream, off_s[r] + sz_s[r], 0)
    run_sum = excl - np.where(in_stream, excl[r], excl[start_of])
    off_s[seq_s] = (base + run_sum)[seq_s] % n_align
    out = off.copy()
    out[order] = off_s
    return out


def gen_trace(
    name: str,
    n_requests: int,
    seed: int = 0,
    footprint_bytes: int = 128 << 20,
    hot_weight: float = 0.6,
    n_extents: int = 4,
    extent_kb: int = 256,
    burst_mean: float = 64.0,
    burst_speed: float = 64.0,
    seq_frac: float = 0.5,
    n_streams: int = 8,
    stats: WorkloadStats | None = None,
) -> Dict[str, np.ndarray]:
    """Generate one synthetic trace in *byte* units (page-size agnostic).

    Arrivals use an ON/OFF burst process (deep-queue submission, like the
    originals): bursts of ~``burst_mean`` requests arrive ``burst_speed``×
    faster than the mean rate, separated by long gaps; the *overall mean*
    inter-arrival time equals Table 2's value exactly in expectation.

    ``stats`` overrides the Table-2 registry lookup — a characterized real
    workload (``repro.workloads.characterize``) generates through the same
    path as every registered name.
    """
    read_pct, avg_kb, avg_iat_us = (
        stats if stats is not None else WORKLOADS[name]
    )
    rs = np.random.RandomState((zlib.crc32(name.encode()) & 0x7FFFFFFF) ^ seed)

    # arrivals: ON/OFF bursts with exact mean IAT
    m, s = burst_mean, burst_speed
    in_burst = rs.rand(n_requests) < (m - 1.0) / m
    iat_b = avg_iat_us / s
    iat_g = avg_iat_us * (m - (m - 1.0) / s)  # preserves the Table-2 mean
    iat = np.where(
        in_burst,
        rs.exponential(iat_b, n_requests),
        rs.exponential(iat_g, n_requests),
    )
    iat *= avg_iat_us / iat.mean()  # exact-mean correction (like sizes)
    arrival = np.cumsum(iat)

    # sizes: lognormal with target mean, 4KB-aligned, heavy tail
    sigma = 0.7
    mu = np.log(avg_kb * 1024) - sigma * sigma / 2
    size = rs.lognormal(mu, sigma, n_requests)
    size = np.maximum(_ALIGN, (size / _ALIGN).round() * _ALIGN)
    # exact-mean correction (keeps Table 2 average request size)
    size *= (avg_kb * 1024) / size.mean()
    size = np.maximum(_ALIGN, (size / _ALIGN).round() * _ALIGN).astype(np.int64)

    is_read = rs.rand(n_requests) < (read_pct / 100.0)

    # addresses: three-way mixture, calibrated to enterprise-trace structure:
    #   * hot refs target a handful of small contiguous *extents* (hot files,
    #     indexes, metadata — typically 100s of KB).  A small extent occupies many
    #     chips of few channels under die-first superpage layout, which is
    #     exactly the access pattern that serializes a shared-bus SSD while a
    #     path-diverse interconnect reaches all of the extent's chips at once;
    #   * sequential streams (scans / file reads) walk contiguous ranges;
    #   * the rest is uniform over the footprint.
    n_align = footprint_bytes // _ALIGN
    hot = rs.rand(n_requests) < hot_weight
    ext_pages = max(1, (extent_kb * 1024) // _ALIGN)
    ext_base = rs.randint(0, max(1, n_align - ext_pages), n_extents)
    # zipf-ish popularity over extents
    pop = 1.0 / np.arange(1, n_extents + 1)
    pop /= pop.sum()
    ext_of = rs.choice(n_extents, n_requests, p=pop)
    off_hot = ext_base[ext_of] + rs.randint(0, ext_pages, n_requests)
    off = np.where(hot, off_hot, rs.randint(0, n_align, n_requests)).astype(np.int64)
    sz_align = (size // _ALIGN).astype(np.int64)
    is_seq = (rs.rand(n_requests) < seq_frac) & ~hot
    stream_of = rs.randint(0, n_streams, n_requests)
    off = _seq_stream_offsets(off, sz_align, is_seq, stream_of, n_align)

    return {
        "name": name,
        "arrival_us": arrival,
        "is_read": is_read,
        "offset_bytes": off * _ALIGN,
        "size_bytes": size,
        "footprint_bytes": footprint_bytes,
    }


def mix_traces(name: str, n_requests_each: int, seed: int = 0) -> Dict[str, np.ndarray]:
    """Table 3 mixes: overlay constituents on a shared timeline with disjoint
    address ranges (separate tenants hitting one SSD).  Request counts are
    scaled per constituent so all spans align (faster tenants issue more).

    Emits per-request tenant attribution (``tenant`` = constituent index,
    ``tenant_names``) — pure metadata riding along the arrays: stripping
    the two keys yields the bit-identical untagged single-tenant trace.

    Constituents may be Table-2 workloads OR registered real traces
    (``CUSTOM_TRACES``): a registered name contributes a slice of its
    literal trace, scaled by its measured mean IAT like any synthetic
    tenant.
    """
    names = MIXES.get(name, None)
    if names is None:  # ad-hoc mixes: "a+b" tenant lists beyond Table 3
        names = tuple(name.split("+"))

    def iat_of(w):
        if w in CUSTOM_TRACES:
            a = np.asarray(CUSTOM_TRACES[w]["arrival_us"], np.float64)
            return max(float(np.diff(a, prepend=0.0).mean()), 1e-9)
        return WORKLOADS[w][2]

    span = n_requests_each * min(iat_of(w) for w in names)
    parts = []
    for i, w in enumerate(names):
        cnt = max(50, int(span / iat_of(w)))
        if w in CUSTOM_TRACES:
            parts.append(_slice_trace(CUSTOM_TRACES[w], cnt))
        else:
            parts.append(gen_trace(w, cnt, seed + i))
    return overlay_traces(name, names, parts)


def overlay_traces(name: str, tenant_names, parts) -> Dict[str, np.ndarray]:
    """Overlay per-tenant byte traces on one timeline, disjoint addresses."""
    base = 0
    arrs, reads, offs, sizes, tens = [], [], [], [], []
    for t, p in enumerate(parts):
        arrs.append(p["arrival_us"])
        reads.append(p["is_read"])
        offs.append(p["offset_bytes"] + base)
        sizes.append(p["size_bytes"])
        tens.append(np.full(len(p["arrival_us"]), t, dtype=np.int32))
        base += p["footprint_bytes"]
    arrival = np.concatenate(arrs)
    order = np.argsort(arrival, kind="stable")
    return {
        "name": name,
        "arrival_us": arrival[order],
        "is_read": np.concatenate(reads)[order],
        "offset_bytes": np.concatenate(offs)[order],
        "size_bytes": np.concatenate(sizes)[order],
        "footprint_bytes": base,
        "tenant": np.concatenate(tens)[order],
        "tenant_names": tuple(tenant_names),
    }


def to_pages(trace: Dict[str, np.ndarray], page_bytes: int) -> Dict[str, np.ndarray]:
    """Convert a byte trace to page units for a given SSD config."""
    off = trace["offset_bytes"] // page_bytes
    last = (trace["offset_bytes"] + trace["size_bytes"] + page_bytes - 1) // page_bytes
    pages = {
        "arrival_us": trace["arrival_us"],
        "is_read": trace["is_read"],
        "offset_page": off.astype(np.int64),
        "n_pages": np.maximum(1, last - off).astype(np.int64),
        "footprint_pages": max(1, trace["footprint_bytes"] // page_bytes),
    }
    if "tenant" in trace:  # per-request attribution rides along untouched
        pages["tenant"] = np.asarray(trace["tenant"], np.int32)
        pages["tenant_names"] = tuple(trace.get(
            "tenant_names", [str(t) for t in
                             range(int(pages["tenant"].max()) + 1)]
        ))
    return pages


def trace_for(name: str, n_requests: int, seed: int = 0, *,
              monolithic: bool = True):
    """Workload, mix, or registered real trace by name.

    ``monolithic=True`` (every non-streaming consumer) refuses a
    streaming-only registered trace whose requested slice still exceeds the
    int32 tick budget; the streaming engine and closed-loop sweeps pass
    ``monolithic=False``."""
    if name in CUSTOM_TRACES:
        tr = _slice_trace(CUSTOM_TRACES[name], n_requests)
        if monolithic and CUSTOM_TRACES[name].get("streaming_only"):
            _require_monolithic(tr, name)
        return tr
    if name in MIXES:
        per = max(1, n_requests // len(MIXES[name]))
        return mix_traces(name, per, seed)
    return gen_trace(name, n_requests, seed)


def default_n_requests(name: str, target_span_us: float = 300_000.0) -> int:
    """Pick a request count so every trace spans a comparable wall-clock
    window (sparse traces need fewer requests; int32 tick budget)."""
    if name in CUSTOM_TRACES:
        return len(CUSTOM_TRACES[name]["arrival_us"])
    if name in MIXES:
        iat = min(WORKLOADS[w][2] for w in MIXES[name]) / len(MIXES[name])
    else:
        iat = WORKLOADS[name][2]
    return int(np.clip(target_span_us / max(iat, 1e-9), 1500, 12000))
