"""Optimizers (self-contained — no optax in this container)."""
from repro.optim.optimizers import (
    Optimizer,
    adamw,
    adafactor,
    clip_by_global_norm,
    global_norm,
)
from repro.optim.compression import (
    compress_int8,
    decompress_int8,
    error_feedback_update,
)

__all__ = [
    "Optimizer", "adamw", "adafactor", "clip_by_global_norm", "global_norm",
    "compress_int8", "decompress_int8", "error_feedback_update",
]
