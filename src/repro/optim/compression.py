"""Int8 gradient compression with error feedback, for the cross-pod
all-reduce (the only DCN-crossing collective in training).

``compressed_psum`` is used inside ``shard_map`` over the "pod" axis: each
pod quantizes its gradient shard to int8 with a per-tensor scale, psums the
int8 payload in int32 (exact — pod counts are tiny), and rescales.  Error
feedback folds the quantization residual into the next step's gradient, which
is what keeps SGD/Adam convergence unaffected (Seide et al. / EF-SGD).
8x less DCN traffic than f32 all-reduce, 4x less than bf16.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(g):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def error_feedback_update(g, err):
    """Fold the residual of the previous step in, compress, return
    (compressed estimate, new residual)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = compress_int8(corrected)
    dq = decompress_int8(q, scale)
    return dq, corrected - dq


def compressed_psum(g, axis_name: str):
    """Quantized psum-mean over ``axis_name`` (call inside shard_map).

    A scalar pmax first agrees on a shared scale (so the int32 accumulation
    is exact), then the int8-range payload is summed — the wide tensor
    crosses the DCN at 1 byte/element."""
    g32 = g.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total.astype(jnp.float32) * scale / n
