"""AdamW and Adafactor (factored second moment), with global-norm clipping.

States are pytrees mirroring the params, so the same sharding specs apply
(ZeRO-style: optimizer state lives wherever its param shard lives).  For the
~1T-param arch AdamW's two f32 moments don't fit; Adafactor's row/col
factored second moment is the standard answer (documented in DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]  # (grads, state, params) -> (new_params, new_state)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        jax.tree_util.tree_reduce(
            lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), tree, 0.0
        )
    )


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), tree), norm


def adamw(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          clip_norm=1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        c = state["count"] + 1
        b1c = 1.0 - b1 ** c.astype(jnp.float32)
        b2c = 1.0 - b2 ** c.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            step = (m / b1c) / (jnp.sqrt(v / b2c) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return m, v, (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        flat = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
        m = jax.tree_util.tree_map(lambda t: t[0], flat,
                                   is_leaf=lambda t: isinstance(t, tuple))
        v = jax.tree_util.tree_map(lambda t: t[1], flat,
                                   is_leaf=lambda t: isinstance(t, tuple))
        new_p = jax.tree_util.tree_map(lambda t: t[2], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"m": m, "v": v, "count": c}, gnorm

    return Optimizer(init=init, update=update)


def adafactor(lr=None, decay=0.8, eps=1e-30, clip_norm=1.0,
              weight_decay=0.0) -> Optimizer:
    """Factored second-moment estimator (Shazeer & Stern 2018), no momentum.

    >=2D leaves store row/col running means (memory O(n+m) instead of O(nm));
    1D/0D leaves fall back to a full second moment.  ``lr=None`` uses the
    paper's relative step size min(1e-2, 1/sqrt(t))."""

    def init(params):
        def st(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "f": jax.tree_util.tree_map(st, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        c = state["count"] + 1
        rho = jnp.minimum(1e-2, 1.0 / jnp.sqrt(c.astype(jnp.float32)))
        step_size = rho if lr is None else lr
        d = decay

        def upd(g, f, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if p.ndim >= 2:
                vr = d * f["vr"] + (1 - d) * g2.mean(axis=-1)
                vc = d * f["vc"] + (1 - d) * g2.mean(axis=-2)
                denom = vr[..., :, None] * vc[..., None, :]
                denom = denom / jnp.maximum(
                    vr.mean(axis=-1)[..., None, None], eps
                )
                step = g32 * jax.lax.rsqrt(denom + eps)
                nf = {"vr": vr, "vc": vc}
            else:
                v = d * f["v"] + (1 - d) * g2
                step = g32 * jax.lax.rsqrt(v + eps)
                nf = {"v": v}
            # relative step size (update clipping à la Adafactor)
            rms = jnp.sqrt(jnp.mean(jnp.square(step)) + eps)
            step = step / jnp.maximum(1.0, rms)
            scale = step_size * jnp.maximum(
                jnp.sqrt(jnp.mean(jnp.square(p.astype(jnp.float32)))), 1e-3
            )
            newp = p.astype(jnp.float32) - scale * step
            if weight_decay:
                newp = newp - step_size * weight_decay * p.astype(jnp.float32)
            return nf, newp.astype(p.dtype)

        g_leaves, treedef = jax.tree_util.tree_flatten(grads)
        p_leaves = treedef.flatten_up_to(params)
        is_state = lambda t: isinstance(t, dict) and ("vr" in t or "v" in t)
        f_leaves, _ = jax.tree_util.tree_flatten(state["f"], is_leaf=is_state)
        outs = [upd(g, f, p) for g, f, p in zip(g_leaves, f_leaves, p_leaves)]
        nf = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        np_ = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        return np_, {"f": nf, "count": c}, gnorm

    return Optimizer(init=init, update=update)
