"""Fault tolerance for 1000+-node posture.

* ``HeartbeatMonitor``: hosts report liveness; a host silent past its
  deadline is declared dead (clock injectable for tests).
* ``StragglerDetector``: per-step durations per host; a host is a straggler
  when it exceeds max(deadline_floor, k · median) for ``patience``
  consecutive steps (the "deadline + p99" rule) — the training driver then
  excludes it like a failure (recompute its data shard elsewhere) instead of
  letting one slow HBM/host gate every step.
* ``replan_mesh``: given the survivor count, pick the largest (pods, data,
  model) mesh that keeps the model axis intact (TP must stay whole; batch
  shrinks), emitting the data re-shard plan; the checkpoint store restores
  into any shard count, so elastic downscale = replan + restore + continue.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


class HeartbeatMonitor:
    def __init__(self, hosts: List[str], timeout_s: float = 60.0, clock=None):
        import time

        self._clock = clock or time.monotonic
        self.timeout_s = timeout_s
        now = self._clock()
        self.last_seen: Dict[str, float] = {h: now for h in hosts}

    def beat(self, host: str) -> None:
        self.last_seen[host] = self._clock()

    def dead_hosts(self) -> List[str]:
        now = self._clock()
        return [h for h, t in self.last_seen.items()
                if now - t > self.timeout_s]

    def alive(self) -> List[str]:
        dead = set(self.dead_hosts())
        return [h for h in self.last_seen if h not in dead]


class StragglerDetector:
    def __init__(self, k: float = 2.0, deadline_floor_s: float = 0.05,
                 patience: int = 3):
        self.k = k
        self.floor = deadline_floor_s
        self.patience = patience
        self._strikes: Dict[str, int] = {}

    def observe_step(self, durations: Dict[str, float]) -> List[str]:
        """Feed one step's per-host durations; returns current stragglers."""
        if not durations:
            return []
        med = sorted(durations.values())[len(durations) // 2]
        deadline = max(self.floor, self.k * med)
        out = []
        for h, d in durations.items():
            if d > deadline:
                self._strikes[h] = self._strikes.get(h, 0) + 1
            else:
                self._strikes[h] = 0
            if self._strikes.get(h, 0) >= self.patience:
                out.append(h)
        return out


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    pods: int
    data: int
    model: int
    global_batch: int
    reshard: bool  # params must be re-restored under the new mesh

    @property
    def devices(self) -> int:
        return self.pods * self.data * self.model


def replan_mesh(
    n_devices_alive: int,
    model_parallel: int = 16,
    per_replica_batch: int = 1,
    prev: Optional[ElasticPlan] = None,
) -> ElasticPlan:
    """Largest usable (pods, data, model) mesh after failures.

    The model axis is immutable (param shards must stay whole); we keep
    whole multiples of (model_parallel x data=16) "pod slices" when we can,
    else shrink the data axis. Batch scales with data parallelism so per-
    device compute stays constant (elastic batch)."""
    if n_devices_alive < model_parallel:
        raise ValueError("not enough devices for one model-parallel group")
    slice_size = model_parallel * 16
    pods = n_devices_alive // (slice_size)
    if pods >= 1:
        data = 16
    else:
        pods = 1
        data = n_devices_alive // model_parallel
    plan = ElasticPlan(
        pods=pods,
        data=data,
        model=model_parallel,
        global_batch=pods * data * per_replica_batch,
        reshard=prev is None or (pods, data) != (prev.pods, prev.data),
    )
    return plan
