"""Fault-tolerance runtime: heartbeats, straggler detection, elastic mesh
re-planning."""
from repro.runtime.fault_tolerance import (
    ElasticPlan,
    HeartbeatMonitor,
    StragglerDetector,
    replan_mesh,
)

__all__ = ["ElasticPlan", "HeartbeatMonitor", "StragglerDetector", "replan_mesh"]
