"""Training driver (end-to-end example on CPU; production path on TPU).

Wires together: config -> init -> sharded train_step -> synthetic data ->
checkpointing (atomic, sharded) -> fault-tolerance hooks (heartbeats,
straggler detection, elastic re-plan).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import restore_latest, save
from repro.configs import get_config, get_smoke
from repro.data.pipeline import SyntheticTokens
from repro.launch.steps import make_train_step, optimizer_for
from repro.models.lm import init_lm
from repro.runtime import StragglerDetector


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    opt = optimizer_for(args.arch)
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    opt_state = opt.init(params)
    n_params = sum(np.prod(x.shape) for x in jax.tree_util.tree_leaves(params))
    print(f"[train] {cfg.name} ({'smoke' if args.smoke else 'full'}): "
          f"{n_params/1e6:.1f}M params")

    start_step = 0
    if args.ckpt_dir:
        got = restore_latest(args.ckpt_dir, {"params": params, "opt": opt_state})
        if got[0] is not None:
            start_step = got[0]
            params, opt_state = got[1]["params"], got[1]["opt"]
            print(f"[train] restored from step {start_step}")

    data = SyntheticTokens(cfg.vocab, args.seq, args.batch, args.seed)
    train_step = jax.jit(make_train_step(cfg, opt))
    detector = StragglerDetector()

    for step in range(start_step, args.steps):
        t0 = time.time()
        batch = {"tokens": data.batch(step)}
        if cfg.family == "vlm":
            batch["images"] = np.zeros(
                (args.batch, cfg.n_img_tokens, cfg.vision_dim), np.float32)
        if cfg.family == "audio":
            batch["frames"] = np.zeros(
                (args.batch, cfg.n_audio_frames, cfg.d_model), np.float32)
        params, opt_state, metrics = train_step(params, opt_state, batch)
        dt = time.time() - t0
        stragglers = detector.observe_step({"host0": dt})
        if step % 5 == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms"
                  + (f" stragglers={stragglers}" if stragglers else ""))
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save(args.ckpt_dir, step + 1,
                 {"params": params, "opt": opt_state})
    print("[train] done")


if __name__ == "__main__":
    main()
