"""train_step / prefill / serve_step builders + their sharding specs.

These are the exact functions the dry-run lowers and the CPU drivers run —
one code path for both (deliverable e: the compiled artifact IS the system).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, input_specs
from repro.models.lm import (
    init_decode_cache,
    init_lm,
    lm_apply,
    lm_decode_step,
    lm_loss,
)
from repro.optim import adafactor, adamw
from repro.parallel.sharding import batch_specs, cache_specs, param_specs

# archs whose param/optimizer shards must span the whole machine
_BIG_ARCHS = {"kimi-k2-1t-a32b", "mistral-large-123b", "llama-3.2-vision-90b"}


def optimizer_for(arch: str):
    # AdamW f32 moments for the 1T-param arch would need ~8 TB; Adafactor's
    # factored second moment is the standard fix (DESIGN.md §4).
    return adafactor() if arch == "kimi-k2-1t-a32b" else adamw()


def fsdp_axes_for(arch: str, mesh) -> tuple:
    axes = ("pod", "data") if arch in _BIG_ARCHS else ("data",)
    return tuple(a for a in axes if a in mesh.shape.keys())


def make_train_step(cfg, opt):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch), has_aux=True
        )(params)
        new_params, new_opt, gnorm = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(loss=loss, grad_norm=gnorm)
        return new_params, new_opt, metrics

    return train_step


def make_train_step_accum(cfg, opt, accum: int):
    """Gradient accumulation over ``accum`` microbatches (leading dim)."""

    def train_step(params, opt_state, batch):
        def micro(g_acc, mb):
            (loss, _), grads = jax.value_and_grad(
                lambda p: lm_loss(p, cfg, mb), has_aux=True
            )(params)
            return jax.tree_util.tree_map(jnp.add, g_acc, grads), loss

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        g_sum, losses = jax.lax.scan(micro, zeros, batch)
        grads = jax.tree_util.tree_map(lambda g: g / accum, g_sum)
        new_params, new_opt, gnorm = opt.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": losses.mean(), "grad_norm": gnorm}

    return train_step


def make_prefill(cfg):
    def prefill(params, batch):
        logits, _ = lm_apply(params, cfg, batch)
        return logits

    return prefill


def make_serve_step(cfg):
    def serve_step(params, cache, token, pos):
        logits, cache = lm_decode_step(params, cfg, cache, token, pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return serve_step


# ---------------------------------------------------------------------------
# abstract shapes + shardings for one (arch, shape, mesh) cell
# ---------------------------------------------------------------------------


def cell_abstract(arch: str, shape: str, mesh, notes: Optional[list] = None,
                  cfg_overrides: Optional[dict] = None):
    """Returns (fn, args_shape_tree, in_shardings, kind) ready to lower."""
    import dataclasses

    cfg = get_config(arch)
    seq, batch, kind = SHAPES[shape]
    fsdp = fsdp_axes_for(arch, mesh)
    bax = tuple(a for a in ("pod", "data") if a in mesh.shape.keys())
    nb = int(np.prod([mesh.shape[a] for a in bax])) if bax else 1
    if batch % nb != 0:
        bax = ()
    overrides = dict(cfg_overrides or {})
    opt_name = overrides.pop("__optimizer__", None)  # perf-iteration knob
    cfg = dataclasses.replace(cfg, batch_axes=bax, **overrides)

    params_shape = jax.eval_shape(
        functools.partial(init_lm, cfg=cfg), jax.random.PRNGKey(0)
    )
    pspecs = param_specs(mesh, params_shape, fsdp, notes)
    psh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)

    if kind == "train":
        opt = adafactor() if opt_name == "adafactor" else optimizer_for(arch)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        ospecs = param_specs(mesh, opt_shape, fsdp, notes)
        osh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), ospecs)
        bshape = input_specs(arch, shape)
        bspecs = batch_specs(mesh, bshape, notes)
        bsh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), bspecs)
        fn = make_train_step(cfg, opt)
        return fn, (params_shape, opt_shape, bshape), (psh, osh, bsh), kind

    if kind == "prefill":
        bshape = input_specs(arch, shape)
        bspecs = batch_specs(mesh, bshape, notes)
        bsh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), bspecs)
        fn = make_prefill(cfg)
        return fn, (params_shape, bshape), (psh, bsh), kind

    # decode
    cache_shape = jax.eval_shape(
        lambda: init_decode_cache(cfg, batch, seq)
    )
    cspecs = cache_specs(mesh, cache_shape, seq_shard=(shape == "long_500k"),
                         notes=notes)
    csh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), cspecs)
    tok_shape = jax.ShapeDtypeStruct((batch,), jnp.int32)
    pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
    bax = tuple(a for a in ("pod", "data") if a in mesh.shape.keys())
    tok_spec = P(bax if len(bax) > 1 else (bax[0] if bax else None))
    if batch % max(
        1, int(jnp.prod(jnp.array([mesh.shape[a] for a in bax])))
    ) != 0:
        tok_spec = P()
    tsh = NamedSharding(mesh, tok_spec)
    fn = make_serve_step(cfg)
    return (
        fn,
        (params_shape, cache_shape, tok_shape, pos_shape),
        (psh, csh, tsh, NamedSharding(mesh, P())),
        kind,
    )
