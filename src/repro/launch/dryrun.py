import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count="
    + os.environ.get("DRYRUN_DEVICES", "512")
).strip()
# ^ MUST run before any jax import: jax locks the device count on first init.

"""Multi-pod dry-run (deliverable e).

For every (arch x input-shape) cell, lower + compile the real train/prefill/
serve step under the production mesh — 16x16 (single pod, 256 chips) and
2x16x16 (two pods, 512 chips) — and record:

  * compiled.memory_analysis()  (fits-per-device proof)
  * compiled.cost_analysis()    (HLO FLOPs / bytes for the roofline)
  * collective bytes by op kind (parsed from the post-SPMD optimized HLO)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.jsonl
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, applicable_shapes, shape_skip_reason
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import cell_abstract
from repro.roofline.analysis import collective_bytes_from_hlo, roofline_terms


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True,
             cfg_overrides: dict | None = None, tag: str = ""):
    mesh = make_production_mesh(multi_pod=multi_pod)
    notes: list = []
    fn, args, in_sh, kind = cell_abstract(arch, shape, mesh, notes,
                                          cfg_overrides=cfg_overrides)
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": kind,
        "devices": mesh.devices.size,
        "sharding_notes": notes,
        "tag": tag,
    }
    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        rec["cost"] = {
            k: float(v)
            for k, v in cost.items()
            if k in ("flops", "bytes accessed", "transcendentals")
        }
    except Exception as e:  # pragma: no cover
        rec["cost"] = {"error": str(e)}
    try:
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes_from_hlo(hlo)
        rec["hlo_bytes"] = len(hlo)
    except Exception as e:  # pragma: no cover
        rec["collectives"] = {"error": str(e)}
    rec["roofline"] = roofline_terms(rec, arch)
    if verbose:
        mem_gb = rec.get("memory", {}).get("temp_size_in_bytes", 0) / 2**30
        print(
            f"[dryrun] {arch:24s} {shape:12s} {rec['mesh']:8s} OK "
            f"lower={rec['lower_s']}s compile={rec['compile_s']}s "
            f"temp/dev={mem_gb:.2f}GiB "
            f"flops={rec.get('cost', {}).get('flops', 0):.3g}",
            flush=True,
        )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--unroll", action="store_true",
                    help="unroll scan-over-layers (true HLO flop counts; "
                         "slower compiles)")
    ap.add_argument("--override", default=None,
                    help="JSON LMConfig overrides (perf hillclimbing), "
                         'e.g. \'{"gqa_grouped": true}\'')
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    cells = []
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    for arch in archs:
        shapes = (
            applicable_shapes(arch)
            if (args.all or not args.shape)
            else [args.shape]
        )
        for shape in shapes:
            reason = shape_skip_reason(arch, shape)
            if reason:
                print(f"[dryrun] {arch:24s} {shape:12s} SKIP: {reason}")
                continue
            cells.append((arch, shape))

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out_f = open(args.out, "a") if args.out else None
    failures = 0
    for arch, shape in cells:
        for multi in meshes:
            try:
                ov = dict(json.loads(args.override)) if args.override else {}
                if args.unroll:
                    ov["scan_unroll"] = True
                rec = run_cell(arch, shape, multi, cfg_overrides=ov or None,
                               tag=args.tag or ("unroll" if args.unroll else ""))
            except Exception as e:
                failures += 1
                rec = {
                    "arch": arch, "shape": shape,
                    "mesh": "2x16x16" if multi else "16x16",
                    "error": f"{type(e).__name__}: {e}",
                }
                print(f"[dryrun] {arch} {shape} {rec['mesh']} FAILED: {e}",
                      flush=True)
                traceback.print_exc()
            if out_f:
                out_f.write(json.dumps(rec) + "\n")
                out_f.flush()
    if out_f:
        out_f.close()
    print(f"[dryrun] done, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
