"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))
