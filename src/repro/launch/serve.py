"""Serving driver: batched greedy decode with KV/SSM caches.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models.lm import init_decode_cache, init_lm, lm_decode_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    S_max = args.prompt_len + args.gen
    cache = init_decode_cache(cfg, args.batch, S_max)
    rs = np.random.RandomState(args.seed)
    if cfg.family == "vlm":
        cache["img"] = jnp.asarray(
            rs.randn(args.batch, cfg.n_img_tokens, cfg.d_model), cfg.dtype)
    if cfg.family == "audio":
        cache["enc"] = jnp.asarray(
            rs.randn(args.batch, cfg.n_audio_frames, cfg.d_model), cfg.dtype)

    step = jax.jit(
        lambda p, c, t, pos: lm_decode_step(p, cfg, c, t, pos),
        static_argnames=(),
    )
    prompt = rs.randint(0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    tok = jnp.asarray(prompt[:, 0])
    t0 = time.time()
    out_tokens = [np.asarray(tok)]
    for pos in range(S_max - 1):
        logits, cache = step(params, cache, tok, pos)
        if pos + 1 < args.prompt_len:
            tok = jnp.asarray(prompt[:, pos + 1])  # teacher-forced prompt
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    dt = time.time() - t0
    seqs = np.stack(out_tokens, axis=1)
    tput = args.batch * (S_max - 1) / dt
    print(f"[serve] {cfg.name}: {args.batch} seqs x {S_max} steps in "
          f"{dt:.1f}s ({tput:.1f} tok/s)")
    print("[serve] first sequence:", seqs[0, : args.prompt_len + 8].tolist())


if __name__ == "__main__":
    main()
