"""Batched serving example: greedy decode with KV caches on a small model.

  PYTHONPATH=src python examples/serve_lm.py --arch gemma2-2b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models.lm import init_decode_cache, init_lm, lm_decode_step

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma2-2b")
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--gen", type=int, default=48)
args = ap.parse_args()

cfg = get_smoke(args.arch)
params = init_lm(jax.random.PRNGKey(0), cfg)
cache = init_decode_cache(cfg, args.batch, args.gen + 8)
rs = np.random.RandomState(0)
if cfg.family == "vlm":
    cache["img"] = jnp.asarray(
        rs.randn(args.batch, cfg.n_img_tokens, cfg.d_model), cfg.dtype)
if cfg.family == "audio":
    cache["enc"] = jnp.asarray(
        rs.randn(args.batch, cfg.n_audio_frames, cfg.d_model), cfg.dtype)

step = jax.jit(lambda p, c, t, i: lm_decode_step(p, cfg, c, t, i))
tok = jnp.asarray(rs.randint(0, cfg.vocab, (args.batch,)), jnp.int32)
outs = [np.asarray(tok)]
t0 = time.time()
for pos in range(args.gen):
    logits, cache = step(params, cache, tok, pos)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    outs.append(np.asarray(tok))
dt = time.time() - t0
print(f"[serve_lm] {cfg.name} ({cfg.family}): {args.batch}x{args.gen} tokens "
      f"in {dt:.1f}s = {args.batch*args.gen/dt:.0f} tok/s")
print("[serve_lm] sample:", np.stack(outs, 1)[0, :16].tolist())
