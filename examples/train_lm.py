"""End-to-end training driver example: a ~100M-param LM for a few hundred
steps with checkpoint/restart (CPU-sized by default; pass --full-100m for
the real thing if you have the cycles).

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import restore_latest, save
from repro.configs import get_config
from repro.data.pipeline import SyntheticTokens
from repro.launch.steps import make_train_step
from repro.models.lm import init_lm
from repro.optim import adamw

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
ap.add_argument("--full-100m", action="store_true")
args = ap.parse_args()

# qwen2-family config scaled to ~20M (CPU) or ~100M (--full-100m)
base = get_config("qwen2-0.5b")
cfg = dataclasses.replace(
    base,
    n_layers=8 if args.full_100m else 4,
    d_model=768 if args.full_100m else 256,
    n_heads=12 if args.full_100m else 4,
    n_kv=4 if args.full_100m else 2,
    head_dim=64,
    d_ff=2048 if args.full_100m else 512,
    vocab=32000,
    dtype=jax.numpy.float32,
    param_dtype=jax.numpy.float32,
    remat=False,
)

params = init_lm(jax.random.PRNGKey(0), cfg)
opt = adamw(lr=1e-3)
opt_state = opt.init(params)
n = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))
print(f"[train_lm] {n/1e6:.1f}M params, {args.steps} steps")

step0 = 0
got = restore_latest(args.ckpt, {"p": params, "o": opt_state})
if got[0]:
    step0, params, opt_state = got[0], got[1]["p"], got[1]["o"]
    print(f"[train_lm] resumed from step {step0}")

train_step = jax.jit(make_train_step(cfg, opt))
data = SyntheticTokens(cfg.vocab, args.seq, args.batch)
losses = []
t0 = time.time()
for step in range(step0, args.steps):
    batch = {"tokens": data.batch(step)}
    params, opt_state, m = train_step(params, opt_state, batch)
    losses.append(float(m["loss"]))
    if step % 20 == 0:
        print(f"  step {step:4d} loss {losses[-1]:.4f}")
    if (step + 1) % 100 == 0:
        save(args.ckpt, step + 1, {"p": params, "o": opt_state})
print(f"[train_lm] loss {losses[0]:.3f} -> {losses[-1]:.3f} "
      f"({time.time()-t0:.0f}s); loss must decrease:",
      losses[-1] < losses[0])
