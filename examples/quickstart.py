"""Quickstart: Venice in three acts.

 1. route one scout through a busy mesh (the paper's Algorithm 1);
 2. simulate a workload on Baseline vs Venice vs the conflict-free ideal;
 3. plan conflict-free parallel shard reads with the same machinery
    (the technique as a framework feature).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import build_mesh, scout_route_ref
from repro.core.rng import seed_for_scout
from repro.data.venice_io import plan_reads
from repro.ssd import perf_optimized
from repro.ssd.bench import run_workload

# --- 1. one scout ----------------------------------------------------------
topo = build_mesh(8, 8)
rs = np.random.RandomState(0)
busy = rs.rand(topo.n_links) < 0.5  # half the mesh is reserved
res = scout_route_ref(topo, src_node=0, dst_node=45, link_busy=busy,
                      seed=seed_for_scout(0, 0))
print(f"[1] scout: success={res.success} hops={res.hops} "
      f"(minimal {res.minimal_hops}) misroutes={res.misroutes} "
      f"backtracks={res.backtracks}")

# --- 2. SSD designs head to head -------------------------------------------
cfg = perf_optimized()
run = run_workload("src2_1", cfg, designs=("baseline", "nossd", "venice",
                                           "ideal"), n_requests=1500)
base = run.results["baseline"]
print(f"[2] src2_1 on {cfg.name}-optimized SSD "
      f"(accelerated replay x{run.accel:.0f}):")
for d, r in run.results.items():
    print(f"    {d:9s} exec={r.exec_s*1e3:7.1f}ms "
          f"speedup={base.exec_s/r.exec_s:4.2f}x "
          f"conflicts={r.conflict_rate()*100:5.1f}% "
          f"p99={r.p99_latency_us():7.0f}us")

# --- 3. Venice-scheduled parallel reads -------------------------------------
reqs = [(h, n) for h in range(4) for n in rs.randint(0, 32, 6)]
plan = plan_reads(reqs, n_hosts=4, n_storage=32)
print(f"[3] {len(reqs)} shard reads over a shared fabric -> "
      f"{plan.n_rounds} conflict-free rounds "
      f"(reservation failures while planning: {plan.n_conflicts})")
