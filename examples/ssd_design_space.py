"""Design-space exploration: mesh geometry x routing policy, beyond the
paper's three points (fig 15) — including the minimal-routing ablation, the
circuit-hold variant and the k-scout policy.  Every geometry row is ONE
batched sweep per cost class (see repro.ssd.sim.simulate_sweep); adding a
design to the sweep is a registry name, not new simulator code.

  PYTHONPATH=src python examples/ssd_design_space.py
"""
import time

from repro.ssd import perf_optimized
from repro.ssd.bench import geomean, run_workload

WORKLOADS = ["proj_3", "src2_1"]
DESIGNS = ("baseline", "nossd", "venice_minimal", "venice_hold",
           "venice_kscout", "venice", "ideal")

print(f"{'mesh':8s} " + " ".join(f"{d:>14s}" for d in DESIGNS))
for (rows, cols) in ((4, 16), (8, 8), (16, 4)):
    cfg = perf_optimized(rows=rows, cols=cols)
    gm = {d: [] for d in DESIGNS}
    t0 = time.time()
    for wl in WORKLOADS:
        run = run_workload(wl, cfg, designs=DESIGNS, n_requests=1500)
        for d in DESIGNS:
            gm[d].append(run.speedup(d))
    print(f"{rows}x{cols:<6d} "
          + " ".join(f"{geomean(gm[d]):13.2f}x" for d in DESIGNS)
          + f"   ({time.time()-t0:.0f}s)")
print("\nvenice_minimal = Algorithm 1 without misrouting (adaptivity ablation)")
print("venice_hold    = circuit held across tR (link-hours ablation)")
print("venice_kscout  = 3 scouts race, fewest-hop success wins (beyond-paper)")
