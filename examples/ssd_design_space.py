"""Design-space exploration: mesh geometry x routing policy, beyond the
paper's three points (fig 15) — including the minimal-routing ablation, the
circuit-hold variant and the k-scout policy.  Every geometry row is ONE
batched sweep per cost class (see repro.ssd.sim.simulate_sweep); adding a
design to the sweep is a registry name, not new simulator code.

  PYTHONPATH=src python examples/ssd_design_space.py
  PYTHONPATH=src python examples/ssd_design_space.py --trace mytrace.csv

``--trace`` replays a *real* trace (MSR-Cambridge or blktrace-style CSV)
instead of the synthetic Table-2 workloads: the file is ingested through
``repro.workloads`` (streamed parse, address compaction), characterized
against the paper's Table-2 statistics, registered for replay-by-name, and
swept through the same pipeline — cache, planner, metrics — as any
built-in workload.
"""
import argparse
import time

from repro.ssd import perf_optimized
from repro.ssd.bench import geomean, run_workload

DESIGNS = ("baseline", "nossd", "venice_minimal", "venice_hold",
           "venice_kscout", "venice", "ideal")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="replay a real trace CSV (MSR or blktrace-style) "
                         "instead of the synthetic workloads")
    ap.add_argument("--n-req", type=int, default=1500)
    args = ap.parse_args()

    if args.trace:
        from repro.workloads import characterize, load_trace, register_trace

        trace = load_trace(args.trace)
        prof = characterize(trace)
        print(f"ingested {prof.name}: {prof.n_requests} requests, "
              f"{prof.footprint_bytes >> 20} MB footprint (compacted)")
        print(f"  Table-2 stats: read {prof.stats.read_pct:.0f}%, "
              f"avg {prof.stats.avg_kb:.1f} KB, "
              f"IAT {prof.stats.avg_iat_us:.1f} us; "
              f"seq {prof.seq_frac:.2f}, hot {prof.hot_frac:.2f}, "
              f"IAT CV {prof.iat_cv:.1f}")
        register_trace(trace["name"], trace)  # already parsed + compacted
        workloads = [trace["name"]]
        n_req = min(args.n_req, prof.n_requests)
    else:
        workloads = ["proj_3", "src2_1"]
        n_req = args.n_req

    print(f"{'mesh':8s} " + " ".join(f"{d:>14s}" for d in DESIGNS))
    for (rows, cols) in ((4, 16), (8, 8), (16, 4)):
        cfg = perf_optimized(rows=rows, cols=cols)
        gm = {d: [] for d in DESIGNS}
        t0 = time.time()
        for wl in workloads:
            run = run_workload(wl, cfg, designs=DESIGNS, n_requests=n_req)
            for d in DESIGNS:
                gm[d].append(run.speedup(d))
        print(f"{rows}x{cols:<6d} "
              + " ".join(f"{geomean(gm[d]):13.2f}x" for d in DESIGNS)
              + f"   ({time.time()-t0:.0f}s)")
    print("\nvenice_minimal = Algorithm 1 without misrouting (adaptivity ablation)")
    print("venice_hold    = circuit held across tR (link-hours ablation)")
    print("venice_kscout  = 3 scouts race, fewest-hop success wins (beyond-paper)")


if __name__ == "__main__":
    main()
