"""Benchmark harness — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run             # quick preset
  PYTHONPATH=src python -m benchmarks.run --full      # all 19+6 workloads
  PYTHONPATH=src python -m benchmarks.run --smoke     # CI probe: 1 wl x 2 designs
  PYTHONPATH=src python -m benchmarks.run --only fig9 --csv results/
  PYTHONPATH=src python -m benchmarks.run --designs venice,venice_kscout,ideal
  PYTHONPATH=src python -m benchmarks.run --json results/BENCH_quick.json
  PYTHONPATH=src python -m benchmarks.run --ftl-engine scalar   # FTL A/B

Every sweep phase runs all requested designs through ONE compiled batched
program (``repro.ssd.sim.simulate_sweep``); ``--json`` records the perf
trajectory as a ``BENCH_*.json`` artifact so regressions are visible across
commits: per-phase wall-clock is split into ``ftl_s`` (trace → transaction
decomposition — the array-native engine, or the scalar oracle under
``--ftl-engine scalar``) and ``sim_s`` (the jitted sweep), plus per-design
speedups and cache telemetry.

Figures reproduced (as CSV tables; all values also summarized to stdout):
  fig4    prior approaches + ideal vs Baseline (perf-optimized)
  fig9    speedups, all designs x {perf, cost} configs
  fig10   IOPS normalized to the conflict-free ideal
  fig11   p99 tail latency (src1_0, hm_0)
  fig12   mixed workloads (Table 3)
  fig13   % requests experiencing path conflicts
  fig14   power / energy normalized to Baseline
  fig15   sensitivity: 4x16 / 8x8 / 16x4 flash-controller configs
  tab4    router/link power & area overheads (analytic)
  sec31   the two-read service-time example (exact latencies)
  tail    beyond-figures QoS surface (workloads subsystem): closed-loop
          queue-depth sweeps (synthetic + bundled real-trace fixture) and
          multi-tenant fairness — per-design p50/p95/p99 into BENCH_*.json
  stream  chunked streaming engine: a ~90 s (beyond the int32 tick budget)
          trace replayed in 10 s windows — per-window IO/s into
          BENCH_*.json; acceptance is flat throughput across windows

Every figure phase hands its whole (workload, config) list to the sweep
planner (``repro.ssd.sweep_plan.prefetch``) before its body runs, so the
phase's sweeps execute as lane groups sharded across the host CPU devices
(one virtual XLA device per core, forced below *before* jax initializes)
instead of one eager sweep per workload.
"""
from __future__ import annotations

import os

# One XLA host device per core so the sweep planner can shard lane groups,
# and the legacy (non-thunk) CPU runtime (see repro.xla_env).  MUST run
# before any jax import: jax locks these on first init.
from repro.xla_env import configure as _configure_xla

_configure_xla()

import argparse
import csv
import json
import time

import numpy as np

from repro.ssd import DESIGNS as ALL_DESIGNS
from repro.ssd import bench, cost_optimized, perf_optimized
from repro.ssd import sim
from repro.ssd import sweep_plan
from repro.ssd.bench import geomean, run_workload
from repro.ssd.sweep_plan import (
    RunRequest,
    precompile,
    prefetch,
    prewarm_small_keys,
)
from repro.traces import MIXES, WORKLOADS

QUICK_WL = ["proj_3", "src2_1", "hm_0", "prxy_0", "YCSB_B", "ssd-10", "usr_0"]
DEFAULT_DESIGNS = ("baseline", "pssd", "pnssd", "nossd", "venice", "ideal")
N_REQ_QUICK = 2500
# CI probe: the smallest run that still exercises the whole pipeline —
# trace gen -> FTL -> both cost classes (bus-routed baseline + scout-routed
# venice) -> metrics/CSV/JSON.  Keeps the fast lane failing on pipeline
# regressions without paying for a full sweep.
SMOKE_WL = ["hm_0"]
SMOKE_DESIGNS = ("baseline", "venice")
N_REQ_SMOKE = 240
SMOKE_PHASES = ("fig4_9_10_13", "tail", "stream", "faults", "tab4", "sec31")

# bundled anonymized MSR-format trace (tests/data, <50 KB): the real-trace
# leg of the tail phase and the ingestion tests share this fixture
FIXTURE_TRACE = os.path.join(
    os.path.dirname(__file__), "..", "tests", "data", "msr_sample.csv"
)


def _rows_to_csv(path, header, rows):
    if path:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(header)
            w.writerows(rows)


def _runs(workloads, cfg, n_req, designs, seed=0):
    out = {}
    for wl in workloads:
        t0 = time.time()
        out[wl] = run_workload(wl, cfg, designs=designs, n_requests=n_req,
                               seed=seed)
        print(f"  [{cfg.name}] {wl}: {time.time()-t0:.0f}s", flush=True)
    return out


def fig4_and_9_and_10_and_13(workloads, n_req, csv_dir, designs):
    rows9, rows10, rows13 = [], [], []
    summary = {}
    has_ideal = "ideal" in designs  # fig10 normalizes IOPS to the ideal lane
    cfgs = (perf_optimized(), cost_optimized())
    # one planning pass over BOTH configs: perf/cost share a geometry, so
    # their lanes pool into the same sharded groups
    prefetch([RunRequest(wl, cfg, designs, n_req)
              for cfg in cfgs for wl in workloads])
    for cfg in cfgs:
        runs = _runs(workloads, cfg, n_req, designs)
        sp = {d: [] for d in designs}
        for wl, r in runs.items():
            for d in designs:
                s = r.speedup(d)
                sp[d].append(s)
                rows9.append([cfg.name, wl, d, f"{s:.3f}"])
                if has_ideal:
                    rows10.append([cfg.name, wl, d, f"{r.iops_norm(d):.3f}"])
                rows13.append(
                    [cfg.name, wl, d,
                     f"{r.results[d].conflict_rate()*100:.2f}"]
                )
        summary[cfg.name] = {d: geomean(sp[d]) for d in designs}
        print(f"[fig9/{cfg.name}] geomean speedups: "
              + " ".join(f"{d}={summary[cfg.name][d]:.2f}x" for d in designs))
    _rows_to_csv(os.path.join(csv_dir, "fig9_speedup.csv"),
                 ["config", "workload", "design", "speedup"], rows9)
    if has_ideal:
        _rows_to_csv(os.path.join(csv_dir, "fig10_iops.csv"),
                     ["config", "workload", "design", "iops_norm_ideal"],
                     rows10)
    else:
        print("[fig10] skipped: no 'ideal' lane to normalize against")
    _rows_to_csv(os.path.join(csv_dir, "fig13_conflicts.csv"),
                 ["config", "workload", "design", "conflict_pct"], rows13)
    return summary


# phase request shapes shared with the cross-phase precompile in main()
FIG11_WLS = ("src1_0", "hm_0")
FIG15_MESHES = ((4, 16), (8, 8), (16, 4))
FIG15_WLS = ("proj_3", "src2_1", "YCSB_B")


def fig11_tail_latency(n_req, csv_dir, designs):
    cfg = perf_optimized()
    rows = []
    wls = FIG11_WLS
    prefetch([RunRequest(wl, cfg, designs, n_req) for wl in wls])
    for wl in wls:
        r = run_workload(wl, cfg, designs=designs, n_requests=n_req)
        for d in designs:
            p99 = r.results[d].p99_latency_us()
            rows.append([wl, d, f"{p99:.1f}"])
            print(f"[fig11] {wl} {d}: p99={p99:.1f}us")
    _rows_to_csv(os.path.join(csv_dir, "fig11_p99.csv"),
                 ["workload", "design", "p99_latency_us"], rows)


def fig12_mixes(n_req, csv_dir, designs, mixes=None):
    cfg = perf_optimized()
    rows = []
    gm = {d: [] for d in designs}
    mixes = tuple(mixes or sorted(MIXES))
    prefetch([RunRequest(mix, cfg, designs, n_req) for mix in mixes])
    for mix in mixes:
        r = run_workload(mix, cfg, designs=designs, n_requests=n_req)
        for d in designs:
            s = r.speedup(d)
            gm[d].append(s)
            rows.append([mix, d, f"{s:.3f}"])
    print("[fig12] mixes geomean: "
          + " ".join(f"{d}={geomean(gm[d]):.2f}x" for d in designs))
    _rows_to_csv(os.path.join(csv_dir, "fig12_mixes.csv"),
                 ["mix", "design", "speedup"], rows)


def fig14_power_energy(workloads, n_req, csv_dir, designs):
    cfg = perf_optimized()
    rows = []
    agg = {d: ([], []) for d in designs}
    prefetch([RunRequest(wl, cfg, designs, n_req) for wl in workloads])
    for wl in workloads:
        r = run_workload(wl, cfg, designs=designs, n_requests=n_req)
        base = r.results["baseline"]
        for d in designs:
            p = r.results[d].avg_power_w / base.avg_power_w
            e = r.results[d].energy_j / base.energy_j
            agg[d][0].append(p)
            agg[d][1].append(e)
            rows.append([wl, d, f"{p:.3f}", f"{e:.3f}"])
    for d in designs:
        print(f"[fig14] {d}: power={np.mean(agg[d][0]):.3f}x "
              f"energy={np.mean(agg[d][1]):.3f}x of baseline")
    _rows_to_csv(os.path.join(csv_dir, "fig14_power_energy.csv"),
                 ["workload", "design", "power_norm", "energy_norm"], rows)


def fig15_sensitivity(n_req, csv_dir, designs):
    rows = []
    designs = tuple(d for d in designs if d != "pnssd")  # needs rows==cols
    meshes = FIG15_MESHES
    wls = FIG15_WLS
    prefetch([RunRequest(wl, perf_optimized(rows=r_, cols=c_), designs, n_req)
              for (r_, c_) in meshes for wl in wls])
    for (r_, c_) in meshes:
        cfg = perf_optimized(rows=r_, cols=c_)
        gm = {d: [] for d in designs}
        for wl in wls:
            run = run_workload(wl, cfg, designs=designs, n_requests=n_req)
            for d in designs:
                gm[d].append(run.speedup(d))
        print(f"[fig15] {r_}x{c_}: " + " ".join(
            f"{d}={geomean(gm[d]):.2f}x" for d in designs))
        for d in designs:
            rows.append([f"{r_}x{c_}", d, f"{geomean(gm[d]):.3f}"])
    _rows_to_csv(os.path.join(csv_dir, "fig15_sensitivity.csv"),
                 ["mesh", "design", "geomean_speedup"], rows)


def tail_qos(n_req, csv_dir, designs, smoke=False):
    """QoS surface (workloads subsystem): closed-loop queue-depth sweeps on
    a synthetic workload AND the bundled real-trace fixture, plus a
    multi-tenant fairness scenario — per-design p50/p95/p99 + per-tenant
    slowdown/fairness, exported under the ``tail`` key of BENCH_*.json."""
    from repro.workloads import ingest_file
    from repro.workloads.scenario import (
        MultiTenantMix,
        QueueDepthSweep,
        run_queue_depth_sweeps,
        run_scenario,
    )

    cfg = perf_optimized()
    fixture = ingest_file(FIXTURE_TRACE, name="msr_fixture")
    qds = (1, 8, 64) if smoke else (1, 4, 16, 64)
    iters = 3 if smoke else 6  # feedback rounds (see QueueDepthSweep doc)
    qd_scns = [QueueDepthSweep(fixture, qds=qds, iters=iters,
                               n_requests=(240 if smoke else None))]
    if not smoke:  # the synthetic leg of the QD acceptance sweep:
        # read-heavy proj_3 — writes bury the depth response under
        # GC/tPROG plane time, reads expose the channel-conflict queueing
        qd_scns.insert(0, QueueDepthSweep("proj_3", qds=qds, iters=iters,
                                          n_requests=800))
    # the QD sweeps iterate ROUND-MERGED (one planner batch per feedback
    # round across all sweeps — bit-identical, but the dispatch-bound
    # tail collapses into full small-lane groups; see scenario.py)
    records = list(run_queue_depth_sweeps(cfg, qd_scns, designs))
    records.append(run_scenario(
        cfg, MultiTenantMix(("mix1",),
                            n_requests_each=(120 if smoke else 400)),
        designs,
    ))
    rows_qd, rows_fair = [], []
    for rec in records:
        if rec["scenario"] == "queue_depth_sweep":
            for d, per in rec["designs"].items():
                for q, m in per.items():
                    rows_qd.append([rec["workload"], d, q, m["p50_us"],
                                    m["p95_us"], m["p99_us"], m["iops"]])
            p99 = {d: per[str(qds[-1])]["p99_us"]
                   for d, per in rec["designs"].items()}
            print(f"[tail] {rec['workload']} QD{qds[-1]} p99: "
                  + " ".join(f"{d}={v:.0f}us" for d, v in p99.items()))
        else:
            for d, m in rec["designs"].items():
                for t, tm in m.get("tenants", {}).items():
                    rows_fair.append([rec["mix"], d, t, tm["p99_us"],
                                      tm.get("slowdown_vs_solo", ""),
                                      m["fairness"]])
                print(f"[tail] {rec['mix']} {d}: fairness={m['fairness']:.3f}"
                      f" p99={m['p99_us']:.0f}us")
    _rows_to_csv(os.path.join(csv_dir, "tail_qd.csv"),
                 ["workload", "design", "qd", "p50_us", "p95_us", "p99_us",
                  "iops"], rows_qd)
    _rows_to_csv(os.path.join(csv_dir, "tail_fairness.csv"),
                 ["mix", "design", "tenant", "p99_us", "slowdown_vs_solo",
                  "fairness"], rows_fair)
    return records


def stream_replay(csv_dir, designs, smoke=False):
    """Chunked streaming-engine leg: a synthetic ~90 s trace — 4x beyond
    the int32 tick budget — replayed in 10 s windows through
    ``repro.ssd.stream``.  Exports per-window ``ios_per_wallclock_s`` (the
    flat-throughput acceptance surface: prep/compile overlap execution, so
    later windows must not droop) into BENCH_*.json and a CSV."""
    from repro.traces.generator import CUSTOM_TRACES, gen_trace, register_trace
    from repro.workloads.scenario import StreamReplay, run_scenario

    cfg = perf_optimized()
    n_req = 600 if smoke else 2000
    name = "stream90_synth"
    if name not in CUSTOM_TRACES:
        tr = dict(gen_trace("hm_0", n_req, seed=11))
        # respace arrivals uniformly over 90 s: same addresses and ordering,
        # beyond-budget timeline -> registered streaming-only.  Uniform load
        # per window makes per-window IO/s comparable, so the droop check
        # measures the engine, not the workload's burst profile.
        tr["arrival_us"] = np.arange(n_req, dtype=np.float64) * (90e6 / n_req)
        register_trace(name, tr)
    rec = run_scenario(cfg, StreamReplay(name, window_s=10.0), designs)
    tp = [w["ios_per_wallclock_s"] for w in rec["windows"] if w["n_requests"]]
    print(f"[stream] {rec['n_windows']} windows x {rec['window_s']:.0f}s, "
          f"{rec['n_requests']} reqs; IO/s first={tp[0]:.0f} "
          f"last={tp[-1]:.0f} flatness={rec['throughput_flatness']:.2f}")
    _rows_to_csv(os.path.join(csv_dir, "stream_windows.csv"),
                 ["window", "n_requests", "n_txns", "prep_s", "exec_s",
                  "compile_wait_s", "wall_s", "ios_per_wallclock_s"],
                 [[w["window"], w["n_requests"], w["n_txns"], w["prep_s"],
                   w["exec_s"], w["compile_wait_s"], w["wall_s"],
                   w["ios_per_wallclock_s"]] for w in rec["windows"]])
    return rec


def fault_degradation(csv_dir, designs, smoke=False):
    """Degraded-mode leg (ISSUE 8): the same workload replayed under
    growing per-channel link-fault counts; exports each design's
    throughput retention (``iops_ok`` vs its own fault-free run) and
    permanent-failure rate into ``fault_degradation.csv`` + the
    ``faults`` key of BENCH_*.json.  The acceptance asymmetry: Venice's
    adaptive DFS routes around dead links while a shared-bus design
    loses the whole channel."""
    from repro.workloads.scenario import DegradedModeSweep, run_scenario

    cfg = perf_optimized()
    counts = (0, 1, 2) if smoke else (0, 1, 2, 4, 8)
    rec = run_scenario(
        cfg,
        DegradedModeSweep("hm_0", fault_counts=counts,
                          placement="per_channel",
                          n_requests=(240 if smoke else 800)),
        designs,
    )
    rows = []
    for d, curve in rec["designs"].items():
        for k, m in curve.items():
            rows.append([rec["workload"], rec["placement"], d, k,
                         m["iops_ok"], m["retention"], m["failure_pct"]])
        worst = curve[str(counts[-1])]
        print(f"[faults] {d}: retention@{counts[-1]}"
              f"={worst['retention']:.3f} "
              f"failures={worst['failure_pct']:.1f}%")
    _rows_to_csv(os.path.join(csv_dir, "fault_degradation.csv"),
                 ["workload", "placement", "design", "failed_links",
                  "iops_ok", "retention", "failure_pct"], rows)
    return rec


def tab4_overheads(csv_dir):
    """Analytic reproduction of Table 4 / §6.6 arithmetic."""
    router_mw = 0.241
    link_mw = 1.08
    n_links = 112
    n_routers = 64
    router_area_mm2 = 8.0  # incl. I/O pads
    chip_area_mm2 = 100.0
    link_area_rel = 0.04  # x flash channel area
    pcb_router_pct = router_area_mm2 / chip_area_mm2 * 100
    link_area_total = 1 - (n_links * link_area_rel) / (8 * 1.0)
    print(f"[tab4] router power {router_mw}mW x{n_routers}, link {link_mw}mW")
    print(f"[tab4] router PCB overhead {pcb_router_pct:.0f}% of flash chip")
    print(f"[tab4] links occupy {link_area_total*100:.0f}% LESS area than "
          f"the 8 shared channels (paper: 44%)")
    _rows_to_csv(os.path.join(csv_dir, "tab4_overheads.csv"),
                 ["quantity", "value"],
                 [["router_power_mw", router_mw],
                  ["link_power_mw_4KB", link_mw],
                  ["router_pcb_overhead_pct", f"{pcb_router_pct:.1f}"],
                  ["link_area_saving_pct", f"{link_area_total*100:.1f}"]])
    assert abs(link_area_total - 0.44) < 0.01  # matches the paper's §6.6


def sec31_example(csv_dir):
    from repro.ssd import simulate

    cfg = perf_optimized(bus_protocol_ovh_ns=0.0, chan_gbps=1.024)

    def mk(planes):
        n = len(planes)
        planes = np.asarray(planes, np.int64)
        chips = planes // 2
        return {
            "arrival": np.zeros(n, np.int64), "kind": np.zeros(n, np.int64),
            "plane": planes, "node": chips, "row": chips // cfg.cols,
            "nbytes": np.full(n, 4096, np.int64),
            "req": np.arange(n, dtype=np.int64),
        }

    conflict = simulate(cfg, mk([0, 2]), "baseline").exec_ticks / 100
    free = simulate(cfg, mk([0, 16]), "baseline").exec_ticks / 100
    print(f"[sec3.1] same-channel two reads: {conflict:.2f}us (paper 11.01)")
    print(f"[sec3.1] diff-channel two reads: {free:.2f}us (paper 7.01)")
    _rows_to_csv(os.path.join(csv_dir, "sec31_example.csv"),
                 ["case", "us", "paper_us"],
                 [["same_channel", f"{conflict:.2f}", 11.01],
                  ["different_channels", f"{free:.2f}", 7.01]])


def _parse_designs(arg: str | None):
    if not arg:
        return DEFAULT_DESIGNS
    if arg == "all":
        return ALL_DESIGNS
    designs = tuple(d.strip() for d in arg.split(",") if d.strip())
    unknown = [d for d in designs if d not in ALL_DESIGNS]
    if unknown:
        raise SystemExit(f"unknown designs {unknown}; registry: {ALL_DESIGNS}")
    if "baseline" not in designs:  # speedups/energy are baseline-normalized
        print("[benchmarks] adding 'baseline' lane (normalization reference)")
        designs = ("baseline",) + designs
    return designs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all 19 workloads + 6 mixes (slow)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI probe: 1 workload x 2 designs, core phases only")
    ap.add_argument("--only", default=None,
                    help="fig4|fig9|fig11|fig12|fig14|fig15|tail|stream|"
                         "faults|tab4|sec31")
    ap.add_argument("--csv", default="results")
    ap.add_argument("--n-req", type=int, default=None)
    ap.add_argument("--designs", default=None, metavar="D1,D2,...",
                    help="design lanes to sweep (default: the paper's six; "
                         "'all' = every registered design incl. ablations)")
    ap.add_argument("--ftl-engine", default="auto",
                    choices=("auto", "vector", "scalar"),
                    help="trace-decomposition engine (scalar = the "
                         "page-at-a-time oracle, for FTL-pipeline A/Bs)")
    ap.add_argument("--lane-backend", default=None,
                    choices=("xla", "pallas", "pallas-interpret", "auto"),
                    help="lane-step kernel for batched static groups "
                         "(default: REPRO_LANE_BACKEND or xla) — lets a "
                         "--smoke leg A/B the Pallas kernel against the "
                         "one-hot XLA step without code edits; every "
                         "backend is bit-exact")
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="write a BENCH_*.json perf-trajectory artifact "
                         "(ftl_s/sim_s per phase + per-design speedups)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace-event JSON (Perfetto "
                         "loadable): per-transaction device timelines + "
                         "resource occupancy tracks AND harness "
                         "compile/dispatch/stream spans in one view; a "
                         "resource-utilization/conflict heatmap CSV lands "
                         "next to it.  Reconstructed from SimResult arrays "
                         "after the fact — figure CSVs stay byte-identical")
    args = ap.parse_args()
    if args.smoke and args.full:
        raise SystemExit("--smoke and --full are mutually exclusive")

    if args.trace_out:
        from repro import obs

        obs.enable_tracing(xc_sidecar=args.trace_out + ".xc.jsonl")
    from repro.obs import spans as obs_spans

    bench.FTL_ENGINE = args.ftl_engine
    if args.lane_backend is not None:
        sim.LANE_BACKEND = args.lane_backend
    if args.smoke:
        designs = _parse_designs(args.designs or ",".join(SMOKE_DESIGNS))
        workloads = SMOKE_WL
        n_req = args.n_req or N_REQ_SMOKE
        mixes = ["mix1"]
    else:
        designs = _parse_designs(args.designs)
        workloads = sorted(WORKLOADS) if args.full else QUICK_WL
        n_req = args.n_req or (None if args.full else N_REQ_QUICK)
        mixes = None if args.full else ["mix1", "mix5"]
    t0 = time.time()
    phases: dict[str, dict] = {}
    speedups = {}

    def want(name):
        if args.only is not None:  # explicit --only wins, also under --smoke
            return args.only in ALIASES.get(name, (name,))
        return not args.smoke or name in SMOKE_PHASES

    ALIASES = {"fig4_9_10_13": ("fig4", "fig9", "fig10", "fig13")}

    # ---- cross-phase compile prefetch (overlapped pipeline, DESIGN §2.2):
    # the planner knows every phase's request shapes up front, so the whole
    # preset's missing executables start compiling/loading NOW — the first
    # phase's two gating programs synchronously in-process, the rest on
    # the out-of-process compile server — while the early phases execute.
    # A hint only — a stale list just means the compile happens at first
    # use.
    pre = []
    if want("fig4_9_10_13"):
        pre += [RunRequest(wl, cfg, designs, n_req)
                for cfg in (perf_optimized(), cost_optimized())
                for wl in workloads]
    if not args.smoke:
        if want("fig11"):
            pre += [RunRequest(wl, perf_optimized(), designs, n_req)
                    for wl in FIG11_WLS]
        if want("fig12"):
            pre += [RunRequest(mix, perf_optimized(), designs, n_req)
                    for mix in (mixes or sorted(MIXES))]
        if want("fig15"):
            d15 = tuple(d for d in designs if d != "pnssd")
            pre += [RunRequest(wl, perf_optimized(rows=r, cols=c), d15,
                               n_req)
                    for (r, c) in FIG15_MESHES for wl in FIG15_WLS]
    # the QoS phase's small-lane programs (quick/full tail only: the smoke
    # tail runs one lane per feedback round, below every layout window)
    extra = (prewarm_small_keys(perf_optimized(), 2048)
             if want("tail") and not args.smoke else [])
    if pre or extra:
        precompile(pre, extra_keys=extra)

    def phase(name, fn, *a, **kw):
        t = time.time()
        f0, s0 = bench.PERF["ftl_s"], bench.PERF["sim_s"]
        c0, e0 = bench.PERF["compile_s"], bench.PERF["exec_s"]
        l0, g0 = bench.PERF["lanes"], len(bench.PERF["groups"])
        w0, o0 = bench.PERF["compile_wait_s"], bench.PERF["compile_overlap_s"]
        bench.PERF["phase"] = name  # run-cache provenance (bench.WorkloadRun)
        try:
            with obs_spans.span("phase", name):
                out = fn(*a, **kw)
        finally:
            bench.PERF["phase"] = None
        cache = bench.PERF["phase_cache"].get(name, {})
        phases[name] = {
            "s": round(time.time() - t, 2),
            "ftl_s": round(bench.PERF["ftl_s"] - f0, 3),
            "sim_s": round(bench.PERF["sim_s"] - s0, 3),
            "compile_s": round(bench.PERF["compile_s"] - c0, 3),
            "exec_s": round(bench.PERF["exec_s"] - e0, 3),
            "compile_wait_s": round(bench.PERF["compile_wait_s"] - w0, 3),
            "compile_overlap_s": round(
                bench.PERF["compile_overlap_s"] - o0, 3),
            "lanes": bench.PERF["lanes"] - l0,
            "groups": len(bench.PERF["groups"]) - g0,
            # a fully-cached phase used to report s=0/lanes=0 as if it
            # hadn't run at all; these two fields distinguish "free" (runs
            # served from the cache, with the phase that paid for them)
            # from "not run"
            "cache_hits": cache.get("hits", 0),
            "cache_from": cache.get("from", {}),
        }
        return out

    if want("fig4_9_10_13"):
        speedups = phase("fig4_9_10_13", fig4_and_9_and_10_and_13,
                         workloads, n_req, args.csv, designs)
    if want("fig11"):
        phase("fig11", fig11_tail_latency, n_req, args.csv, designs)
    if want("fig12"):
        phase("fig12", fig12_mixes, n_req, args.csv, designs, mixes)
    if want("fig14"):
        phase("fig14", fig14_power_energy, workloads[:4], n_req, args.csv,
              designs)
    if want("fig15"):
        phase("fig15", fig15_sensitivity, n_req, args.csv, designs)
    tail_records = []
    if want("tail"):
        tail_records = phase("tail", tail_qos, n_req, args.csv, designs,
                             smoke=args.smoke)
    stream_record = None
    if want("stream"):
        stream_record = phase("stream", stream_replay, args.csv, designs,
                              smoke=args.smoke)
    fault_record = None
    if want("faults"):
        fault_record = phase("faults", fault_degradation, args.csv, designs,
                             smoke=args.smoke)
    if want("tab4"):
        phase("tab4", tab4_overheads, args.csv)
    if want("sec31"):
        phase("sec31", sec31_example, args.csv)
    total = round(time.time() - t0, 2)
    ftl_total = round(bench.PERF["ftl_s"], 3)
    sim_total = round(bench.PERF["sim_s"], 3)
    print(f"[benchmarks] total {total}s (ftl {ftl_total}s, sim {sim_total}s, "
          f"engine={args.ftl_engine}); CSVs in {args.csv}/")

    if args.json is not None:
        from repro.ssd import exec_cache

        exec_cache.flush()  # queued stores land before telemetry export
        bench.PERF.update({f"xc_{k}": v for k, v in
                           exec_cache.STATS.items()})
        path = args.json or os.path.join(
            args.csv, f"BENCH_{time.strftime('%Y%m%d_%H%M%S')}.json"
        )
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        artifact = {
            "preset": ("smoke" if args.smoke
                       else "full" if args.full else "quick"),
            "generated_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "only": args.only,
            "n_req": n_req,
            "designs": list(designs),
            "workloads": workloads,
            "ftl_engine": args.ftl_engine,
            "phases": phases,
            "ftl_s_total": ftl_total,
            "sim_s_total": sim_total,
            "cache": {k: bench.PERF[k] for k in
                      ("decomp_hits", "decomp_misses", "run_hits",
                       "run_subset_hits", "run_misses", "run_prefetched")},
            # warm-path backend: persistent-executable store telemetry and
            # the overlapped compile/execute pipeline split
            "exec_cache": {
                "hits": bench.PERF["xc_hits"],
                "misses": bench.PERF["xc_misses"],
                "errors": bench.PERF["xc_errors"],
                "stores": bench.PERF["xc_stores"],
                "tombstones": bench.PERF["xc_tombstones"],
                "load_s": round(bench.PERF["xc_load_s"], 3),
                "dir": os.environ.get("REPRO_XC_DIR", ""),
            },
            "compile_overlap_s": round(
                bench.PERF["compile_overlap_s"], 3),
            "compile_wait_s": round(bench.PERF["compile_wait_s"], 3),
            # sweep-planner attribution: lane/step counts, devices, and the
            # per-group compile-vs-execute split (satellite: make the
            # speedup attributable)
            "lanes": bench.PERF["lanes"],
            "scan_steps": {
                "valid": bench.PERF["scan_steps_valid"],
                "padded": bench.PERF["scan_steps_padded"],
            },
            "devices_used": bench.PERF["devices_used"],
            "compile_s_total": round(bench.PERF["compile_s"], 3),
            "exec_s_total": round(bench.PERF["exec_s"], 3),
            "groups": bench.PERF["groups"],
            # kernel-dispatch split: which lane-step kernel each group ran
            # (xla / pallas-interpret / pallas-compiled) and the share of
            # lane-steps served by the batched runners — static and scout
            # lanes tallied separately (the scout split is ISSUE 10's
            # figure of merit)
            "kernel_dispatch": {
                "lane_backend": sim.resolve_lane_backend(),
                "planner_profile": sweep_plan.planner_profile(),
                "backends": bench.PERF["kernel_backends"],
                "steps_batched": bench.PERF["steps_batched"],
                "steps_unbatched": bench.PERF["steps_unbatched"],
                "batched_share": round(
                    bench.PERF["steps_batched"]
                    / max(bench.PERF["steps_batched"]
                          + bench.PERF["steps_unbatched"], 1), 4),
                "steps_scout_batched": bench.PERF["steps_scout_batched"],
                "steps_scout_unbatched":
                    bench.PERF["steps_scout_unbatched"],
                "scout_batched_share": round(
                    bench.PERF["steps_scout_batched"]
                    / max(bench.PERF["steps_scout_batched"]
                          + bench.PERF["steps_scout_unbatched"], 1), 4),
            },
            # accelerated-replay audit: per-(workload, config) scale factor
            # and offered utilization (satellite — previously dropped)
            "accel": bench.PERF["accel"],
            # QoS surface: per-design p50/p95/p99 + per-tenant fairness
            # from the tail phase's scenarios
            "tail": tail_records,
            # self-healing compile pipeline + store health (ISSUE 8): the
            # persistent-store counters again (including tombstones and
            # version-skew-induced misses) next to the compile-server
            # watchdog's trip/fallback accounting
            "xc_health": {
                **{k: int(exec_cache.STATS[k]) for k in
                   ("hits", "misses", "errors", "stores", "tombstones")},
                "watchdog_trips": bench.PERF["xc_watchdog_trips"],
                "watchdog_fallbacks": bench.PERF["xc_watchdog_fallbacks"],
                "watchdog_reason": bench.PERF["xc_watchdog_reason"],
            },
            # degraded-mode fault sweep: per-design throughput retention
            # under growing per-channel link faults
            "faults": fault_record,
            # streaming engine: per-window throughput of the beyond-budget
            # replay (acceptance: flat, compile_wait ~0 after window 1)
            "stream": stream_record,
            "stream_windows": bench.PERF["stream_windows"],
            "stream_prep_s": round(bench.PERF["stream_prep_s"], 3),
            "total_s": total,
            "speedups_geomean": {
                cfg: {d: round(v, 4) for d, v in per.items()}
                for cfg, per in speedups.items()
            },
        }
        with open(path, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"[benchmarks] perf trajectory written to {path}")

    if args.trace_out:
        from repro import obs

        heat = os.path.splitext(args.trace_out)[0] + ".heatmap.csv"
        info = obs.export_trace(args.trace_out, heatmap_csv=heat)
        print(f"[benchmarks] trace written to {args.trace_out} "
              f"({info['n_events']} events, {info['n_txn']} transactions); "
              f"heatmap in {heat}")


if __name__ == "__main__":
    main()
