"""Perf-trajectory report across committed BENCH_*.json artifacts.

The repo's perf history lives in ``results/BENCH_*.json`` (one artifact
per landed optimization, written by ``benchmarks/run.py --json``), but the
trajectory itself was only recorded implicitly in CHANGES.md prose.  This
tool prints it as a table — total/ftl/sim/compile/exec seconds plus the
per-phase wall-clock — ordered by generation time, and writes
``results/TRAJECTORY.md`` (uploaded as a CI artifact).

Ordering: artifacts carry ``generated_at`` since the warm-path PR; older
ones fall back to file mtime, then name (which happens to sort the
pre-existing artifacts in landing order).  Presets are reported in
separate tables — a --smoke probe and a quick run are not comparable.

  PYTHONPATH=src python -m benchmarks.trajectory [--results results]
      [--out results/TRAJECTORY.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PHASE_ORDER = ("fig4_9_10_13", "fig11", "fig12", "fig14", "fig15", "tail",
               "stream", "tab4", "sec31")


def load_artifacts(results_dir: str) -> list:
    arts = []
    for path in glob.glob(os.path.join(results_dir, "BENCH_*.json")):
        try:
            with open(path) as f:
                art = json.load(f)
        except (OSError, ValueError) as e:
            print(f"[trajectory] skipping {path}: {e}")
            continue
        name = os.path.basename(path)
        key = (art.get("generated_at") or "", os.path.getmtime(path), name)
        arts.append((key, name, art))
    arts.sort(key=lambda t: t[0])
    return [(name, art) for _, name, art in arts]


def _fmt(v, nd=1):
    if v is None:
        return "-"
    return f"{float(v):.{nd}f}"


def _kernel_split(art: dict) -> tuple:
    """("batched%", "scout%", "backend") cells from the
    ``kernel_dispatch`` block; pre-PR-7 artifacts lack the block and
    pre-PR-10 ones the scout split — both render as "-"."""
    kd = art.get("kernel_dispatch")
    if not kd:
        return "-", "-", "-"
    share = kd.get("batched_share")
    share_s = "-" if share is None else f"{100.0 * float(share):.0f}%"
    sshare = kd.get("scout_batched_share")
    sshare_s = "-" if sshare is None else f"{100.0 * float(sshare):.0f}%"
    backends = kd.get("backends") or {}
    be_s = ("-" if not backends else
            " ".join(f"{k}:{v}" for k, v in sorted(backends.items())))
    return share_s, sshare_s, be_s


def rows_for(arts: list) -> tuple:
    """(header, rows) of the trajectory table for one preset's artifacts."""
    phases = [p for p in PHASE_ORDER
              if any(p in (a.get("phases") or {}) for _, a in arts)]
    header = (["artifact", "total_s", "ftl_s", "sim_s", "compile_s",
               "exec_s", "cwait_s", "covl_s", "groups", "cache_hits(xc)",
               "batched%", "scout%", "kernels"]
              + [f"{p}_s" for p in phases])
    rows = []
    for name, art in arts:
        ph = art.get("phases") or {}
        xc = art.get("exec_cache") or {}
        groups = art.get("groups")
        share_s, sshare_s, be_s = _kernel_split(art)
        rows.append(
            [name.replace("BENCH_", "").replace(".json", ""),
             _fmt(art.get("total_s")), _fmt(art.get("ftl_s_total"), 2),
             _fmt(art.get("sim_s_total")),
             _fmt(art.get("compile_s_total"), 2),
             _fmt(art.get("exec_s_total"), 2),
             _fmt(art.get("compile_wait_s"), 2),
             _fmt(art.get("compile_overlap_s"), 2),
             str(len(groups)) if isinstance(groups, list) else "-",
             str(xc.get("hits", "-")), share_s, sshare_s, be_s]
            + [_fmt((ph.get(p) or {}).get("s")) for p in phases]
        )
    return header, rows


def render(results_dir: str) -> str:
    arts = load_artifacts(results_dir)
    by_preset: dict = {}
    for name, art in arts:
        by_preset.setdefault(art.get("preset") or "?", []).append(
            (name, art))
    lines = ["# Perf trajectory (committed BENCH_*.json artifacts)", ""]
    lines.append("Regenerate: `PYTHONPATH=src python -m "
                 "benchmarks.trajectory`.  Ordering: `generated_at`, then "
                 "file mtime, then name.  Wall-clock fields are seconds; "
                 "`cache_hits(xc)` counts executables served from the "
                 "persistent AOT store (warm runs); `cwait_s`/`covl_s` split "
                 "background compilation into dispatcher stall vs time "
                 "hidden behind execution; `batched%` is the share "
                 "of static lane-steps run by the batched static step, "
                 "`scout%` the share of scout lane-steps run by the batched "
                 "scout runner, and `kernels` the per-backend group counts "
                 "(xla / pallas-interpret / pallas-compiled).")
    for preset in sorted(by_preset):
        header, rows = rows_for(by_preset[preset])
        lines += ["", f"## preset: {preset}", ""]
        lines.append("| " + " | ".join(header) + " |")
        lines.append("|" + "---|" * len(header))
        for r in rows:
            lines.append("| " + " | ".join(r) + " |")
        # streaming-engine acceptance surface: per-window simulated-IOs per
        # wall-clock second of the beyond-budget replay (must stay flat)
        for name, art in by_preset[preset]:
            stream = art.get("stream") or {}
            wins = [w for w in stream.get("windows", [])
                    if w.get("n_requests")]
            if wins:
                per = " ".join(f"w{w['window']}={_fmt(w['ios_per_wallclock_s'], 0)}"
                               for w in wins)
                lines.append(
                    f"- `{name.replace('BENCH_', '').replace('.json', '')}` "
                    f"stream IO/s per window: {per} (flatness "
                    f"{_fmt(stream.get('throughput_flatness'), 2)})")
    return "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results")
    ap.add_argument("--out", default=None,
                    help="markdown output path (default "
                         "<results>/TRAJECTORY.md); '-' = stdout only")
    args = ap.parse_args()
    md = render(args.results)
    print(md)
    out = args.out or os.path.join(args.results, "TRAJECTORY.md")
    if out != "-":
        with open(out, "w") as f:
            f.write(md)
        print(f"[trajectory] written to {out}")


if __name__ == "__main__":
    main()
