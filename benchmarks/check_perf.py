"""Non-blocking perf-regression probe for the CI fast lane.

Compares a fresh ``--smoke`` BENCH_*.json against the committed baseline
and prints a GitHub Actions ``::warning::`` annotation when ``total_s``
regresses by more than the threshold.  Also checks the streaming-engine
leg's per-window throughput within the fresh run: the last window dropping
more than the threshold below the first means window prep/compile stopped
overlapping execution.  Always exits 0: CI runner timing is
noisy (shared vCPUs), so this is a tripwire for humans, not a gate — real
perf acceptance happens on the committed quick-preset BENCH artifacts.

  python -m benchmarks.check_perf results/BENCH_smoke.json \
      results/BENCH_smoke_baseline.json [--threshold 0.30]
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="BENCH_*.json from this CI run")
    ap.add_argument("baseline", help="committed baseline BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="warn when total_s exceeds baseline by this "
                         "fraction (default 0.30)")
    args = ap.parse_args()

    # a tripwire must never trip the lane itself: any surprise (missing
    # file, renamed field, null value) degrades to a warning, not a failure
    try:
        with open(args.fresh) as f:
            fresh = json.load(f)
        with open(args.baseline) as f:
            base = json.load(f)
        if fresh.get("preset") != base.get("preset"):
            print(f"::warning::perf probe skipped: preset mismatch "
                  f"({fresh.get('preset')} vs baseline "
                  f"{base.get('preset')})")
            return
        t_new, t_old = float(fresh["total_s"]), float(base["total_s"])
        ratio = t_new / max(t_old, 1e-9)
        detail = (
            f"total {t_new:.1f}s vs baseline {t_old:.1f}s ({ratio:.2f}x); "
            f"sim {fresh.get('sim_s_total')}s vs {base.get('sim_s_total')}s, "
            f"ftl {fresh.get('ftl_s_total')}s vs {base.get('ftl_s_total')}s, "
            f"compile {fresh.get('compile_s_total')}s vs "
            f"{base.get('compile_s_total')}s"
        )
        # per-phase breakdown: phases are compared only when BOTH runs have
        # them, so a baseline predating a new phase (e.g. ``tail``) never
        # trips the probe — new phases are reported informationally and
        # start being compared once the baseline is regenerated
        ph_new = fresh.get("phases") or {}
        ph_old = base.get("phases") or {}
        for name in ph_new.keys() - ph_old.keys():
            print(f"[check_perf] phase '{name}' "
                  f"({ph_new[name].get('s')}s) not in baseline — skipped")
        for name in sorted(ph_new.keys() & ph_old.keys()):
            s_new = float(ph_new[name].get("s", 0.0) or 0.0)
            s_old = float(ph_old[name].get("s", 0.0) or 0.0)
            if s_old >= 1.0 and s_new > s_old * (1.0 + args.threshold):
                print(f"::warning title=bench --smoke phase regression::"
                      f"{name}: {s_new:.1f}s vs baseline {s_old:.1f}s")
        # streaming engine flatness (within the fresh run, no baseline
        # needed): prep/compile are supposed to hide behind execution, so
        # a last window markedly slower than steady state means the
        # pipeline stopped overlapping.  The first nonempty window is
        # warm-up (one-time executable load) and is skipped.
        wins = [w for w in (fresh.get("stream") or {}).get("windows", [])
                if w.get("n_requests")]
        if len(wins) > 2:
            wins = wins[1:]  # drop warm-up
        if len(wins) >= 2:
            tp_first = float(wins[0]["ios_per_wallclock_s"])
            tp_last = float(wins[-1]["ios_per_wallclock_s"])
            if tp_first > 0 and tp_last < tp_first * (1.0 - args.threshold):
                print(f"::warning title=stream throughput droop::last "
                      f"window {tp_last:.0f} IO/s vs steady-state window "
                      f"{tp_first:.0f} IO/s "
                      f"({tp_last / tp_first:.2f}x, threshold "
                      f"{1.0 - args.threshold:.2f}x)")
    except Exception as e:  # noqa: BLE001
        print(f"::warning::perf probe skipped: {type(e).__name__}: {e}")
        return
    if ratio > 1.0 + args.threshold:
        print(f"::warning title=bench --smoke regression::{detail}")
    else:
        print(f"[check_perf] OK: {detail}")


if __name__ == "__main__":
    main()
    sys.exit(0)
