"""Perf-regression probe for the CI fast lane.

Compares a fresh ``--smoke`` BENCH_*.json against the committed baseline
and prints a GitHub Actions ``::warning::`` annotation when ``total_s``
regresses by more than the threshold.  Also checks the streaming-engine
leg's per-window throughput within the fresh run: the last window dropping
more than the threshold below the first means window prep/compile stopped
overlapping execution.

By default the probe is **fail-open** — always exits 0: CI runner timing
is noisy (shared vCPUs), so it is a tripwire for humans, not a gate — real
perf acceptance happens on the committed quick-preset BENCH artifacts.
``--strict`` turns it into a gate: exit 1 when any regression tripped,
exit 2 when the probe could not evaluate (missing file, preset mismatch,
schema drift).  Either way a machine-readable
``check_perf_summary.json`` lands next to the fresh artifact with the
status, every finding, and the numbers behind them.

  python -m benchmarks.check_perf results/BENCH_smoke.json \
      results/BENCH_smoke_baseline.json [--threshold 0.30] [--strict]
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _compare(fresh: dict, base: dict, threshold: float) -> dict:
    """Pure comparison: {status, findings, totals} — no I/O, unit-testable."""
    findings = []
    summary: dict = {"status": "ok", "findings": findings}
    if fresh.get("preset") != base.get("preset"):
        summary["status"] = "skipped"
        summary["reason"] = (f"preset mismatch ({fresh.get('preset')} vs "
                             f"baseline {base.get('preset')})")
        return summary
    t_new, t_old = float(fresh["total_s"]), float(base["total_s"])
    ratio = t_new / max(t_old, 1e-9)
    summary["total_s"] = {"fresh": t_new, "baseline": t_old,
                          "ratio": round(ratio, 4)}
    if ratio > 1.0 + threshold:
        findings.append({
            "kind": "total_regression",
            "detail": (f"total {t_new:.1f}s vs baseline {t_old:.1f}s "
                       f"({ratio:.2f}x)"),
            "fresh_s": t_new, "baseline_s": t_old,
        })
    # per-phase breakdown: phases are compared only when BOTH runs have
    # them, so a baseline predating a new phase (e.g. ``tail``) never
    # trips the probe — new phases are reported informationally and
    # start being compared once the baseline is regenerated
    ph_new = fresh.get("phases") or {}
    ph_old = base.get("phases") or {}
    summary["phases_not_in_baseline"] = sorted(ph_new.keys() - ph_old.keys())
    for name in sorted(ph_new.keys() & ph_old.keys()):
        s_new = float(ph_new[name].get("s", 0.0) or 0.0)
        s_old = float(ph_old[name].get("s", 0.0) or 0.0)
        if s_old >= 1.0 and s_new > s_old * (1.0 + threshold):
            findings.append({
                "kind": "phase_regression", "phase": name,
                "detail": f"{name}: {s_new:.1f}s vs baseline {s_old:.1f}s",
                "fresh_s": s_new, "baseline_s": s_old,
            })
    # streaming engine flatness (within the fresh run, no baseline
    # needed): prep/compile are supposed to hide behind execution, so
    # a last window markedly slower than steady state means the
    # pipeline stopped overlapping.  The first nonempty window is
    # warm-up (one-time executable load) and is skipped.
    wins = [w for w in (fresh.get("stream") or {}).get("windows", [])
            if w.get("n_requests")]
    if len(wins) > 2:
        wins = wins[1:]  # drop warm-up
    if len(wins) >= 2:
        tp_first = float(wins[0]["ios_per_wallclock_s"])
        tp_last = float(wins[-1]["ios_per_wallclock_s"])
        summary["stream"] = {"steady_ios_s": tp_first, "last_ios_s": tp_last}
        if tp_first > 0 and tp_last < tp_first * (1.0 - threshold):
            findings.append({
                "kind": "stream_droop",
                "detail": (f"last window {tp_last:.0f} IO/s vs steady-state "
                           f"window {tp_first:.0f} IO/s "
                           f"({tp_last / tp_first:.2f}x)"),
                "steady_ios_s": tp_first, "last_ios_s": tp_last,
            })
    if findings:
        summary["status"] = "regressed"
    return summary


def _write_summary(fresh_path: str, summary: dict) -> None:
    """Best-effort ``check_perf_summary.json`` next to the fresh artifact."""
    out = os.path.join(os.path.dirname(fresh_path) or ".",
                       "check_perf_summary.json")
    try:
        with open(out, "w") as f:
            json.dump(summary, f, indent=2)
        print(f"[check_perf] summary written to {out}")
    except OSError as e:
        print(f"::warning::check_perf summary not written: {e}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="BENCH_*.json from this CI run")
    ap.add_argument("baseline", help="committed baseline BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="warn when total_s exceeds baseline by this "
                         "fraction (default 0.30)")
    ap.add_argument("--strict", action="store_true",
                    help="gate mode: exit 1 on any regression, 2 when the "
                         "probe could not evaluate (default: warn-only, "
                         "always exit 0)")
    args = ap.parse_args(argv)

    # in the default mode a tripwire must never trip the lane itself: any
    # surprise (missing file, renamed field, null value) degrades to a
    # warning — --strict upgrades both regressions and surprises to
    # nonzero exits
    try:
        with open(args.fresh) as f:
            fresh = json.load(f)
        with open(args.baseline) as f:
            base = json.load(f)
        summary = _compare(fresh, base, args.threshold)
    except Exception as e:  # noqa: BLE001
        summary = {"status": "skipped",
                   "reason": f"{type(e).__name__}: {e}", "findings": []}
    summary["threshold"] = args.threshold
    summary["strict"] = bool(args.strict)
    _write_summary(args.fresh, summary)

    if summary["status"] == "skipped":
        print(f"::warning::perf probe skipped: {summary.get('reason')}")
        return 2 if args.strict else 0
    for fnd in summary["findings"]:
        title = {"total_regression": "bench --smoke regression",
                 "phase_regression": "bench --smoke phase regression",
                 "stream_droop": "stream throughput droop"}[fnd["kind"]]
        print(f"::warning title={title}::{fnd['detail']}")
    for name in summary.get("phases_not_in_baseline", []):
        print(f"[check_perf] phase '{name}' not in baseline — skipped")
    if summary["status"] == "ok":
        t = summary["total_s"]
        print(f"[check_perf] OK: total {t['fresh']:.1f}s vs baseline "
              f"{t['baseline']:.1f}s ({t['ratio']:.2f}x)")
        return 0
    return 1 if args.strict else 0


if __name__ == "__main__":
    sys.exit(main())
