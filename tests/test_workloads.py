"""Workloads subsystem: ingestion, characterization, registry round trips."""
import os

import numpy as np
import pytest

from repro.traces.generator import (
    CUSTOM_TRACES,
    WORKLOADS,
    WorkloadStats,
    gen_trace,
    trace_for,
)
from repro.workloads import (
    characterize,
    compact_footprint,
    ingest_file,
    iter_trace_csv,
    load_trace,
    register_workload,
    sniff_format,
    write_msr_csv,
)

FIXTURE = os.path.join(os.path.dirname(__file__), "data", "msr_sample.csv")


@pytest.fixture(autouse=True)
def _clean_registries():
    yield
    for k in [k for k in CUSTOM_TRACES if k.startswith("test_")]:
        del CUSTOM_TRACES[k]
    for k in [k for k in WORKLOADS if k.startswith("test_")]:
        del WORKLOADS[k]


class TestIngestion:
    def test_fixture_sniffs_as_msr(self):
        assert sniff_format(FIXTURE) == "msr"

    def test_streamed_and_whole_file_paths_identical(self):
        whole = load_trace(FIXTURE, compact=False)
        for batch in (1, 7, 64, 100000):
            batches = list(iter_trace_csv(FIXTURE, batch_requests=batch))
            assert sum(len(b["arrival_us"]) for b in batches) \
                == len(whole["arrival_us"])
            streamed_off = np.concatenate(
                [b["offset_bytes"] for b in batches])
            assert np.array_equal(streamed_off, whole["offset_bytes"])
            streamed_ts = np.concatenate([b["arrival_us"] for b in batches])
            assert np.array_equal(streamed_ts - streamed_ts[0],
                                  whole["arrival_us"])
        # memory bound: a small batch size yields many small batches
        assert len(list(iter_trace_csv(FIXTURE, batch_requests=50))) == 12

    def test_msr_fields_parse(self, tmp_path):
        p = tmp_path / "mini.csv"
        p.write_text(
            "128166372003061629,srv,0,Write,4096,8192,80311\n"
            "128166372003071629,srv,0,Read,0,4096,151687\n"
        )
        tr = load_trace(str(p), compact=False)
        assert np.array_equal(tr["is_read"], [False, True])
        assert np.array_equal(tr["offset_bytes"], [4096, 0])
        assert np.array_equal(tr["size_bytes"], [8192, 4096])
        # FILETIME 100ns ticks -> us, rebased to 0
        assert tr["arrival_us"] == pytest.approx([0.0, 1000.0])

    def test_blktrace_fields_parse(self, tmp_path):
        p = tmp_path / "blk.csv"
        p.write_text(
            "time_s,op,sector,nsectors\n"  # header skipped
            "0.001,WS,8,16\n"
            "0.002,R,0,8\n"
        )
        assert sniff_format(str(p)) == "blktrace"
        tr = load_trace(str(p), compact=False)
        assert np.array_equal(tr["is_read"], [False, True])
        assert np.array_equal(tr["offset_bytes"], [8 * 512, 0])
        assert np.array_equal(tr["size_bytes"], [16 * 512, 8 * 512])
        assert tr["arrival_us"] == pytest.approx([0.0, 1000.0])

    def test_compaction_preserves_structure(self):
        # two extents separated by a 1 GB hole; sequential pair inside one
        tr = {
            "name": "t",
            "arrival_us": np.arange(4, dtype=np.float64),
            "is_read": np.ones(4, bool),
            "offset_bytes": np.array(
                [0, 4096, (1 << 30), (1 << 30) + 100], np.int64),
            "size_bytes": np.array([4096, 4096, 100, 4096], np.int64),
            "footprint_bytes": (1 << 30) + 8192,
        }
        out = compact_footprint(tr)
        off = out["offset_bytes"]
        # adjacency inside extents survives; the hole is gone
        assert off[1] - off[0] == 4096  # still sequential
        assert off[3] - off[2] == 100  # intra-page remainder kept
        assert out["footprint_bytes"] == 4 * 4096  # 2 + 2 covered pages
        assert (off + out["size_bytes"] <= out["footprint_bytes"]).all()

    def test_fixture_compaction_drops_the_hole(self):
        raw = load_trace(FIXTURE, compact=False)
        dense = load_trace(FIXTURE)
        assert raw["footprint_bytes"] > (1 << 30)  # sparse on the wire
        assert dense["footprint_bytes"] < (16 << 20)  # dense after ingest
        assert np.array_equal(raw["size_bytes"], dense["size_bytes"])
        assert np.array_equal(raw["is_read"], dense["is_read"])

    def test_msr_writer_round_trips(self, tmp_path):
        tr = gen_trace("wdev_0", 120, seed=9)
        p = tmp_path / "rt.csv"
        write_msr_csv(tr, str(p))
        back = load_trace(str(p), compact=False)
        assert np.array_equal(back["offset_bytes"], tr["offset_bytes"])
        assert np.array_equal(back["size_bytes"], tr["size_bytes"])
        assert np.array_equal(back["is_read"], tr["is_read"])
        assert back["arrival_us"] == pytest.approx(
            tr["arrival_us"] - tr["arrival_us"][0], abs=0.2  # 0.1us ticks
        )

    def test_ingest_file_registers_for_replay(self):
        name = ingest_file(FIXTURE, name="test_fixture")
        assert name == "test_fixture"
        tr = trace_for(name, 50)
        assert len(tr["arrival_us"]) == 50  # sliced view
        full = trace_for(name, None)
        assert len(full["arrival_us"]) == 600

    def test_beyond_budget_traces_register_as_streaming_only(self):
        """Arrivals past the int32 tick budget (~21 s) register fine, but
        tagged streaming-only: a *monolithic* replay of the full span (which
        would wrap the transaction arrays negative) must refuse and point at
        the streaming path; a prefix that fits the budget, or any consumer
        that opted into streaming, goes through."""
        from repro.traces.generator import register_trace

        week = {
            "name": "test_week",
            "arrival_us": np.array([0.0, 1.0, 7 * 86400e6]),  # a week apart
            "is_read": np.ones(3, bool),
            "offset_bytes": np.zeros(3, np.int64),
            "size_bytes": np.full(3, 4096, np.int64),
            "footprint_bytes": 1 << 20,
        }
        register_trace("test_week", week)
        assert CUSTOM_TRACES["test_week"]["streaming_only"] is True
        with pytest.raises(ValueError, match="tick budget") as ei:
            trace_for("test_week", None)
        # the error must route users to the streaming engine, not dead-end
        assert "stream_simulate" in str(ei.value)
        # a fitting prefix is an ordinary monolithic replay
        prefix = trace_for("test_week", 2)
        assert len(prefix["arrival_us"]) == 2
        # streaming consumers opt out of the span check entirely
        full = trace_for("test_week", None, monolithic=False)
        assert len(full["arrival_us"]) == 3

    def test_windowed_ingest_covers_the_trace(self):
        """iter_trace_windows cuts the stream into contiguous tick-rebased
        windows: indices dense (empty interior windows included), rebased
        ticks within the window span, absolute ticks reassembling to the
        whole-file ingest."""
        from repro.workloads import arrival_ticks_i64, iter_trace_windows

        whole = load_trace(FIXTURE, compact=False)
        t_abs = arrival_ticks_i64(whole["arrival_us"])
        span_s = float(whole["arrival_us"][-1]) * 1e-6
        wins = list(iter_trace_windows(FIXTURE, window_s=span_s / 5,
                                       batch_requests=64))
        assert [w["window_index"] for w in wins] == list(range(len(wins)))
        assert len(wins) >= 5
        W = wins[1]["base_ticks"] - wins[0]["base_ticks"]
        rebuilt = np.concatenate(
            [w["arrival_ticks"] + w["base_ticks"] for w in wins])
        assert np.array_equal(rebuilt, t_abs)
        for w in wins:
            if len(w["arrival_ticks"]):
                assert 0 <= w["arrival_ticks"][0]
                assert w["arrival_ticks"][-1] < W
        off = np.concatenate([w["offset_bytes"] for w in wins])
        assert np.array_equal(off, whole["offset_bytes"])

    def test_gzip_csv_pinned_to_uncompressed(self, tmp_path):
        """A .csv.gz ingests identically to the uncompressed file — format
        sniffing, streamed batches, and the registered trace all pinned."""
        import gzip
        import shutil

        gz = tmp_path / "msr_sample.csv.gz"
        with open(FIXTURE, "rb") as src, gzip.open(gz, "wb") as dst:
            shutil.copyfileobj(src, dst)
        assert sniff_format(str(gz)) == "msr"
        plain = load_trace(FIXTURE)
        zipped = load_trace(str(gz))
        assert zipped["name"] == "msr_sample"  # .gz stripped from the stem
        for k in ("arrival_us", "is_read", "offset_bytes", "size_bytes"):
            assert np.array_equal(plain[k], zipped[k]), k
        assert plain["footprint_bytes"] == zipped["footprint_bytes"]
        whole = load_trace(FIXTURE, compact=False)
        batches = list(iter_trace_csv(str(gz), batch_requests=64))
        streamed_off = np.concatenate([b["offset_bytes"] for b in batches])
        assert np.array_equal(streamed_off, whole["offset_bytes"])


class TestCharacterize:
    def test_round_trip_recovers_stats(self):
        stats = WorkloadStats(read_pct=35, avg_kb=12.0, avg_iat_us=90.0)
        tr = gen_trace("test_rt", 12000, seed=4, stats=stats)
        prof = characterize(tr)
        assert prof.stats.read_pct == pytest.approx(35, abs=2.0)
        assert prof.stats.avg_kb == pytest.approx(12.0, rel=0.05)
        assert prof.stats.avg_iat_us == pytest.approx(90.0, rel=0.05)
        assert prof.n_requests == 12000
        assert prof.footprint_bytes == tr["footprint_bytes"]

    @pytest.mark.parametrize("name", ["hm_0", "src2_1", "prxy_0"])
    def test_round_trip_on_table2_workloads(self, name):
        prof = characterize(gen_trace(name, 10000, seed=1), name=name)
        want = WORKLOADS[name]
        assert prof.stats.read_pct == pytest.approx(want.read_pct, abs=2.5)
        assert prof.stats.avg_kb == pytest.approx(want.avg_kb, rel=0.06)
        assert prof.stats.avg_iat_us == pytest.approx(
            want.avg_iat_us, rel=0.08)

    def test_sequentiality_metric_responds(self):
        seq = characterize(gen_trace("usr_0", 4000, seed=2, seq_frac=0.9,
                                     hot_weight=0.0))
        rnd = characterize(gen_trace("usr_0", 4000, seed=2, seq_frac=0.0,
                                     hot_weight=0.0))
        assert seq.seq_frac > rnd.seq_frac + 0.2

    def test_hot_metric_responds(self):
        hot = characterize(gen_trace("usr_0", 4000, seed=2, hot_weight=0.9))
        cold = characterize(gen_trace("usr_0", 4000, seed=2, hot_weight=0.0))
        assert hot.hot_frac > cold.hot_frac + 0.2

    def test_register_workload_feeds_generator(self):
        prof = characterize(load_trace(FIXTURE), name="test_msr")
        stats = register_workload("test_msr", prof)
        assert WORKLOADS["test_msr"] == stats
        tr = gen_trace("test_msr", 3000, seed=0,
                       **{k: v for k, v in prof.gen_kwargs().items()
                          if k != "stats"}, stats=prof.stats)
        refit = characterize(tr)
        assert refit.stats.avg_kb == pytest.approx(stats.avg_kb, rel=0.06)
        assert refit.stats.read_pct == pytest.approx(stats.read_pct, abs=3.0)

    def test_empty_trace_rejected(self):
        with pytest.raises(ValueError):
            characterize({"arrival_us": np.zeros(0), "is_read": np.zeros(0),
                          "offset_bytes": np.zeros(0, np.int64),
                          "size_bytes": np.zeros(0, np.int64)})


class TestCorruptedRows:
    """``on_error``: strict by default, skip-and-count on request, and
    bit-identical to the strict path on clean input (ISSUE 8)."""

    @pytest.fixture()
    def corrupted(self, tmp_path):
        """The msr fixture with three corrupted rows spliced in: a
        truncated row, a non-numeric offset, and a garbage line."""
        lines = open(FIXTURE).read().splitlines()
        bad = ["129000000000000099,anon,0,Read,12345",         # 5 fields
               "129000000000000101,anon,0,Write,oops,4096,0",  # bad offset
               "129000000000000103,anon,0,Read,4096,huge,0"]   # bad size
        # (a line whose FIRST field is non-numeric reads as a header and
        # is silently skipped in both modes — deliberately not an error)
        spliced = lines[:5] + bad[:1] + lines[5:40] + bad[1:] + lines[40:]
        p = tmp_path / "corrupt.csv"
        p.write_text("\n".join(spliced) + "\n")
        return str(p)

    def test_raise_names_the_line(self, corrupted):
        with pytest.raises(ValueError, match=r"corrupt\.csv:6: corrupted"):
            load_trace(corrupted)
        with pytest.raises(ValueError):
            list(iter_trace_csv(corrupted))  # default is strict

    def test_skip_counts_and_keeps_good_rows(self, corrupted):
        clean = load_trace(FIXTURE, compact=False)
        tr = load_trace(corrupted, compact=False, on_error="skip")
        assert tr["skipped_rows"] == 3
        for k in ("arrival_us", "is_read", "offset_bytes", "size_bytes"):
            assert np.array_equal(tr[k], clean[k]), k
        stats = {}
        n = sum(len(b["arrival_us"]) for b in
                iter_trace_csv(corrupted, on_error="skip", stats=stats))
        assert stats["skipped_rows"] == 3
        assert n == len(clean["arrival_us"])

    def test_clean_input_identical_under_both_modes(self):
        strict = load_trace(FIXTURE)
        skip = load_trace(FIXTURE, on_error="skip")
        assert strict["skipped_rows"] == skip["skipped_rows"] == 0
        for k in ("arrival_us", "is_read", "offset_bytes", "size_bytes",
                  "footprint_bytes"):
            assert np.array_equal(strict[k], skip[k]), k

    def test_bad_mode_rejected(self):
        with pytest.raises(ValueError, match="on_error"):
            list(iter_trace_csv(FIXTURE, on_error="ignore"))

    def test_ingest_file_threads_on_error(self, corrupted):
        name = ingest_file(corrupted, name="test_corrupt",
                           on_error="skip")
        assert name == "test_corrupt"
        from repro.traces.generator import CUSTOM_TRACES
        assert len(CUSTOM_TRACES["test_corrupt"]["arrival_us"]) \
            == len(load_trace(FIXTURE)["arrival_us"])
