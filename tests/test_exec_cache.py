"""Warm-path executable cache: parity, fallback, and telemetry.

The load-bearing guarantees of the persistent AOT store
(``repro.ssd.exec_cache``):

* results served by deserialized executables are bit-identical to
  freshly-compiled ones (in-process and across processes);
* corrupted or version-mismatched entries degrade to a compile — never a
  crash — and the miss/error counters say so;
* the store is an optimization, not a dependency: disabling it changes
  nothing but wall-clock.
"""
import hashlib
import os
import pickle
import subprocess
import sys

import numpy as np
import pytest

from repro.ssd import bench, exec_cache, simulate_sweep
from repro.ssd import sim as S

PARITY_FIELDS = ("completion", "wait", "conflict", "hops", "tries",
                 "misroutes")
DESIGNS_MIX = ("baseline", "pnssd", "nossd", "venice")


def _digest(sweep) -> str:
    h = hashlib.sha1()
    for lane in sweep:
        for f in PARITY_FIELDS:
            h.update(np.ascontiguousarray(getattr(lane, f)).tobytes())
    return h.hexdigest()


@pytest.fixture()
def xc_dir(tmp_path, monkeypatch):
    """A fresh store for this test only (the session dir stays warm)."""
    d = str(tmp_path / "xc")
    monkeypatch.setenv("REPRO_XC_DIR", d)
    exec_cache.flush()  # other tests' queued stores keep out of STATS
    S.clear_exec_cache()
    exec_cache.reset_stats()
    yield d
    S.clear_exec_cache()
    exec_cache.reset_stats()


def test_store_roundtrip_bit_identical(tiny_cfg, tiny_txns, xc_dir):
    """cold compile+store -> drop in-process cache -> disk load: the
    loaded executables must reproduce every output bit.

    The store verifies each entry's round trip before committing and
    tombstones programs XLA:CPU cannot re-load (nondeterministic,
    process-state-dependent — see exec_cache), so the invariants are:
    every program either stored or tombstoned; every STORED program loads
    (hits == prior stores, zero errors); outputs bit-identical
    regardless."""
    cold = simulate_sweep(tiny_cfg, tiny_txns, DESIGNS_MIX, seeds=11)
    exec_cache.flush()
    stored = exec_cache.STATS["stores"]
    assert stored + exec_cache.STATS["tombstones"] > 0
    assert os.listdir(xc_dir)

    S.clear_exec_cache()  # force the disk path
    warm = simulate_sweep(tiny_cfg, tiny_txns, DESIGNS_MIX, seeds=11)
    assert exec_cache.STATS["hits"] == stored, exec_cache.STATS
    assert exec_cache.STATS["errors"] == 0, exec_cache.STATS
    assert _digest(cold) == _digest(warm)
    assert bench.PERF["xc_hits"] == exec_cache.STATS["hits"]


def test_corrupted_entries_fall_back_to_compile(tiny_cfg, tiny_txns,
                                                xc_dir):
    """Garbage payloads must count as errors and recompile, bit-exact."""
    ref = simulate_sweep(tiny_cfg, tiny_txns, DESIGNS_MIX, seeds=11)
    exec_cache.flush()
    entries = [os.path.join(xc_dir, f) for f in os.listdir(xc_dir)
               if f.endswith(".xc")]
    assert entries
    for path in entries:
        with open(path, "wb") as f:
            f.write(b"\x00garbage\xff" * 32)

    S.clear_exec_cache()
    exec_cache.reset_stats()
    again = simulate_sweep(tiny_cfg, tiny_txns, DESIGNS_MIX, seeds=11)
    assert _digest(again) == _digest(ref)
    assert exec_cache.STATS["errors"] > 0
    assert exec_cache.STATS["hits"] == 0
    # corrupted entries were tombstoned: the NEXT pass recompiles
    # deterministically (a miss, not another error)
    S.clear_exec_cache()
    exec_cache.reset_stats()
    third = simulate_sweep(tiny_cfg, tiny_txns, DESIGNS_MIX, seeds=11)
    assert _digest(third) == _digest(ref)
    assert exec_cache.STATS["errors"] == 0
    assert exec_cache.STATS["tombstones"] > 0


def test_version_salt_invalidates(tiny_cfg, tiny_txns, xc_dir,
                                  monkeypatch):
    """A changed version salt (stand-in for a jaxlib/XLA-flag/source
    change) must miss — never serve a stale executable."""
    simulate_sweep(tiny_cfg, tiny_txns, ("baseline",), seeds=1)
    exec_cache.flush()
    assert exec_cache.STATS["stores"] + exec_cache.STATS["tombstones"] > 0

    monkeypatch.setenv("REPRO_XC_SALT", "other-toolchain")
    exec_cache._version_salt.cache_clear()
    S.clear_exec_cache()
    exec_cache.reset_stats()
    simulate_sweep(tiny_cfg, tiny_txns, ("baseline",), seeds=1)
    exec_cache.flush()
    assert exec_cache.STATS["hits"] == 0
    assert exec_cache.STATS["misses"] > 0
    monkeypatch.delenv("REPRO_XC_SALT")
    exec_cache._version_salt.cache_clear()


def test_disabled_store_is_inert(tiny_cfg, tiny_txns, monkeypatch):
    monkeypatch.setenv("REPRO_XC_DIR", "")
    exec_cache.flush()
    S.clear_exec_cache()
    exec_cache.reset_stats()
    simulate_sweep(tiny_cfg, tiny_txns, ("baseline",), seeds=1)
    exec_cache.flush()
    assert exec_cache.STATS == {"hits": 0, "misses": 0, "errors": 0,
                                "stores": 0, "tombstones": 0}
    S.clear_exec_cache()


@pytest.mark.slow
def test_warm_subprocess_digest_and_speedup_parity(tmp_path):
    """Fresh process with an empty store vs fresh process with the
    populated store: identical digests AND identical speedups, with the
    warm run actually loading executables instead of compiling."""
    xc = str(tmp_path / "xc")
    script = r"""
import json, hashlib, sys
import numpy as np
from repro.ssd import bench, exec_cache, decompose_trace, perf_optimized, simulate_sweep
from repro.traces.generator import gen_trace, to_pages

cfg = perf_optimized(rows=2, cols=2, pages_per_block=64)
tr = gen_trace("src2_1", 60, seed=3)
tr = dict(tr); tr["arrival_us"] = tr["arrival_us"] / 16.0
pages = to_pages(tr, cfg.page_bytes)
txns = decompose_trace(cfg, pages, footprint_pages=int(pages["footprint_pages"]))
designs = ("baseline", "pssd", "venice", "ideal")
sweep = simulate_sweep(cfg, txns, designs, seeds=5)
h = hashlib.sha1()
for lane in sweep:
    for f in ("completion", "wait", "conflict", "hops", "tries", "misroutes"):
        h.update(np.ascontiguousarray(getattr(lane, f)).tobytes())
base = dict(zip(designs, sweep))
speedups = {d: base["baseline"].exec_ticks / max(base[d].exec_ticks, 1)
            for d in designs}
exec_cache.flush()
print("RESULT", json.dumps({
    "digest": h.hexdigest(), "speedups": speedups,
    "stats": exec_cache.STATS}))
"""
    env = dict(os.environ, REPRO_XC_DIR=xc, JAX_PLATFORMS="cpu")

    def run_once():
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, timeout=560)
        assert out.returncode == 0, out.stderr[-2000:]
        line = [l for l in out.stdout.splitlines()
                if l.startswith("RESULT")][0]
        import json

        return json.loads(line.split(" ", 1)[1])

    cold = run_once()
    warm = run_once()
    assert cold["digest"] == warm["digest"]
    assert cold["speedups"] == warm["speedups"]
    assert cold["stats"]["stores"] > 0 and cold["stats"]["hits"] == 0
    assert warm["stats"]["hits"] > 0 and warm["stats"]["stores"] == 0
    assert warm["stats"]["errors"] == 0


def test_entry_digest_covers_logical_key(xc_dir):
    k1 = ("lane", (2, 2, 2, 2, 1), 1024, 2, 1, False, (None,) * 12, 2)
    k2 = ("lane", (2, 2, 2, 2, 1), 1024, 2, 1, True, (None,) * 12, 2)
    assert exec_cache.entry_digest(k1) != exec_cache.entry_digest(k2)
    assert exec_cache.entry_digest(k1) == exec_cache.entry_digest(k1)


def test_stale_version_entry_degrades_to_compile(xc_dir, monkeypatch):
    """Version skew (ISSUE 8): an entry planted under a stale jaxlib
    salt is invisible to the current toolchain — a plain miss, never a
    crash; a stale payload sitting AT the current digest (salt collision
    / partial upgrade) errors exactly once, is tombstoned, and every
    later lookup takes the deterministic recompile path."""
    key = ("lane", "stale-jaxlib-probe")
    monkeypatch.setenv("REPRO_XC_SALT", "jaxlib=0.0.0-stale")
    exec_cache._version_salt.cache_clear()
    stale_path = exec_cache._entry_path(exec_cache.entry_digest(key))
    os.makedirs(xc_dir, exist_ok=True)
    blob = pickle.dumps(("not-an-executable", None, None))
    with open(stale_path, "wb") as f:
        f.write(blob)
    monkeypatch.delenv("REPRO_XC_SALT")
    exec_cache._version_salt.cache_clear()
    # the stale entry lives under a different digest: clean miss
    assert exec_cache.entry_digest(key) not in os.path.basename(stale_path)
    assert not exec_cache.has(key)
    assert exec_cache.lookup(key) is None
    assert exec_cache.STATS == {"hits": 0, "misses": 1, "errors": 0,
                                "stores": 0, "tombstones": 0}
    # same payload at the CURRENT digest: one error, then tombstone
    with open(exec_cache._entry_path(exec_cache.entry_digest(key)),
              "wb") as f:
        f.write(blob)
    assert exec_cache.lookup(key) is None
    assert exec_cache.STATS["errors"] == 1
    assert exec_cache.lookup(key) is None
    assert exec_cache.STATS["tombstones"] == 1
    assert exec_cache.STATS["errors"] == 1  # tombstone, not a re-error
