"""Sweep planner: decomposed/sharded execution must be bit-exact.

The load-bearing guarantees of this PR's execution model:

* channel-decomposed scans (row-confined static lanes split by channel
  row) are bit-identical to the flat single-lane ``simulate``;
* the planner's pooled, sharded, chunk-trimmed groups — across designs,
  workloads AND geometries in one batch — are bit-identical too;
* the same holds in a single-device environment (subprocess probe, since
  the in-process suite runs with 2 forced host devices — see conftest);
* the vectorized ``_nominal_order`` grouped-cumsum pass matches the
  per-transaction reference loop exactly.
"""
import dataclasses
import hashlib
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.ssd import DESIGNS, bench, simulate, simulate_sweep
from repro.ssd import sim as S
from repro.ssd.designs import rows_confined
from repro.ssd.sweep_plan import execute_sim_runs

PARITY_FIELDS = ("completion", "wait", "conflict", "hops", "tries",
                 "misroutes")

CONFINED = ("baseline", "pssd", "ideal")


def _assert_lane_parity(lane, solo, ctx):
    for f in PARITY_FIELDS:
        assert np.array_equal(getattr(lane, f), getattr(solo, f)), (ctx, f)
    assert lane.exec_ticks == solo.exec_ticks, ctx
    assert lane.bus_hold_ticks == solo.bus_hold_ticks, ctx
    assert lane.link_hold_ticks == solo.link_hold_ticks, ctx


def test_rows_confined_is_proved_not_assumed(tiny_cfg):
    """The decomposition proof: private/row buses pass, anything that can
    couple rows (column buses, dynamic FC selection, the global-mesh
    scout) fails and falls back to the flat scan."""
    flags = dict(zip(DESIGNS, rows_confined(tiny_cfg, DESIGNS)))
    for d in CONFINED:
        assert flags[d], d
    for d in ("pnssd", "nossd", "venice", "venice_minimal", "venice_hold",
              "venice_kscout"):
        assert not flags[d], d


def test_channel_decomposed_parity_all_designs(tiny_cfg, tiny_txns):
    """decompose=True vs the flat 1-lane oracle, every registered design.

    Confined lanes actually decompose (asserted via the planner's lane
    accounting); unconfined lanes must fall back — both bit-exact."""
    lanes0 = bench.PERF["lanes"]
    sweep = simulate_sweep(tiny_cfg, tiny_txns, DESIGNS, seeds=5,
                           decompose=True)
    # 3 confined designs split into 2 rows each on the 2x2 mesh: the lane
    # count exceeds one-per-design (group padding may add duplicates)
    assert bench.PERF["lanes"] - lanes0 >= len(DESIGNS) + len(CONFINED)
    for lane, design in zip(sweep, DESIGNS):
        solo = simulate(tiny_cfg, tiny_txns, design, seed=5)
        _assert_lane_parity(lane, solo, design)


def test_planner_multi_run_mixed_geometry_parity(tiny_cfg, tiny_txns):
    """One planned batch spanning two geometries (2x2 and 2x3) and two
    design subsets must equal per-lane ``simulate`` on the right config."""
    from repro.ssd import decompose_trace
    from repro.traces.generator import gen_trace, to_pages

    cfg2 = dataclasses.replace(tiny_cfg, name="t2x3", cols=3)
    tr = gen_trace("hm_0", 40, seed=1)
    pages = to_pages(tr, cfg2.page_bytes)
    txns2 = decompose_trace(cfg2, pages,
                            footprint_pages=int(pages["footprint_pages"]))
    designs1 = ("baseline", "pnssd", "venice", "ideal")
    designs2 = ("baseline", "nossd", "venice_kscout")  # pnssd needs square
    runs = [
        (tiny_cfg, tiny_txns, designs1, (5,) * 4, "auto"),
        (cfg2, txns2, designs2, (9,) * 3, True),
    ]
    res1, res2 = execute_sim_runs(runs)
    for lane, design in zip(res1, designs1):
        _assert_lane_parity(lane, simulate(tiny_cfg, tiny_txns, design,
                                           seed=5), ("2x2", design))
    for lane, design in zip(res2, designs2):
        _assert_lane_parity(lane, simulate(cfg2, txns2, design, seed=9),
                            ("2x3", design))


def test_planner_perf_accounting(tiny_cfg, tiny_txns):
    """PERF must attribute the execution: lanes, trimmed step counts,
    devices, and a per-group compile-vs-execute split."""
    before = {k: bench.PERF[k] for k in
              ("lanes", "scan_steps_valid", "scan_steps_padded")}
    g0 = len(bench.PERF["groups"])
    simulate_sweep(tiny_cfg, tiny_txns, ("baseline", "venice"), seeds=3)
    assert bench.PERF["lanes"] > before["lanes"]
    dv = bench.PERF["scan_steps_valid"] - before["scan_steps_valid"]
    dp = bench.PERF["scan_steps_padded"] - before["scan_steps_padded"]
    n = len(tiny_txns["arrival"])
    assert dv >= 2 * n  # both lanes' valid steps counted
    assert dp >= dv  # padded counts chunk round-up (+ any group padding)
    assert bench.PERF["devices_used"] == S.host_device_count() == 2
    new_groups = bench.PERF["groups"][g0:]
    assert new_groups, "planned execution must record its groups"
    for g in new_groups:
        assert {"lanes", "capacity", "shards", "scout", "steps",
                "compile_s", "exec_s"} <= set(g)


def test_prefetch_serves_run_workload_from_cache(tiny_cfg):
    """A prefetched figure phase is served from the run cache, and the
    results are the planner's (bit-identical either way)."""
    from repro.ssd.sweep_plan import RunRequest, prefetch

    bench.clear_caches()
    try:
        req = RunRequest("hm_0", tiny_cfg, ("baseline", "venice"),
                         n_requests=30)
        prefetch([req])
        misses = bench.PERF["run_misses"]
        run = bench.run_workload("hm_0", tiny_cfg,
                                 designs=("baseline", "venice"),
                                 n_requests=30)
        assert bench.PERF["run_misses"] == misses  # cache hit, no re-plan
        assert set(run.results) == {"baseline", "venice"}
        prefetch([req])  # idempotent: nothing pending
        assert bench.PERF["run_misses"] == misses
    finally:
        bench.clear_caches()


def test_single_device_environment_parity(tiny_cfg, tiny_txns):
    """The planner must be bit-exact in a plain 1-device environment.

    The suite forces 2 host devices (conftest), so the 1-device check runs
    in a subprocess with the forcing stripped; digests of every parity
    field must match the in-process (sharded, decomposed) run."""
    sweep = simulate_sweep(tiny_cfg, tiny_txns, DESIGNS, seeds=5,
                           decompose=True)
    h = hashlib.sha1()
    for lane in sweep:
        for f in PARITY_FIELDS:
            h.update(np.ascontiguousarray(getattr(lane, f)).tobytes())
    expect = h.hexdigest()

    script = r"""
import hashlib
import numpy as np
import jax
from repro.ssd import DESIGNS, decompose_trace, perf_optimized, simulate_sweep
from repro.traces.generator import gen_trace, to_pages

assert len(jax.devices()) == 1, jax.devices()
cfg = perf_optimized(rows=2, cols=2, pages_per_block=64)
tr = gen_trace("src2_1", 60, seed=3)
tr = dict(tr)
tr["arrival_us"] = tr["arrival_us"] / 16.0
pages = to_pages(tr, cfg.page_bytes)
txns = decompose_trace(cfg, pages,
                       footprint_pages=int(pages["footprint_pages"]))
sweep = simulate_sweep(cfg, txns, DESIGNS, seeds=5, decompose=True)
h = hashlib.sha1()
for lane in sweep:
    for f in ("completion", "wait", "conflict", "hops", "tries",
              "misroutes"):
        h.update(np.ascontiguousarray(getattr(lane, f)).tobytes())
print("DIGEST", h.hexdigest())
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = " ".join(  # a stock environment: 1 device, default
        f for f in env.get("XLA_FLAGS", "").split()  # (thunk) CPU runtime
        if "--xla_force_host_platform_device_count" not in f
        and "--xla_cpu_use_thunk_runtime" not in f
    )
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    digest = [l for l in out.stdout.splitlines() if l.startswith("DIGEST")]
    assert digest and digest[0].split()[1] == expect


def _rand_txns(rng, n, n_planes):
    return {
        "arrival": rng.integers(0, 50_000, n),
        "kind": rng.integers(0, 3, n),
        "plane": rng.integers(0, n_planes, n),
        "nbytes": rng.choice([512, 4096, 16384], n).astype(np.int64),
    }


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_nominal_order_vectorized_matches_loop(tiny_cfg, seed):
    """The grouped-cumsum ``_nominal_order`` is pinned bit-exact to the
    per-transaction reference loop on adversarial random streams (plane
    collisions, equal arrivals, all three kinds)."""
    rng = np.random.default_rng(seed)
    txns = _rand_txns(rng, 4000, tiny_cfg.n_planes)
    assert np.array_equal(S._nominal_order(tiny_cfg, txns),
                          S._nominal_order_ref(tiny_cfg, txns))


def test_empty_trace_all_decompose_flags(tiny_cfg):
    """An empty transaction set must return empty results on every path
    (decompose=True used to assume at least one row lane exists)."""
    empty = {k: np.empty((0,), np.int64)
             for k in ("arrival", "kind", "plane", "node", "row", "nbytes",
                       "req")}
    for flag in (False, "auto", True):
        for r in simulate_sweep(tiny_cfg, empty, ("baseline", "venice"),
                                seeds=1, decompose=flag):
            assert len(r.completion) == 0
            assert r.exec_ticks == 0


def test_nominal_order_fixture_and_edge_cases(tiny_cfg, tiny_txns):
    assert np.array_equal(S._nominal_order(tiny_cfg, tiny_txns),
                          S._nominal_order_ref(tiny_cfg, tiny_txns))
    empty = {k: np.empty((0,), np.int64)
             for k in ("arrival", "kind", "plane", "nbytes")}
    assert len(S._nominal_order(tiny_cfg, empty)) == 0
    one = {"arrival": np.array([7]), "kind": np.array([0]),
           "plane": np.array([3]), "nbytes": np.array([4096])}
    assert np.array_equal(S._nominal_order(tiny_cfg, one),
                          S._nominal_order_ref(tiny_cfg, one))
