"""Batched scout lanes (ISSUE 10): the gather-free scout DFS runner.

PR 5's batched runner stopped at statically-routed designs; this PR steps
[B] scout DFS machines per dispatch (``sim._make_batched_scout_step`` +
``kernels.ops.route_dfs``) with each lane routing against its own
link-occupancy map.  The parity bar is the house rule: element-wise
bit-identical to the flat per-lane scan AND to ``scalar_ref`` for every
scout design — rng streams, retry schedules and the k-scout race
included — with and without injected faults, on the XLA step and the
promoted Pallas kernel (interpreter mode, so CI needs no accelerator).
The planner's layout choice is pure policy; these tests force the bscout
layouts regardless of the measured thresholds in ``sweep_plan``.
"""
import numpy as np
import pytest

from repro.ssd import DESIGNS, bench, simulate
from repro.ssd import sim as S
from repro.ssd import sweep_plan as SP
from repro.ssd.designs import REGISTRY, KIND_SCOUT, FaultSpec
from repro.ssd.scalar_ref import simulate_ref

PARITY_FIELDS = ("completion", "wait", "conflict", "hops", "tries",
                 "misroutes")
SCOUT_DESIGNS = tuple(d for d in DESIGNS
                      if REGISTRY[d].kind == KIND_SCOUT)

FAULT_SPECS = {
    "none": None,
    "link": FaultSpec(failed_links=(0,)),
    "link+fc": FaultSpec(failed_links=(0,), failed_fcs=(1,)),
    "router": FaultSpec(failed_routers=(3,)),
}


def _assert_parity(lane, solo, ctx):
    for f in PARITY_FIELDS:
        assert np.array_equal(np.asarray(getattr(lane, f)),
                              np.asarray(getattr(solo, f))), (ctx, f)
    if lane.failed is not None or solo.failed is not None:
        assert np.array_equal(np.asarray(lane.failed),
                              np.asarray(solo.failed)), (ctx, "failed")
    assert lane.bus_hold_ticks == solo.bus_hold_ticks, ctx
    assert lane.link_hold_ticks == solo.link_hold_ticks, ctx


def _force_bscout(monkeypatch, backend="xla"):
    """Every scout pool lands in ONE batched scout dispatch."""
    monkeypatch.setattr(SP, "SMALL_LANE_MAX_CHUNKS", 64)
    monkeypatch.setattr(SP, "_BATCH_MIN_LANES", 2)
    monkeypatch.setattr(SP, "_BSCOUT_MAX_PER_SHARD", 64)
    monkeypatch.setattr(S, "LANE_BACKEND", backend)


@pytest.mark.parametrize("backend", ["xla", "pallas-interpret"])
def test_bscout_every_scout_design(tiny_cfg, tiny_txns, monkeypatch,
                                   backend):
    """One batched scout dispatch spanning ALL scout designs
    (heterogeneous hold/allow/n_scouts in one pool) == per-design flat
    ``simulate``, bit for bit, on both lane-step backends."""
    _force_bscout(monkeypatch, backend)
    designs = SCOUT_DESIGNS * 2  # wider than the 2*n_shards window
    g0 = len(bench.PERF["groups"])
    sweep = S.simulate_sweep(tiny_cfg, tiny_txns, designs, seeds=9,
                             decompose=False)
    new = bench.PERF["groups"][g0:]
    assert {g["variant"] for g in new} == {"bscout"}
    assert len(new) == 1  # the whole scout sweep was ONE dispatch
    for lane, design in zip(sweep, designs):
        _assert_parity(lane, simulate(tiny_cfg, tiny_txns, design, seed=9),
                       (backend, design))


@pytest.mark.parametrize("spec_name", sorted(FAULT_SPECS))
def test_bscout_faults_res_dead(tiny_cfg, tiny_txns, monkeypatch,
                                spec_name):
    """``res_dead`` fault masks flow through the batched scout path: dead
    links/FCs look permanently busy to every lane's DFS and the failed
    surface (FAIL_TIMEOUT rows) matches the flat oracle exactly."""
    _force_bscout(monkeypatch)
    spec = FAULT_SPECS[spec_name]
    designs = SCOUT_DESIGNS * 2
    g0 = len(bench.PERF["groups"])
    sweep = S.simulate_sweep(tiny_cfg, tiny_txns, designs, seeds=4,
                             decompose=False, faults=spec)
    assert "bscout" in {g["variant"]
                        for g in bench.PERF["groups"][g0:]}
    for lane, design in zip(sweep, designs):
        _assert_parity(
            lane, simulate(tiny_cfg, tiny_txns, design, seed=4,
                           faults=spec), (spec_name, design))


@pytest.mark.parametrize("design", SCOUT_DESIGNS)
def test_bscout_scalar_ref_parity(tiny_cfg, tiny_txns, monkeypatch,
                                  design):
    """The batched path also matches the independent scalar reference —
    same parity bar the flat scan is held to (seeds go through the same
    ``seed | 1`` lane transform on both sides)."""
    _force_bscout(monkeypatch)
    lanes = (design,) * 6
    sweep = S.simulate_sweep(tiny_cfg, tiny_txns, lanes, seeds=(7,) * 6,
                             decompose=False)
    ref = simulate_ref(tiny_cfg, tiny_txns, design, seed=7)
    for lane in sweep:
        for f in PARITY_FIELDS:
            assert np.array_equal(np.asarray(getattr(lane, f)),
                                  ref[f]), (design, f)


def test_bscout_kscout_race_masking(tiny_cfg, tiny_txns, monkeypatch):
    """Heterogeneous n_scouts in one pool (k_max=3): the 1-scout lanes
    must be masked out of the extra race rounds — bit-identical to their
    solo runs, rng stream included."""
    _force_bscout(monkeypatch)
    designs = ("venice", "venice_kscout", "venice_minimal", "venice_hold",
               "venice", "venice_kscout")
    sweep = S.simulate_sweep(tiny_cfg, tiny_txns, designs, seeds=9,
                             decompose=False)
    for lane, design in zip(sweep, designs):
        _assert_parity(lane, simulate(tiny_cfg, tiny_txns, design, seed=9),
                       design)


def test_bscout_mixed_lengths_masked_tail(tiny_cfg, tiny_txns,
                                          monkeypatch):
    """Scout lanes of different lengths share a batch: the shorter lane's
    masked tail steps must not perturb it (validity masking == the
    unbatched cond-skip), and its rng stream must not advance."""
    _force_bscout(monkeypatch)
    short = {k: np.asarray(v)[: len(tiny_txns["arrival"]) // 3]
             for k, v in dict(tiny_txns).items()}
    runs = [
        (tiny_cfg, tiny_txns, ("venice", "venice_kscout", "venice_hold"),
         (5, 5, 5), False),
        (tiny_cfg, short, ("venice", "venice_minimal"), (5, 5), False),
    ]
    res_long, res_short = SP.execute_sim_runs(runs)
    for res, design in zip(res_long, ("venice", "venice_kscout",
                                      "venice_hold")):
        _assert_parity(res, simulate(tiny_cfg, tiny_txns, design, seed=5),
                       ("long", design))
    for res, design in zip(res_short, ("venice", "venice_minimal")):
        _assert_parity(res, simulate(tiny_cfg, short, design, seed=5),
                       ("short", design))


def test_bscout_occupancy_profile(tiny_cfg, tiny_txns, monkeypatch):
    """Under the occupancy profile a scout pool dispatches as bscout
    occupancy groups (no monkeypatched windows) and stays bit-exact —
    the accelerator layout the CI A/B leg exercises."""
    monkeypatch.setattr(SP, "PLANNER_PROFILE", "occupancy")
    g0 = len(bench.PERF["groups"])
    sweep = S.simulate_sweep(tiny_cfg, tiny_txns, SCOUT_DESIGNS, seeds=11,
                             decompose=False)
    new = bench.PERF["groups"][g0:]
    assert {g["variant"] for g in new} == {"bscout"}
    for lane, design in zip(sweep, SCOUT_DESIGNS):
        _assert_parity(lane, simulate(tiny_cfg, tiny_txns, design,
                                      seed=11), design)


def test_bscout_telemetry_split(tiny_cfg, tiny_txns, monkeypatch):
    """Scout lane-steps land in the scout tallies (``steps_scout_*``),
    not the static ones — the kernel_dispatch split BENCH artifacts
    surface."""
    _force_bscout(monkeypatch)
    b0 = bench.PERF["steps_scout_batched"]
    s0 = bench.PERF["steps_batched"]
    S.simulate_sweep(tiny_cfg, tiny_txns, SCOUT_DESIGNS * 2, seeds=13,
                     decompose=False)
    assert bench.PERF["steps_scout_batched"] > b0
    assert bench.PERF["steps_batched"] == s0
