"""Self-healing compile backend: a dead/hung compile server never hangs
or fails a run (ISSUE 8).

The compile server (``sweep_plan._schedule_compiles`` -> ``xc_worker``)
is a scheduling hint with no correctness surface; these tests pin the
recovery paths that keep it that way:

* a SIGKILLed worker is detected by ``_await_server`` (nonzero
  returncode -> "crashed"), every delegated key falls back to the
  in-process compile, and the watchdog counters say so;
* an alive-but-silent worker (stale heartbeat) trips the
  ``_ServerWatchdog`` within its timeout — never the 600s poll deadline —
  and is killed and abandoned;
* end-to-end: SIGKILLing the worker right after it is spawned leaves a
  streamed run bit-identical to the clean rerun.
"""
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.ssd import bench, exec_cache
from repro.ssd import sim as S
from repro.ssd import sweep_plan as SP
from repro.ssd.stream import stream_simulate
from repro.traces.generator import gen_trace

PARITY_FIELDS = ("completion", "wait", "conflict", "hops", "tries",
                 "misroutes", "failed")


@pytest.fixture()
def server_state():
    """Run against a clean compile-server slate; never leak a fake/killed
    server (or its delegated keys) into other tests."""
    assert SP._PROC is None and not SP._PROC_KEYS

    def reset():
        if SP._PROC is not None and SP._PROC.poll() is None:
            SP._PROC.kill()
            SP._PROC.wait()
        SP._PROC = None
        SP._PROC_KEYS.clear()
        SP._WATCHDOG = None

    reset()
    yield
    reset()


def _fake_worker() -> subprocess.Popen:
    return subprocess.Popen(
        [sys.executable, "-c", "import time; time.sleep(600)"])


def test_sigkilled_worker_falls_back_fast(tmp_path, monkeypatch,
                                          server_state):
    """SIGKILL -> ``_await_server`` sees the nonzero returncode at once,
    records the crash, and compiles in-process."""
    hb = str(tmp_path / "wk.hb")
    open(hb, "w").close()
    proc = _fake_worker()
    key = ("lane", "sigkill-test")
    SP._PROC = proc
    SP._PROC_KEYS.add(key)
    SP._WATCHDOG = SP._ServerWatchdog(hb, timeout_s=30.0)
    compiled = []
    monkeypatch.setattr(
        S, "ensure_compiled",
        lambda k, *a, **kw: compiled.append(k) or "sentinel")
    proc.kill()
    proc.wait()
    trips0 = bench.PERF["xc_watchdog_trips"]
    fb0 = bench.PERF["xc_watchdog_fallbacks"]
    t0 = time.perf_counter()
    out = SP._await_server(key)
    assert time.perf_counter() - t0 < 30.0  # immediate, not the deadline
    assert out == "sentinel" and compiled == [key]
    assert bench.PERF["xc_watchdog_trips"] == trips0 + 1
    assert bench.PERF["xc_watchdog_reason"] == "crashed"
    assert bench.PERF["xc_watchdog_fallbacks"] == fb0 + 1
    assert SP._PROC is None and not SP._PROC_KEYS


def test_stale_heartbeat_trips_watchdog(tmp_path, monkeypatch,
                                        server_state):
    """A worker that is alive but silent (SIGSTOP/swap-death analogue:
    the heartbeat file stops changing) is abandoned at the heartbeat
    deadline and killed; the key compiles in-process."""
    hb = str(tmp_path / "wk.hb")
    open(hb, "w").close()
    proc = _fake_worker()  # alive, but never touches the heartbeat file
    key = ("lane", "hang-test")
    SP._PROC = proc
    SP._PROC_KEYS.add(key)
    SP._WATCHDOG = SP._ServerWatchdog(hb, timeout_s=0.3)
    monkeypatch.setattr(S, "ensure_compiled",
                        lambda k, *a, **kw: "sentinel")
    trips0 = bench.PERF["xc_watchdog_trips"]
    fb0 = bench.PERF["xc_watchdog_fallbacks"]
    t0 = time.perf_counter()
    out = SP._await_server(key)
    assert time.perf_counter() - t0 < 10.0
    assert out == "sentinel"
    assert bench.PERF["xc_watchdog_trips"] == trips0 + 1
    assert bench.PERF["xc_watchdog_reason"] == "heartbeat"
    assert bench.PERF["xc_watchdog_fallbacks"] == fb0 + 1
    assert SP._PROC is None and not SP._PROC_KEYS
    proc.wait(timeout=10)  # _fail_server killed the zombie
    assert proc.returncode is not None


def test_straggler_rule_flags_wedged_key():
    """The watchdog's straggler path: heartbeats keep coming but one
    key's wait dwarfs the median past the deadline floor — flagged after
    ``patience`` observations (driven with an injected clock, no 5s
    real-time waits)."""
    now = [0.0]
    wd = SP._ServerWatchdog.__new__(SP._ServerWatchdog)
    from repro.runtime.fault_tolerance import (HeartbeatMonitor,
                                               StragglerDetector)

    wd.hb_path = os.devnull  # mtime never changes; timeout is huge
    wd._clock = lambda: now[0]
    wd.mon = HeartbeatMonitor(["xc_worker"], timeout_s=1e9,
                              clock=wd._clock)
    wd.strag = StragglerDetector(k=4.0, deadline_floor_s=0.0, patience=3)
    wd.waits = {}
    wd._mtime = None
    wd._next_observe = now[0] + wd.OBSERVE_PERIOD_S
    wd.reason = None
    # one wedged key among three progressing ones: re-anchor the healthy
    # keys' wait start each round so only the wedged key accumulates
    t_start = time.perf_counter()
    wd.waits["wedged"] = t_start - 100.0
    for i in range(3):
        for k in ("a", "b", "c"):
            wd.waits[k] = time.perf_counter()
        now[0] += wd.OBSERVE_PERIOD_S
        healthy = wd.healthy()
        assert healthy == (i < 2), i
    assert wd.reason == "straggler"
    assert not wd.healthy()  # sticky


def test_run_completes_after_worker_sigkill(tiny_cfg, tmp_path,
                                            monkeypatch, server_state):
    """End-to-end acceptance: kill the real compile server the moment it
    is spawned mid-preset; the streamed run must complete and be
    bit-identical to the clean rerun."""
    monkeypatch.setenv("REPRO_XC_DIR", str(tmp_path / "xc"))
    monkeypatch.setenv("REPRO_COMPILE_PROC", "1")
    exec_cache.flush()
    S.clear_exec_cache()
    trace = gen_trace("prxy_0", 200, seed=3, footprint_bytes=1 << 20)
    span_s = float(trace["arrival_us"][-1]) * 1e-6
    designs = ("baseline", "venice", "venice_kscout")  # >= 3 lanec keys
    orig = SP._schedule_compiles
    killed = []

    def schedule_then_kill(keys):
        orig(keys)
        if SP._PROC is not None and SP._PROC.poll() is None:
            SP._PROC.kill()
            SP._PROC.wait()
            killed.append(True)

    monkeypatch.setattr(SP, "_schedule_compiles", schedule_then_kill)
    sr = stream_simulate(tiny_cfg, trace, designs, seeds=5,
                         window_s=max(2 * span_s, 1.0))
    assert killed, "the compile server was never spawned (keys < 3?)"
    monkeypatch.setattr(SP, "_schedule_compiles", orig)
    clean = stream_simulate(tiny_cfg, trace, designs, seeds=5,
                            window_s=max(2 * span_s, 1.0))
    for i, d in enumerate(designs):
        for f in PARITY_FIELDS:
            assert np.array_equal(getattr(sr.results[i], f),
                                  getattr(clean.results[i], f)), (d, f)
