"""Pallas batched static step: interpreter-mode bit-exactness pins.

The lane-tiled Pallas wrapper (``kernels.batched_step.lane_tiled_step``)
runs the SAME step closure ``sim._make_batched_static_step`` builds, so
these tests pin the whole chain — pallas_call blocking, scan-in-kernel
interaction, masked-validity no-ops — element-wise bit-exact against the
flat unbatched ``simulate`` oracle for every statically-routed design
(including nossd's dynamic-FC one-hot path), on CPU, with no
accelerator: exactly what CI runs under ``JAX_PLATFORMS=cpu``.

Also covered here: the occupancy planner profile (accelerator pooling by
lanes x padded chunks per device) must stay bit-exact on CPU with the
cpu profile untouched as the default, and the kernel-dispatch counters
must attribute every group to its backend.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ssd import bench, simulate
from repro.ssd import sim as S
from repro.ssd import sweep_plan as SP
from repro.ssd.designs import static_design_names

STATIC_DESIGNS = static_design_names()
PARITY_FIELDS = ("completion", "wait", "conflict", "hops", "tries",
                 "misroutes")


def _assert_parity(lane, solo, ctx):
    for f in PARITY_FIELDS:
        assert np.array_equal(getattr(lane, f), getattr(solo, f)), (ctx, f)
    assert lane.bus_hold_ticks == solo.bus_hold_ticks, ctx
    assert lane.link_hold_ticks == solo.link_hold_ticks, ctx


def _force_batched(monkeypatch, backend=None):
    """Every static pool -> one batched dispatch, on the given backend."""
    monkeypatch.setattr(SP, "SMALL_LANE_MAX_CHUNKS", 64)
    monkeypatch.setattr(SP, "_BATCH_MIN_LANES", 2)
    monkeypatch.setattr(SP, "_BATCH_MAX_PER_SHARD", 64)
    if backend is not None:
        monkeypatch.setattr(S, "LANE_BACKEND", backend)


def test_lane_tiled_step_generic_toy():
    """The wrapper itself, off the simulator: tiled grid, pytree I/O, and
    bool outputs survive the pallas_call round-trip bit-exactly."""
    from repro.kernels.batched_step import lane_tiled_step

    def step(sp, state, xs):
        tx, mask = xs
        s = state + tx * sp["gain"][:, None]
        out = (s.sum(axis=1), (s.max(axis=1) > 40) & mask)
        return s, out

    B, N = 8, 5
    sp = {"gain": jnp.arange(B, dtype=jnp.int32)}
    state = jnp.ones((B, N), jnp.int32)
    xs = (jnp.arange(B * N, dtype=jnp.int32).reshape(B, N) % 7,
          jnp.asarray([True, False] * (B // 2)))
    want = step(sp, state, xs)
    for bt in (None, 4, 3):  # 3 does not divide 8 -> single-tile fallback
        got = lane_tiled_step(step, b_tile=bt, interpret=True)(sp, state, xs)
        for g, w in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(want)):
            assert g.dtype == w.dtype
            assert np.array_equal(np.asarray(g), np.asarray(w)), bt


def test_lane_backend_resolution():
    assert S.resolve_lane_backend("xla") == "xla"
    if jax.default_backend() not in S._ACCEL_BACKENDS:
        # no Pallas compiler on CPU: "pallas" degrades honestly,
        # "auto" keeps the measured XLA path
        assert S.resolve_lane_backend("pallas") == "pallas-interpret"
        assert S.resolve_lane_backend("auto") == "xla"
    assert S.resolve_lane_backend("pallas-interpret") == "pallas-interpret"
    with pytest.raises(ValueError):
        S.resolve_lane_backend("cuda-graphs")
    # key -> backend attribution used by the PERF counters
    base = ("batched", (2, 2, 2, 2, 64), 1024, 2, (None,), 2)
    assert S.kernel_backend_of_key(base) == "xla"
    assert S.kernel_backend_of_key(base + ("pallas",)) == "pallas-compiled"
    assert (S.kernel_backend_of_key(base + ("pallas-interpret",))
            == "pallas-interpret")
    assert S.kernel_backend_of_key(("lane",) + base[1:]) == "xla"


def test_pallas_step_every_static_design(tiny_cfg, tiny_txns, monkeypatch):
    """THE tentpole pin: one Pallas-interpret batched dispatch spanning
    all statically-routed designs == per-design flat ``simulate``, bit
    for bit, with the dispatch attributed to the pallas backend."""
    _force_batched(monkeypatch, backend="pallas-interpret")
    g0 = len(bench.PERF["groups"])
    sweep = S.simulate_sweep(tiny_cfg, tiny_txns, STATIC_DESIGNS, seeds=5,
                             decompose=False)
    new = bench.PERF["groups"][g0:]
    assert {g["variant"] for g in new} == {"batched"}
    assert {g["kernel_backend"] for g in new} == {"pallas-interpret"}
    for lane, design in zip(sweep, STATIC_DESIGNS):
        _assert_parity(lane, simulate(tiny_cfg, tiny_txns, design, seed=5),
                       design)


@pytest.mark.parametrize("design", STATIC_DESIGNS)
def test_pallas_step_per_design_seed_sweep(tiny_cfg, tiny_txns, design,
                                           monkeypatch):
    """Homogeneous Pallas batches stay bit-exact per design — nossd's
    dynamic-FC one-hot selection included."""
    _force_batched(monkeypatch, backend="pallas-interpret")
    lanes = (design,) * 6
    sweep = S.simulate_sweep(tiny_cfg, tiny_txns, lanes, seeds=(3,) * 6,
                             decompose=False)
    solo = simulate(tiny_cfg, tiny_txns, design, seed=3)
    for lane in sweep:
        _assert_parity(lane, solo, design)


def test_pallas_masked_tail_is_noop(tiny_cfg, tiny_txns, monkeypatch):
    """Mixed-length lanes under the Pallas step: the shorter lane's
    masked (invalid) steps must stay bit-identical no-ops — the
    masked-arithmetic validity path survives the kernel wrapping."""
    _force_batched(monkeypatch, backend="pallas-interpret")
    short = {k: np.asarray(v)[: len(tiny_txns["arrival"]) // 3]
             for k, v in dict(tiny_txns).items()}
    runs = [
        (tiny_cfg, tiny_txns, ("baseline", "pnssd", "pssd"), (5, 5, 5),
         False),
        (tiny_cfg, short, ("nossd", "ideal"), (5, 5), False),
    ]
    res_long, res_short = SP.execute_sim_runs(runs)
    for res, txns, designs in ((res_long, tiny_txns,
                                ("baseline", "pnssd", "pssd")),
                               (res_short, short, ("nossd", "ideal"))):
        for lane, design in zip(res, designs):
            _assert_parity(lane, simulate(tiny_cfg, txns, design, seed=5),
                           design)


def test_occupancy_profile_parity(tiny_cfg, tiny_txns, monkeypatch):
    """The accelerator planner profile on CPU: every static lane routes
    through the batched runner pooled by occupancy, scouts keep the cpu
    layout, and every output stays bit-exact vs the flat oracle."""
    monkeypatch.setattr(SP, "PLANNER_PROFILE", "occupancy")
    designs = STATIC_DESIGNS + ("venice", "venice_minimal")
    g0 = len(bench.PERF["groups"])
    sweep = S.simulate_sweep(tiny_cfg, tiny_txns, designs, seeds=7,
                             decompose=False)
    new = bench.PERF["groups"][g0:]
    by_scout = {g["scout"]: g["variant"] for g in new}
    assert by_scout.get(False) == "batched"  # static pool -> occupancy
    assert by_scout.get(True) != "batched"  # scouts keep the cpu layout
    for lane, design in zip(sweep, designs):
        _assert_parity(lane, simulate(tiny_cfg, tiny_txns, design, seed=7),
                       design)


def test_occupancy_budget_cuts_groups(tiny_cfg, tiny_txns, monkeypatch):
    """A one-chunk-per-device budget forces the occupancy planner to cut
    the pool into several dispatches; outputs must not change."""
    monkeypatch.setattr(SP, "PLANNER_PROFILE", "occupancy")
    monkeypatch.setattr(SP, "OCCUPANCY_CHUNKS", 1)
    designs = STATIC_DESIGNS * 2
    g0 = len(bench.PERF["groups"])
    sweep = S.simulate_sweep(tiny_cfg, tiny_txns, designs,
                             seeds=tuple(range(len(designs))),
                             decompose=False)
    new = [g for g in bench.PERF["groups"][g0:] if g["variant"] == "batched"]
    assert len(new) > 1
    for lane, design, seed in zip(sweep, designs, range(len(designs))):
        _assert_parity(lane, simulate(tiny_cfg, tiny_txns, design,
                                      seed=seed), design)


def test_kernel_dispatch_counters(tiny_cfg, tiny_txns, monkeypatch):
    """PERF accounting: batched-vs-unbatched step share and per-backend
    group counts move when a Pallas batched group runs."""
    _force_batched(monkeypatch, backend="pallas-interpret")
    kb0 = bench.PERF["kernel_backends"].get("pallas-interpret", 0)
    sb0 = bench.PERF["steps_batched"]
    su0 = bench.PERF["steps_scout_unbatched"]
    S.simulate_sweep(tiny_cfg, tiny_txns, STATIC_DESIGNS + ("venice",),
                     seeds=2, decompose=False)
    assert bench.PERF["kernel_backends"]["pallas-interpret"] > kb0
    assert bench.PERF["steps_batched"] > sb0  # the static batch
    # the lone scout lane runs flat here and tallies into the SCOUT
    # split (ISSUE 10), not the static unbatched counter
    assert bench.PERF["steps_scout_unbatched"] > su0
