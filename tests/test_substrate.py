"""Optimizers, checkpointing, data pipeline, venice_io, fault tolerance,
sharding rules."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import latest_step, restore, restore_latest, save
from repro.data.pipeline import SyntheticTokens
from repro.data.venice_io import plan_reads
from repro.optim import adafactor, adamw, clip_by_global_norm
from repro.optim.compression import compressed_psum, error_feedback_update
from repro.runtime import HeartbeatMonitor, StragglerDetector, replan_mesh


class TestOptim:
    def _quad(self, opt, steps=200):
        target = jnp.asarray(np.linspace(-1, 1, 12).reshape(3, 4), jnp.float32)
        params = {"w": jnp.zeros((3, 4), jnp.float32),
                  "b": jnp.zeros((4,), jnp.float32)}
        state = opt.init(params)

        def loss(p):
            return jnp.sum((p["w"] - target) ** 2) + jnp.sum((p["b"] - 1.0) ** 2)

        for _ in range(steps):
            g = jax.grad(loss)(params)
            params, state, _ = opt.update(g, state, params)
        return float(loss(params))

    def test_adamw_converges(self):
        assert self._quad(adamw(lr=0.05, weight_decay=0.0)) < 1e-2

    def test_adafactor_converges(self):
        assert self._quad(adafactor(), steps=800) < 5e-2

    def test_adafactor_state_is_factored(self):
        opt = adafactor()
        params = {"w": jnp.zeros((64, 128), jnp.float32)}
        st = opt.init(params)
        assert st["f"]["w"]["vr"].shape == (64,)
        assert st["f"]["w"]["vc"].shape == (128,)

    def test_clip_by_global_norm(self):
        tree = {"a": jnp.full((10,), 10.0)}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert float(norm) > 1.0
        _, n2 = clip_by_global_norm(clipped, 1e9)
        assert float(n2) == pytest.approx(1.0, rel=1e-5)

    def test_error_feedback_reduces_bias(self):
        rs = np.random.RandomState(0)
        g = jnp.asarray(rs.randn(256) * 1e-3, jnp.float32)
        err = jnp.zeros_like(g)
        acc_plain = jnp.zeros_like(g)
        acc_ef = jnp.zeros_like(g)
        for _ in range(50):
            dq, err = error_feedback_update(g, err)
            acc_ef = acc_ef + dq
            from repro.optim.compression import compress_int8, decompress_int8
            q, s = compress_int8(g)
            acc_plain = acc_plain + decompress_int8(q, s)
        true = g * 50
        assert float(jnp.abs(acc_ef - true).max()) <= float(
            jnp.abs(acc_plain - true).max()
        ) + 1e-6

    def test_compressed_psum_matches_mean(self):
        # single-device shard_map over a size-1 axis: exactness check
        mesh = jax.make_mesh((1,), ("pod",))
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        g = jnp.asarray(np.random.RandomState(1).randn(64), jnp.float32)
        f = shard_map(
            lambda x: compressed_psum(x, "pod"), mesh=mesh,
            in_specs=P(), out_specs=P(),
        )
        got = f(g)
        np.testing.assert_allclose(np.asarray(got), np.asarray(g), atol=2e-2)


class TestCheckpoint:
    def _tree(self, seed=0):
        rs = np.random.RandomState(seed)
        return {
            "layers": {"w": rs.randn(16, 8).astype(np.float32),
                       "b": rs.randn(8).astype(np.float32)},
            "step_scalar": np.float32(3.5),
        }

    def test_roundtrip(self, tmp_path):
        t = self._tree()
        save(str(tmp_path), 10, t, n_shards=4)
        got = restore(str(tmp_path), 10, t)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(t)):
            np.testing.assert_array_equal(a, b)

    def test_latest_and_atomicity(self, tmp_path):
        t = self._tree()
        save(str(tmp_path), 1, t)
        save(str(tmp_path), 7, t)
        # a crashed save (tmp dir) must be invisible
        os.makedirs(str(tmp_path / "step_00000009.tmp"))
        assert latest_step(str(tmp_path)) == 7
        step, got = restore_latest(str(tmp_path), t)
        assert step == 7

    def test_elastic_reshard(self, tmp_path):
        """Save with 8 shards, restore under a different parallelism."""
        t = self._tree(3)
        save(str(tmp_path), 5, t, n_shards=8)
        got = restore(str(tmp_path), 5, t)  # reader shard count independent
        np.testing.assert_array_equal(got["layers"]["w"], t["layers"]["w"])


class TestData:
    def test_determinism_and_sharding(self):
        src = SyntheticTokens(vocab=1000, seq_len=32, global_batch=8, seed=1)
        a = src.batch(3, shard=0, n_shards=2)
        b = src.batch(3, shard=0, n_shards=2)
        c = src.batch(3, shard=1, n_shards=2)
        assert np.array_equal(a, b)
        assert not np.array_equal(a, c)
        assert a.shape == (4, 32)
        assert a.max() < 1000 and a.min() >= 0

    def test_venice_io_plan_is_conflict_free_rounds(self):
        reqs = [(h, n) for h in range(4) for n in range(8)]
        plan = plan_reads(reqs, n_hosts=4, n_storage=32, seed=0)
        # complete coverage, each request exactly once
        assert sorted(i for r in plan.rounds for i in r) == list(range(len(reqs)))
        # within each round the reserved paths must be link-disjoint
        for rnd in plan.rounds:
            links = np.concatenate([plan.paths[i] for i in rnd])
            assert len(links) == len(set(links.tolist()))
        assert 1 <= plan.n_rounds <= len(reqs)


class TestRuntime:
    def test_heartbeat(self):
        clock = {"t": 0.0}
        hb = HeartbeatMonitor(["a", "b"], timeout_s=10,
                              clock=lambda: clock["t"])
        clock["t"] = 5.0
        hb.beat("a")
        clock["t"] = 12.0
        assert hb.dead_hosts() == ["b"]
        assert hb.alive() == ["a"]

    def test_straggler_detection(self):
        det = StragglerDetector(k=2.0, patience=2)
        durs = {f"h{i}": 0.1 for i in range(8)}
        durs["h7"] = 1.0
        assert det.observe_step(durs) == []  # first strike
        assert det.observe_step(durs) == ["h7"]  # second -> flagged

    def test_elastic_replan(self):
        p = replan_mesh(512, model_parallel=16)
        assert (p.pods, p.data, p.model) == (2, 16, 16)
        p2 = replan_mesh(511, model_parallel=16, prev=p)
        assert p2.devices <= 511 and p2.model == 16
        assert p2.reshard
        with pytest.raises(ValueError):
            replan_mesh(8, model_parallel=16)


class TestShardingRules:
    def test_param_specs_divisibility_fallback(self):
        from jax.sharding import PartitionSpec as P

        from repro.parallel.sharding import param_specs

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        shapes = {
            "layers": {
                "attn": {"wq": jax.ShapeDtypeStruct((4, 64, 896), jnp.float32)},
                "moe": {"wg": jax.ShapeDtypeStruct((4, 8, 64, 128), jnp.float32)},
            },
            "embed": jax.ShapeDtypeStruct((1000, 64), jnp.float32),
        }
        notes = []
        specs = param_specs(mesh, shapes, ("data",), notes)
        assert specs["layers"]["attn"]["wq"] == P(None, "data", "model")
        assert specs["layers"]["moe"]["wg"] == P(None, "model", "data", None)
        assert specs["embed"] == P("model", "data")

    def test_cache_specs(self):
        from jax.sharding import PartitionSpec as P

        from repro.parallel.sharding import cache_specs

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        shapes = {
            "layers": {
                "k": jax.ShapeDtypeStruct((24, 8, 1024, 2, 64), jnp.float32),
                "v": jax.ShapeDtypeStruct((24, 8, 1024, 2, 64), jnp.float32),
            }
        }
        specs = cache_specs(mesh, shapes)
        assert specs["layers"]["k"] == P(None, "data", None, None, "model")
        specs2 = cache_specs(mesh, shapes, seq_shard=True)
        assert specs2["layers"]["k"] == P(None, None, "data", None, "model")
