"""Small-lane layouts: batched (gather-free) and stacked, pinned bit-exact.

PR 3 recorded "vmap-batching lanes is ~50x slower and therefore unused";
this PR revisits that with the gather-free formulation (one-hot
compare-and-reduce state lookups, host-side pre-gathered node tables,
masked-arithmetic validity — see ``sim._make_batched_static_step``).  The
runner must be bit-identical to the flat unbatched scan for EVERY
statically-routed design — including nossd, whose live FC selection takes
the one-hot F-axis path — and the stacked layout (K sequential unbatched
lanes per shard) must be bit-identical for every design incl. scouts.
The planner's layout choice is pure policy; these tests force each layout
regardless of the measured-threshold policy in ``sweep_plan``.
"""
import numpy as np
import pytest

from repro.ssd import DESIGNS, bench, simulate
from repro.ssd import sim as S
from repro.ssd import sweep_plan as SP
from repro.ssd.designs import REGISTRY, KIND_SCOUT

PARITY_FIELDS = ("completion", "wait", "conflict", "hops", "tries",
                 "misroutes")
STATIC_DESIGNS = tuple(d for d in DESIGNS
                       if REGISTRY[d].kind != KIND_SCOUT)
SCOUT_DESIGNS = tuple(d for d in DESIGNS
                      if REGISTRY[d].kind == KIND_SCOUT)


def _assert_parity(lane, solo, ctx):
    for f in PARITY_FIELDS:
        assert np.array_equal(getattr(lane, f), getattr(solo, f)), (ctx, f)
    assert lane.bus_hold_ticks == solo.bus_hold_ticks, ctx
    assert lane.link_hold_ticks == solo.link_hold_ticks, ctx


def _variants(monkeypatch, layout):
    """Force every small-lane-eligible pool onto one layout."""
    monkeypatch.setattr(SP, "SMALL_LANE_MAX_CHUNKS", 64)
    monkeypatch.setattr(SP, "_BATCH_MIN_LANES", 2)
    if layout == "batched":
        monkeypatch.setattr(SP, "_BATCH_MAX_PER_SHARD", 64)
    else:  # stack only
        monkeypatch.setattr(SP, "_BATCH_MAX_PER_SHARD", 0)


def test_batched_runner_every_static_design(tiny_cfg, tiny_txns,
                                            monkeypatch):
    """One batched dispatch spanning ALL statically-routed designs
    (heterogeneous scalars, pnssd's 2-candidate masks, nossd's dynamic
    FC) == per-design flat ``simulate``, bit for bit."""
    _variants(monkeypatch, "batched")
    g0 = len(bench.PERF["groups"])
    sweep = S.simulate_sweep(tiny_cfg, tiny_txns, STATIC_DESIGNS, seeds=5,
                             decompose=False)
    new = bench.PERF["groups"][g0:]
    assert {g["variant"] for g in new} == {"batched"}
    assert len(new) == 1  # the whole static sweep was ONE dispatch
    for lane, design in zip(sweep, STATIC_DESIGNS):
        _assert_parity(lane, simulate(tiny_cfg, tiny_txns, design, seed=5),
                       design)


@pytest.mark.parametrize("design", STATIC_DESIGNS)
def test_batched_runner_per_design_seed_sweep(tiny_cfg, tiny_txns, design,
                                              monkeypatch):
    """A homogeneous batch (same design, several seeds) stays bit-exact —
    covers the promoted/specialized scalar paths per design kind."""
    _variants(monkeypatch, "batched")
    lanes = (design,) * 6  # wider than the 2*n_shards small-lane window
    sweep = S.simulate_sweep(tiny_cfg, tiny_txns, lanes, seeds=(3,) * 6,
                             decompose=False)
    solo = simulate(tiny_cfg, tiny_txns, design, seed=3)
    for lane in sweep:
        _assert_parity(lane, solo, design)


def test_batched_mixed_lengths_masked_tail(tiny_cfg, tiny_txns,
                                           monkeypatch):
    """Lanes of different lengths share a batch: the shorter lane's
    masked tail steps must not perturb it (validity masking == the
    unbatched cond-skip)."""
    from repro.ssd.sweep_plan import execute_sim_runs

    _variants(monkeypatch, "batched")
    short = {k: np.asarray(v)[: len(tiny_txns["arrival"]) // 3]
             for k, v in dict(tiny_txns).items()}
    runs = [
        (tiny_cfg, tiny_txns, ("baseline", "pnssd", "pssd"), (5, 5, 5),
         False),
        (tiny_cfg, short, ("nossd", "ideal"), (5, 5), False),
    ]
    res_long, res_short = execute_sim_runs(runs)
    _assert_parity(res_long[0], simulate(tiny_cfg, tiny_txns, "baseline",
                                         seed=5), "baseline")
    _assert_parity(res_long[1], simulate(tiny_cfg, tiny_txns, "pnssd",
                                         seed=5), "pnssd")
    _assert_parity(res_long[2], simulate(tiny_cfg, tiny_txns, "pssd",
                                         seed=5), "pssd")
    _assert_parity(res_short[0], simulate(tiny_cfg, short, "nossd",
                                          seed=5), "nossd")
    _assert_parity(res_short[1], simulate(tiny_cfg, short, "ideal",
                                          seed=5), "ideal")


def test_stacked_lanes_every_design(tiny_cfg, tiny_txns, monkeypatch):
    """The stacked layout (sequential unbatched lanes per shard) is
    bit-exact for every design, scouts included."""
    _variants(monkeypatch, "stack")
    g0 = len(bench.PERF["groups"])
    sweep = S.simulate_sweep(tiny_cfg, tiny_txns, DESIGNS, seeds=5,
                             decompose=False)
    new = bench.PERF["groups"][g0:]
    assert "stack" in {g["variant"] for g in new}
    assert len(new) < len(DESIGNS)  # dispatches actually collapsed
    for lane, design in zip(sweep, DESIGNS):
        _assert_parity(lane, simulate(tiny_cfg, tiny_txns, design, seed=5),
                       design)


def test_scout_stack_parity_with_kscout(tiny_cfg, tiny_txns, monkeypatch):
    """Stacked scout lanes with heterogeneous n_scouts (k_max=3 pool):
    the 1-scout lanes must stay bit-identical to their solo runs."""
    _variants(monkeypatch, "stack")
    designs = ("venice", "venice_kscout", "venice_minimal", "venice_hold",
               "venice", "venice_kscout")
    sweep = S.simulate_sweep(tiny_cfg, tiny_txns, designs, seeds=9,
                             decompose=False)
    for lane, design in zip(sweep, designs):
        _assert_parity(lane, simulate(tiny_cfg, tiny_txns, design, seed=9),
                       design)


def test_default_policy_collapses_small_pools(tiny_cfg, tiny_txns):
    """Under the DEFAULT policy (no monkeypatching), a small-lane static
    pool wider than the batched window still collapses into stacked
    dispatches — the tail-phase regime."""
    designs = STATIC_DESIGNS * 3  # 15 small static lanes on 2 shards
    g0 = len(bench.PERF["groups"])
    sweep = S.simulate_sweep(tiny_cfg, tiny_txns, designs,
                             seeds=tuple(range(15)), decompose=False)
    new = bench.PERF["groups"][g0:]
    assert len(new) <= 2, [g["variant"] for g in new]
    for lane, design, seed in zip(sweep, designs, range(15)):
        _assert_parity(lane, simulate(tiny_cfg, tiny_txns, design,
                                      seed=seed), design)
