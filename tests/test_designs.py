"""Table-driven design substrate: spec validation + sweep/simulate parity.

The parity test is the load-bearing guarantee of the substrate: a design
sweep (one batched executable per cost class) must be *bit-identical* to
running each design through ``simulate`` on its own — including the k-scout
lane, whose program races more scouts but masks the extras' rng streams.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.topology import build_mesh
from repro.ssd import DESIGNS, REGISTRY, simulate, simulate_sweep
from repro.ssd.designs import (
    KIND_SCOUT,
    lower_designs,
    resolve_specs,
    sweep_layout,
)

PARITY_FIELDS = ("completion", "wait", "conflict", "hops", "tries",
                 "misroutes")


def test_registry_covers_paper_designs():
    for d in ("baseline", "pssd", "pnssd", "nossd", "venice",
              "venice_minimal", "venice_hold", "venice_kscout", "ideal"):
        assert d in REGISTRY
        assert REGISTRY[d].doc  # every ablation documented next to its spec


def test_resolve_specs_rejects_unknown():
    with pytest.raises(ValueError, match="unknown design"):
        resolve_specs(("venice", "venice_release"))


@pytest.mark.parametrize("rows,cols", [(2, 2), (2, 3), (8, 8)])
def test_lowered_tables_well_formed(rows, cols, tiny_cfg):
    """Padded tables must be in-bounds and internally consistent for every
    registered design on square and non-square geometries."""
    cfg = dataclasses.replace(tiny_cfg, name=f"t{rows}x{cols}", rows=rows,
                              cols=cols)
    designs = DESIGNS if rows == cols else tuple(
        d for d in DESIGNS if d != "pnssd"  # pnssd assumes rows == cols
    )
    lay = sweep_layout(cfg)
    t = lower_designs(cfg, designs)
    D, N = len(designs), lay.n_nodes
    assert t.cmask.shape == (D, lay.F_pad, N, 2, lay.R_pad)
    assert bool((t.xfer_den > 0).all())
    assert bool((t.n_scouts >= 1).all())
    cmask = np.asarray(t.cmask)
    hops = np.asarray(t.hops)
    topo = build_mesh(rows, cols)
    for i, d in enumerate(designs):
        spec = REGISTRY[d]
        link_bits = cmask[i, :, :, :, : lay.L_pad]
        fc_bits = cmask[i, :, :, :, lay.L_pad : lay.L_pad + lay.F_pad]
        chip_bits = cmask[i, :, :, :, lay.L_pad + lay.F_pad :]
        if spec.kind == KIND_SCOUT:
            assert not cmask[i].any()  # routes come from the scout
            continue
        # candidate 0 must exist for every (fc, node) — except 0-hop
        # routes (an FC reaching its own injection node crosses no link)
        assert (link_bits[:, :, 0].any(axis=-1)
                | (hops[i, :, :, 0] == 0)).all(), d
        if spec.kind == "bus":
            assert (link_bits.sum(axis=-1) == 1).all()  # exactly one bus
            assert not fc_bits.any() and not chip_bits.any()
            assert (hops[i] == 0).all()
        elif spec.kind == "pnssd":
            assert bool(np.asarray(t.cand2_ok)[i].all())
            assert (link_bits.sum(axis=-1) == 1).all()
            assert (fc_bits.sum(axis=-1) == 1).all()
            assert (chip_bits.sum(axis=-1) == 1).all()
        elif spec.kind == "nossd":
            # XY path length == link popcount == manhattan distance, per FC
            for f in range(rows):
                for n in range(N):
                    r1, c1 = divmod(n, cols)
                    man = abs(int(topo.fc_node[f]) // cols - r1) + c1
                    assert hops[i, f, n, 0] == man
                    assert link_bits[f, n, 0].sum() == man
        # valid FC slots only
        assert np.asarray(t.fc_valid)[i, :rows].all()
        assert not np.asarray(t.fc_valid)[i, rows:].any()


def test_sweep_matches_per_design_simulate(tiny_cfg, tiny_txns):
    """The tentpole guarantee: one sweep == nine independent simulations,
    bit for bit, on every metric the StepOut emits."""
    sweep = simulate_sweep(tiny_cfg, tiny_txns, DESIGNS, seeds=5)
    for lane, design in zip(sweep, DESIGNS):
        solo = simulate(tiny_cfg, tiny_txns, design, seed=5)
        for f in PARITY_FIELDS:
            assert np.array_equal(
                getattr(lane, f), getattr(solo, f)
            ), (design, f)
        assert lane.exec_ticks == solo.exec_ticks
        assert lane.bus_hold_ticks == solo.bus_hold_ticks
        assert lane.link_hold_ticks == solo.link_hold_ticks


def test_sweep_seed_axis(tiny_cfg, tiny_txns):
    """Repeating a design with different seeds sweeps the seed axis; equal
    seeds must reproduce bit-identically."""
    a, b, c = simulate_sweep(
        tiny_cfg, tiny_txns, ("venice", "venice", "venice"), seeds=(1, 9, 1)
    )
    assert np.array_equal(a.completion, c.completion)
    # the per-lane seed must actually reach the lane: different tie-break
    # streams explore different paths under this trace's conflicts
    assert not np.array_equal(a.completion, b.completion)


def test_sweep_behavioural_orderings(tiny_cfg, tiny_txns):
    """Paper-level orderings hold on the tiny geometry too."""
    res = dict(
        zip(DESIGNS, simulate_sweep(tiny_cfg, tiny_txns, DESIGNS, seeds=0))
    )
    assert res["venice"].conflict_rate() <= res["baseline"].conflict_rate()
    for d in ("baseline", "venice", "nossd"):
        assert res["ideal"].exec_s <= res[d].exec_s * 1.02
    assert res["venice_hold"].link_hold_ticks >= res["venice"].link_hold_ticks


def test_sweep_lane_count_validation(tiny_cfg, tiny_txns):
    with pytest.raises(ValueError, match="seeds"):
        simulate_sweep(tiny_cfg, tiny_txns, ("venice", "ideal"), seeds=(1,))
