"""Fault model: zero-fault bit-identity, scalar parity, degraded mode.

The load-bearing guarantees of the fault-injection subsystem (ISSUE 8):

* an empty/absent ``FaultSpec`` is bit-identical to the fault-free path —
  same lowered tables, same per-transaction arrays, and the SAME
  executables (fault masks ride as scan *arguments*, so a faulted run
  adds zero cache keys);
* the faulty scan is pinned element-wise against the scalar fault-aware
  reference (``repro.ssd.scalar_ref``) for static and scout designs,
  under link / FC / router / read-retry faults — including a mid-stream
  fault arriving exactly on a window boundary, replayed window-by-window
  through the stream capture hook;
* the paper's asymmetry: venice routes around dead links that stall the
  shared-bus baseline, retaining strictly more throughput in the
  degraded-mode sweep.
"""
import numpy as np
import pytest

from repro.ssd import bench, simulate
from repro.ssd import sim as S
from repro.ssd.designs import (DESIGNS, FaultSpec, LaneTables, NO_FAULTS,
                               lower_designs)
from repro.ssd.scalar_ref import LaneRef, simulate_ref
from repro.ssd.stream import (_active_faults, stream_simulate,
                              window_ticks_for)
from repro.traces.generator import gen_trace
from repro.workloads.scenario import (DegradedModeSweep,
                                      degraded_fault_spec, run_scenario)

PARITY_FIELDS = ("completion", "wait", "conflict", "hops", "tries",
                 "misroutes", "failed")

# at least one static-bus, one static-private, and one scout design
REF_DESIGNS = ("baseline", "pssd", "venice")

SPECS = {
    "none": None,
    "link": FaultSpec(failed_links=(0,)),
    "link+fc": FaultSpec(failed_links=(0,), failed_fcs=(1,)),
    "router": FaultSpec(failed_routers=(3,)),
    "retry": FaultSpec(retry_chips=(0, 1), retry_prob=0.5,
                       retry_ladder=(800, 2400), retry_seed=9),
}


class TestZeroFaultIdentity:
    def test_empty_spec_lowers_to_fault_free_tables(self, tiny_cfg):
        """NO_FAULTS and ``faults=None`` produce identical LaneTables for
        every registered design — all-False dead masks included."""
        t0 = lower_designs(tiny_cfg, DESIGNS)
        t1 = lower_designs(tiny_cfg, DESIGNS, NO_FAULTS)
        for f in LaneTables._fields:
            assert np.array_equal(np.asarray(getattr(t0, f)),
                                  np.asarray(getattr(t1, f))), f
        assert not np.asarray(t0.res_dead).any()

    def test_empty_spec_results_and_cache_keys_unchanged(
            self, tiny_cfg, tiny_txns):
        """faults=NO_FAULTS is bit-identical to faults=None, and neither
        an empty nor a REAL spec adds executable cache keys — fault
        tables are scan arguments, never part of the lanec key."""
        base = {d: simulate(tiny_cfg, tiny_txns, d, seed=5)
                for d in ("baseline", "venice")}
        keys0 = set(S._EXEC_CACHE)
        assert keys0  # the fault-free runs above compiled/loaded these
        for d, ref in base.items():
            res = simulate(tiny_cfg, tiny_txns, d, seed=5,
                           faults=NO_FAULTS)
            for f in PARITY_FIELDS:
                assert np.array_equal(getattr(res, f), getattr(ref, f)), \
                    (d, f)
            assert res.exec_ticks == ref.exec_ticks
            assert res.bus_hold_ticks == ref.bus_hold_ticks
            assert res.link_hold_ticks == ref.link_hold_ticks
            assert np.array_equal(res.req_failed, ref.req_failed)
        assert set(S._EXEC_CACHE) == keys0
        for d in ("baseline", "venice"):
            simulate(tiny_cfg, tiny_txns, d, seed=5,
                     faults=FaultSpec(failed_links=(0,)))
        assert set(S._EXEC_CACHE) == keys0


class TestScalarParity:
    @pytest.mark.parametrize("spec_name", tuple(SPECS))
    @pytest.mark.parametrize("design", REF_DESIGNS)
    def test_scan_pinned_against_scalar_reference(
            self, tiny_cfg, tiny_txns, design, spec_name):
        """Element-wise parity of the jitted scan vs the scalar oracle.

        seed=4 is deliberately EVEN: the planner forces odd scout seeds
        (``seeds[i] | 1``) and the reference must apply the same
        transform — an odd seed could not tell."""
        spec = SPECS[spec_name]
        res = simulate(tiny_cfg, tiny_txns, design, seed=4, faults=spec)
        ref = simulate_ref(tiny_cfg, tiny_txns, design, seed=4,
                           faults=spec)
        for f in PARITY_FIELDS:
            assert np.array_equal(np.asarray(getattr(res, f)), ref[f]), \
                (design, spec_name, f)
        assert res.bus_hold_ticks == int(ref["bus_hold"].sum())
        assert res.link_hold_ticks == int(ref["link_hold"].sum())

    def test_venice_routes_around_what_stalls_the_bus(
            self, tiny_cfg, tiny_txns):
        """One dead horizontal link: the shared-bus baseline strands the
        chips behind it (permanent failures), the fully-adaptive scout
        detours and completes everything."""
        spec = FaultSpec(failed_links=(0,))
        v = simulate(tiny_cfg, tiny_txns, "venice", seed=5, faults=spec)
        b = simulate(tiny_cfg, tiny_txns, "baseline", seed=5, faults=spec)
        assert not v.failed.any()
        assert b.failed.any()
        assert v.failure_rate() == 0.0 < b.failure_rate()


class TestMidStreamFault:
    def test_fault_on_window_boundary_pinned_scalar(self, tiny_cfg):
        """A fault arriving exactly at window 2's start: the windowed scan
        is replayed element-wise by the scalar reference through the
        capture hook, mirroring the engine's loop order (table swap ->
        execute -> rebase) with the carried state."""
        trace = gen_trace("prxy_0", 400, seed=3, footprint_bytes=1 << 20)
        span_s = float(trace["arrival_us"][-1]) * 1e-6
        window_s = span_s / 4
        spec = FaultSpec(failed_links=(0,))
        schedule = {2: spec}
        designs = ("venice", "baseline")
        cap: list = []
        sr = stream_simulate(tiny_cfg, trace, designs, seeds=4,
                             window_s=window_s, fault_schedule=schedule,
                             capture=cap)
        assert sr.n_windows >= 4
        assert [e["w"] for e in cap] == list(range(sr.n_windows))
        W = window_ticks_for(window_s)
        for i, d in enumerate(designs):
            lane = LaneRef(tiny_cfg, d)
            state = lane.initial_state(4 | 1)  # planner's odd-seed rule
            cur = None
            acc = {f: [] for f in ("completion", "wait", "conflict",
                                   "hops", "tries", "failed")}
            for e in cap:
                spec_w = _active_faults(schedule, e["w"])
                if spec_w is not cur:
                    cur = spec_w
                    lane.set_faults(spec_w)
                if e["n"]:
                    state, outs = lane.run(e["packed"], state)
                    acc["completion"].append(
                        outs["completion"] + e["w"] * W)
                    for f in ("wait", "conflict", "hops", "tries",
                              "failed"):
                        acc[f].append(outs[f])
                state = S.rebase_lane_state(state, W)
            res = sr.results[i]
            for f, col in acc.items():
                assert np.array_equal(np.asarray(getattr(res, f)),
                                      np.concatenate(col)), (d, f)
        # asymmetry: the mid-trace dead link fails baseline requests but
        # none of venice's
        assert not sr.results[0].failed.any()
        assert sr.results[1].failed.any()


class TestDegradedMode:
    def test_venice_retains_strictly_more_than_baseline(self, tiny_cfg):
        """Acceptance: >= 1 failed link per channel (count=2 kills one
        horizontal link in each of the 2 rows) — venice's throughput
        retention must strictly exceed the shared-bus baseline's."""
        spec = degraded_fault_spec(tiny_cfg, 2, "per_channel", seed=0)
        rows = {l // (tiny_cfg.cols - 1) for l in spec.failed_links}
        assert rows == {0, 1}  # every channel row lost a link
        scn = DegradedModeSweep("hm_0", fault_counts=(1, 2),
                                placement="per_channel", n_requests=160)
        rec = run_scenario(tiny_cfg, scn, ("baseline", "venice"))
        # count=1: mesh stays connected — venice completes every request
        # (graceful: only the detour hops cost throughput) while the bus
        # already fails requests behind the dead link
        assert rec["designs"]["venice"]["1"]["failure_pct"] == 0.0
        assert rec["designs"]["venice"]["1"]["retention"] >= 0.99
        assert rec["designs"]["baseline"]["1"]["failure_pct"] > 0.0
        # count=2 severs BOTH horizontal links: the 2x2 mesh itself
        # partitions, so even venice loses the unreachable chips — but it
        # must still retain strictly more than the stalled bus
        b = rec["designs"]["baseline"]["2"]
        v = rec["designs"]["venice"]["2"]
        assert v["retention"] > b["retention"]
        assert v["failure_pct"] < b["failure_pct"]
        assert rec["designs"]["baseline"]["0"]["retention"] == 1.0

    def test_placements_are_deterministic_and_in_range(self, tiny_cfg):
        for placement in ("per_channel", "spread", "clustered"):
            a = degraded_fault_spec(tiny_cfg, 2, placement, seed=1)
            b = degraded_fault_spec(tiny_cfg, 2, placement, seed=1)
            assert a == b
            assert all(l >= 0 for l in a.failed_links)
            lower_designs(tiny_cfg, ("venice",), a)  # must validate clean
        assert degraded_fault_spec(tiny_cfg, 0) is None
        with pytest.raises(ValueError):
            degraded_fault_spec(tiny_cfg, 1, "nonsense")


class TestFaultSpecValidation:
    def test_bad_values_rejected(self, tiny_cfg):
        with pytest.raises(ValueError):
            FaultSpec(retry_prob=1.5)
        with pytest.raises(ValueError):
            FaultSpec(retry_ladder=(-1,))
        with pytest.raises(ValueError):
            lower_designs(tiny_cfg, ("venice",),
                          FaultSpec(failed_links=(99,)))
        with pytest.raises(ValueError):
            lower_designs(tiny_cfg, ("venice",),
                          FaultSpec(failed_routers=(99,)))
        with pytest.raises(ValueError):
            lower_designs(tiny_cfg, ("venice",),
                          FaultSpec(failed_fcs=(5,)))

    def test_normalization_and_truthiness(self):
        assert FaultSpec(failed_links=(2, 1, 2)).failed_links == (1, 2)
        assert not FaultSpec()
        assert not FaultSpec(retry_prob=0.5)  # no ladder -> inert
        assert FaultSpec(failed_links=(0,))
        assert FaultSpec(retry_prob=0.5, retry_ladder=(100,))
