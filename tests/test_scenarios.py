"""Scenario engine: closed-loop QD sweeps, multi-tenant parity, burst scale.

The load-bearing invariant: tenant attribution is pure metadata.  A tagged
multi-tenant run must be BIT-EXACT with the untagged run of the same merged
trace, and per-tenant metrics must merge back to the untagged aggregates.
"""
import numpy as np
import pytest

from repro.ssd import bench, decompose_trace, simulate
from repro.traces.generator import mix_traces, to_pages
from repro.workloads.scenario import (
    BurstScale,
    MultiTenantMix,
    QueueDepthSweep,
    closed_loop_arrivals,
    run_scenario,
)


@pytest.fixture(autouse=True)
def _clean_caches():
    bench.clear_caches()
    yield
    bench.clear_caches()


@pytest.fixture(scope="module")
def tagged_untagged(tiny_cfg):
    """The same merged mix trace decomposed with and without tenant tags."""
    merged = mix_traces("mix3", 60, seed=1)
    merged["arrival_us"] = merged["arrival_us"] / 8.0  # intensify
    untagged = {k: v for k, v in merged.items()
                if k not in ("tenant", "tenant_names")}

    def dec(tr):
        pages = to_pages(tr, tiny_cfg.page_bytes)
        return decompose_trace(
            tiny_cfg, pages, footprint_pages=int(pages["footprint_pages"])
        )

    return dec(merged), dec(untagged)


class TestTenantParity:
    def test_attribution_is_pure_metadata(self, tiny_cfg, tagged_untagged):
        tagged, untagged = tagged_untagged
        for k in tagged:
            assert np.array_equal(tagged[k], untagged[k]), k
        a = simulate(tiny_cfg, tagged, "venice")
        b = simulate(tiny_cfg, untagged, "venice")
        # bit-exact aggregates: attribution never reaches the scan
        assert np.array_equal(a.completion, b.completion)
        assert np.array_equal(a.req_latency, b.req_latency)
        assert np.array_equal(a.req_completion, b.req_completion)
        assert a.exec_ticks == b.exec_ticks
        assert a.req_tenant is not None and b.req_tenant is None

    def test_per_tenant_metrics_merge_to_aggregate(self, tiny_cfg,
                                                   tagged_untagged):
        tagged, _ = tagged_untagged
        res = simulate(tiny_cfg, tagged, "baseline")
        tl = res.tenant_latencies()
        assert len(tl) == 2  # mix3 = prxy_0 + rsrch_0
        # merged per-tenant arrays are a permutation of the aggregate …
        assert sum(len(v) for v in tl.values()) == len(res.req_latency)
        merged = np.sort(np.concatenate(list(tl.values())))
        assert np.array_equal(merged, np.sort(res.req_latency))
        # … and so is every derived statistic (sum pinned bit-exact)
        assert merged.sum() == res.req_latency.sum()


class TestQueueDepthSweep:
    def test_closed_loop_arrivals_identity(self):
        comp = np.array([500, 300, 800, 600, 900], np.int64)  # ticks
        a = closed_loop_arrivals(comp, 2)
        # first QD requests at t=0; request k issued at completion[k-2] (us)
        assert a[0] == a[1] == 0.0
        assert a[2] == pytest.approx(5.0)  # 500 ticks = 5us
        assert a[3] == pytest.approx(5.0)  # running max keeps FIFO causal
        assert a[4] == pytest.approx(8.0)
        assert (np.diff(a) >= 0).all()
        # degenerate depths
        assert (closed_loop_arrivals(comp, 0) == 0).all()
        assert (closed_loop_arrivals(comp, 99) == 0).all()

    def test_sweep_shape_and_feedback(self, tiny_cfg):
        scn = QueueDepthSweep("proj_3", qds=(1, 16), n_requests=60, iters=2)
        out = run_scenario(tiny_cfg, scn, ("baseline", "venice"))
        assert out["qds"] == [1, 16]
        for d in ("baseline", "venice"):
            per = out["designs"][d]
            assert set(per) == {"1", "16"}
            for m in per.values():
                assert m["n_requests"] == 60
                assert 0 < m["p50_us"] <= m["p95_us"] <= m["p99_us"]
                assert m["iops"] > 0
        # deterministic: the fixed-point iteration replays identically
        again = run_scenario(tiny_cfg, scn, ("baseline", "venice"))
        assert again == out

    def test_deeper_queue_does_not_lose_throughput(self, tiny_cfg):
        """The closed-loop signature: more outstanding requests keep the
        device busier — aggregate throughput must not degrade from QD 1 to
        a saturating depth (the whole point of evaluating under depth)."""
        scn = QueueDepthSweep("proj_3", qds=(1, 64), n_requests=100, iters=3)
        out = run_scenario(tiny_cfg, scn, ("baseline",))
        per = out["designs"]["baseline"]
        assert per["64"]["iops"] >= per["1"]["iops"] * 0.95

    def test_sweep_on_mix_carries_tenants(self, tiny_cfg):
        scn = QueueDepthSweep("mix3", qds=(4,), n_requests=60, iters=1)
        out = run_scenario(tiny_cfg, scn, ("baseline",))
        m = out["designs"]["baseline"]["4"]
        assert set(m["tenants"]) == {"prxy_0", "rsrch_0"}

    def test_round_merged_sweeps_identical_to_sequential(self, tiny_cfg):
        """Round-merging several sweeps into one planner batch per
        feedback round (the tail-phase dispatch collapse) must be
        BIT-identical to running the sweeps one after another — the cells
        are independent fixed-point iterations, merging is scheduling
        only.  Also covers unequal iteration counts (the shorter sweep
        stops updating while the longer one keeps iterating)."""
        from repro.workloads.scenario import run_queue_depth_sweeps

        a = QueueDepthSweep("proj_3", qds=(1, 16), n_requests=60, iters=2)
        b = QueueDepthSweep("hm_0", qds=(4,), n_requests=40, iters=3,
                            seed=1)
        designs = ("baseline", "venice")
        merged = run_queue_depth_sweeps(tiny_cfg, (a, b), designs)
        solo = [run_scenario(tiny_cfg, a, designs),
                run_scenario(tiny_cfg, b, designs)]
        assert merged == solo


class TestMultiTenantAndBurst:
    def test_multi_tenant_fairness_record(self, tiny_cfg):
        scn = MultiTenantMix(("mix3",), n_requests_each=50, seed=1)
        out = run_scenario(tiny_cfg, scn, ("baseline", "venice"))
        assert out["tenants"] == ["prxy_0", "rsrch_0"]
        assert out["accel_factor"] >= 1.0
        for d, rec in out["designs"].items():
            assert 0 < rec["fairness"] <= 1.0
            assert set(rec["slowdowns"]) == {"prxy_0", "rsrch_0"}
            for t, sd in rec["slowdowns"].items():
                assert sd["mean"] > 0
                assert rec["tenants"][t]["slowdown_vs_solo"] == sd["mean"]
        # the audit satellite: the accelerate factor is recorded in PERF
        assert f"mix3/{tiny_cfg.name}" in bench.PERF["accel"]
        rec = bench.PERF["accel"][f"mix3/{tiny_cfg.name}"]
        assert rec["factor"] == out["accel_factor"]
        assert rec["offered_util"] > 0

    def test_ad_hoc_tenant_tuple(self, tiny_cfg):
        scn = MultiTenantMix(("prxy_0", "rsrch_0", "mds_0"),
                             n_requests_each=40, seed=2)
        out = run_scenario(tiny_cfg, scn, ("baseline",))
        assert out["mix"] == "prxy_0+rsrch_0+mds_0"
        assert len(out["designs"]["baseline"]["slowdowns"]) == 3

    def test_ingested_trace_as_tenant(self, tiny_cfg):
        """A registered real trace mixes with a synthetic tenant."""
        import os

        from repro.traces.generator import CUSTOM_TRACES
        from repro.workloads import ingest_file

        fixture = os.path.join(os.path.dirname(__file__), "data",
                               "msr_sample.csv")
        try:
            name = ingest_file(fixture, name="test_mix_fx")
            scn = MultiTenantMix((name, "proj_3"), n_requests_each=40,
                                 seed=3)
            out = run_scenario(tiny_cfg, scn, ("baseline",))
            assert out["tenants"] == [name, "proj_3"]
            assert set(out["designs"]["baseline"]["slowdowns"]) \
                == {name, "proj_3"}
        finally:
            CUSTOM_TRACES.pop("test_mix_fx", None)

    def test_burst_scale_records_offered_util(self, tiny_cfg):
        scn = BurstScale("hm_0", factors=(1.0, 8.0), n_requests=50)
        out = run_scenario(tiny_cfg, scn, ("baseline",))
        assert out["offered_util_base"] > 0
        per = out["designs"]["baseline"]
        assert set(per) == {"1.0", "8.0"}
        # 8x acceleration compresses the replay window: throughput rises
        assert per["8.0"]["iops"] > per["1.0"]["iops"]

    def test_unknown_scenario_rejected(self, tiny_cfg):
        with pytest.raises(TypeError):
            run_scenario(tiny_cfg, object(), ("baseline",))
