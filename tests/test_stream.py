"""Chunked streaming engine: window-boundary state-carry pinned bit-exact.

Every test compares a windowed ``stream_simulate`` replay against the
single-window oracle (monolithic ``decompose_trace`` + ``sim.simulate`` of
the same trace) — the contract is bit-identity of the per-request surface
(latencies, completions), the per-transaction completion multiset, the
resource-hold totals, and the carried FTL state.  The boundary cases the
tentpole calls out get their own fixtures: GC triggered exactly at a
window boundary, an in-flight transaction spanning the boundary, and an
empty window mid-trace.
"""
import os

import numpy as np
import pytest

from repro.ssd import sim as S
from repro.ssd.config import TICK_NS, perf_optimized
from repro.ssd.ftl import decompose_trace
from repro.ssd.stream import stream_simulate, window_ticks_for
from repro.traces.generator import gen_trace, to_pages
from repro.workloads import load_trace

FIXTURE = os.path.join(os.path.dirname(__file__), "data", "msr_sample.csv")

# FTL state arrays + scalars that must carry bit-exactly across windows
FTL_STATE = ("l2p", "p2l", "valid", "written", "erase_count", "is_free",
             "open_block", "next_page")
FTL_SCALARS = ("_stripe", "gc_events", "gc_page_moves",
               "read_precond_pages", "read_precond_gc_txns")


def _assert_ftl_identical(a, b):
    for f in FTL_STATE:
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    for f in FTL_SCALARS:
        assert getattr(a, f) == getattr(b, f), f


def _mono(cfg, trace, design, overprovision=1.28):
    pages = to_pages(trace, cfg.page_bytes) if "offset_page" not in trace \
        else trace
    txns = decompose_trace(cfg, pages, int(pages["footprint_pages"]),
                           overprovision=overprovision)
    return txns, S.simulate(cfg, txns, design, seed=0)


def _assert_parity(stream_res, mono_res):
    """Windowed vs monolithic, bit for bit.

    The concatenation of per-window execution batches IS the monolithic
    nominal order (nominal-time deferral + stable decomposition-order
    ties), so every per-transaction array compares element-wise — and the
    float energy reductions, summed in the same element order, match
    exactly too."""
    assert np.array_equal(stream_res.completion,
                          mono_res.completion.astype(np.int64))
    assert np.array_equal(stream_res.latency,
                          mono_res.latency.astype(np.int64))
    assert np.array_equal(stream_res.wait, mono_res.wait)
    assert np.array_equal(stream_res.conflict, mono_res.conflict)
    assert np.array_equal(stream_res.hops, mono_res.hops)
    assert np.array_equal(stream_res.tries, mono_res.tries)
    assert np.array_equal(stream_res.misroutes, mono_res.misroutes)
    assert np.array_equal(stream_res.req_latency, mono_res.req_latency)
    assert np.array_equal(stream_res.req_completion,
                          mono_res.req_completion)
    assert stream_res.exec_ticks == mono_res.exec_ticks
    assert stream_res.bus_hold_ticks == mono_res.bus_hold_ticks
    assert stream_res.link_hold_ticks == mono_res.link_hold_ticks
    assert stream_res.flash_energy_j == mono_res.flash_energy_j
    assert stream_res.transfer_energy_j == mono_res.transfer_energy_j
    assert stream_res.static_energy_j == mono_res.static_energy_j


@pytest.fixture(scope="module")
def cfg():
    return perf_optimized(rows=2, cols=2, pages_per_block=64)


class TestPrefixParity:
    @pytest.mark.parametrize("design", ["baseline", "venice"])
    def test_single_window_prefix_bit_identical(self, cfg, design):
        """A prefix that fits one window replays bit-identically to the
        monolithic run — same commit order, so even the float energy sums
        match exactly."""
        trace = gen_trace("prxy_0", 400, seed=3, footprint_bytes=1 << 20)
        span_s = float(trace["arrival_us"][-1]) * 1e-6
        txns, mono = _mono(cfg, trace, design)
        sr = stream_simulate(cfg, trace, (design,), seeds=0,
                             window_s=max(2 * span_s, 1.0))
        assert sr.n_windows == 1
        r = sr.results[0]
        assert np.array_equal(r.completion,
                              mono.completion.astype(np.int64))
        assert np.array_equal(r.latency, mono.latency.astype(np.int64))
        assert np.array_equal(r.wait, mono.wait)
        assert np.array_equal(r.conflict, mono.conflict)
        assert np.array_equal(r.hops, mono.hops)
        _assert_parity(r, mono)
        _assert_ftl_identical(sr.ftl, txns.ftl)

    def test_msr_fixture_windowed_replay(self, cfg):
        """The bundled real-trace fixture, windowed vs monolithic."""
        trace = load_trace(FIXTURE)
        span_s = float(trace["arrival_us"][-1]) * 1e-6
        txns, mono = _mono(cfg, trace, "venice")
        sr = stream_simulate(cfg, trace, ("venice",), seeds=0,
                             window_s=span_s / 4)
        assert sr.n_windows >= 4
        _assert_parity(sr.results[0], mono)
        _assert_ftl_identical(sr.ftl, txns.ftl)


class TestBoundaryCarry:
    def test_multi_window_multi_design(self, cfg):
        """8-window replay of a synthetic workload, both cost classes
        (static-routed baseline and scout-routed venice) carried."""
        trace = gen_trace("prxy_0", 800, seed=3, footprint_bytes=1 << 20)
        span_s = float(trace["arrival_us"][-1]) * 1e-6
        sr = stream_simulate(cfg, trace, ("baseline", "venice"), seeds=0,
                             window_s=span_s / 7)
        assert sr.n_windows >= 8
        txns, _ = _mono(cfg, trace, "baseline")
        for i, design in enumerate(("baseline", "venice")):
            _assert_parity(sr.results[i],
                           S.simulate(cfg, txns, design, seed=0))
        _assert_ftl_identical(sr.ftl, txns.ftl)

    def test_gc_exactly_at_window_boundary(self):
        """The window edge lands exactly on a GC transaction's arrival
        tick: the carried FTL must resume mid-GC-pressure (free-block
        state, wear ordering, epoch split) bit-exactly."""
        cfg = perf_optimized(rows=2, cols=2, pages_per_block=16)
        trace = gen_trace("prxy_0", 2500, seed=5, footprint_bytes=1 << 20)
        pages = to_pages(trace, cfg.page_bytes)
        txns = decompose_trace(cfg, pages, int(pages["footprint_pages"]),
                               overprovision=3.0)
        assert txns.ftl.gc_events > 100  # the recipe really does GC
        t = np.asarray(txns["arrival"], np.int64)
        gc_ticks = t[np.asarray(txns["req"]) < 0]
        span = int(t.max())
        # a GC arrival near mid-trace becomes the window boundary
        t_gc = int(gc_ticks[np.argmin(np.abs(gc_ticks - span // 2))])
        assert 0 < t_gc < span
        window_s = t_gc * TICK_NS * 1e-9
        assert window_ticks_for(window_s) == t_gc  # boundary ON the GC txn
        sr = stream_simulate(cfg, trace, ("venice",), seeds=0,
                             window_s=window_s, overprovision=3.0)
        assert sr.n_windows >= 2
        _assert_parity(sr.results[0], S.simulate(cfg, txns, "venice",
                                                 seed=0))
        _assert_ftl_identical(sr.ftl, txns.ftl)

    def test_inflight_transaction_spans_boundary(self, cfg):
        """A same-plane read backlog still in service when the window ends:
        the carried occupancy must delay the next window's requests by
        exactly the residual, and the spanning completions land past the
        boundary."""
        W_s = 0.001  # 1 ms windows
        W = window_ticks_for(W_s)
        n0, n1 = 40, 10
        # dense same-offset reads just before the boundary, then more on
        # the same plane right after it — all serialized through one plane
        arrival = np.concatenate([
            990.0 + 0.2 * np.arange(n0),  # [990 us, 998 us)
            1000.0 + 0.2 * np.arange(n1),  # just past the boundary
        ])
        n = n0 + n1
        trace = {
            "name": "t_span",
            "arrival_us": arrival,
            "is_read": np.ones(n, bool),
            "offset_bytes": np.zeros(n, np.int64),
            "size_bytes": np.full(n, 4096, np.int64),
            "footprint_bytes": 1 << 20,
        }
        txns, mono = _mono(cfg, trace, "venice")
        sr = stream_simulate(cfg, trace, ("venice",), seeds=0,
                             window_s=W_s)
        assert sr.n_windows >= 2
        assert sr.windows[0]["n_requests"] == n0
        # the backlog really does span the cut: part of window 0's arrivals
        # commit nominally past the boundary, so they are re-injected into
        # window 1's batch (n_txns conserved, window 1 executing more than
        # its own arrivals) and the completions land past the boundary
        assert sr.windows[1]["n_txns"] > n1
        assert sum(w["n_txns"] for w in sr.windows) == len(mono.completion)
        assert int(sr.results[0].completion.max()) > W
        # ... and the carried residual reproduces the monolithic run
        _assert_parity(sr.results[0], mono)
        _assert_ftl_identical(sr.ftl, txns.ftl)

    def test_empty_window_mid_trace(self, cfg):
        """A silent interior window: decompose/dispatch skip it, but the
        carried state still ages by the window span."""
        rng = np.random.default_rng(7)
        arrival = np.concatenate([
            np.sort(rng.uniform(0.0, 0.3e6, 120)),  # [0, 0.3 s)
            np.sort(rng.uniform(1.2e6, 1.4e6, 80)),  # [1.2 s, 1.4 s)
        ])
        n = len(arrival)
        trace = {
            "name": "t_gap",
            "arrival_us": arrival,
            "is_read": rng.uniform(size=n) < 0.7,
            "offset_bytes": (rng.integers(0, 200, n) * 4096).astype(
                np.int64),
            "size_bytes": np.full(n, 4096, np.int64),
            "footprint_bytes": 1 << 20,
        }
        txns, mono = _mono(cfg, trace, "venice")
        sr = stream_simulate(cfg, trace, ("venice",), seeds=0,
                             window_s=0.5)
        assert sr.n_windows == 3
        assert [w["n_requests"] for w in sr.windows] == [120, 0, 80]
        assert sr.windows[1]["n_txns"] == 0
        _assert_parity(sr.results[0], mono)
        _assert_ftl_identical(sr.ftl, txns.ftl)


class TestPipeline:
    def test_compile_wait_flat_after_first_window(self, cfg):
        """Steady state is execution-bound: every window after the first
        reuses the same lanec executable (capacity high-water bucketing),
        so the per-window compile wait collapses to ~0."""
        trace = gen_trace("prxy_0", 800, seed=3, footprint_bytes=1 << 20)
        span_s = float(trace["arrival_us"][-1]) * 1e-6
        sr = stream_simulate(cfg, trace, ("venice",), seeds=0,
                             window_s=span_s / 7)
        assert sr.n_windows >= 8
        for w in sr.windows[1:]:
            assert w["compile_wait_s"] < 0.05, w
        assert sr.throughput_flatness() > 0.0

    def test_window_guard_rejects_beyond_budget_spans(self):
        with pytest.raises(ValueError, match="tick budget"):
            window_ticks_for(30.0)  # > int32 minus headroom
        with pytest.raises(ValueError, match="tick budget"):
            window_ticks_for(0.0)

    def test_stream_replay_scenario(self, cfg):
        """End-to-end through the scenario engine: a streaming-only
        registered trace replays by name via StreamReplay."""
        from repro.traces.generator import CUSTOM_TRACES, register_trace
        from repro.workloads import StreamReplay, run_scenario

        tr = dict(gen_trace("hm_0", 120, seed=11))
        a = np.asarray(tr["arrival_us"], np.float64)
        tr["arrival_us"] = a * (60e6 / max(float(a[-1]), 1.0))  # 60 s span
        register_trace("test_stream60", tr)
        try:
            assert CUSTOM_TRACES["test_stream60"]["streaming_only"] is True
            rec = run_scenario(cfg, StreamReplay("test_stream60",
                                                 window_s=10.0),
                               ("venice",))
        finally:
            del CUSTOM_TRACES["test_stream60"]
        assert rec["scenario"] == "stream_replay"
        assert rec["n_windows"] >= 6
        assert rec["n_requests"] == 120
        assert sum(w["n_requests"] for w in rec["windows"]) == 120
        assert rec["designs"]["venice"]["n_requests"] == 120
