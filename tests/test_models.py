"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
asserting output shapes and no NaNs; plus decode-vs-full consistency and
kernel-grade numerics for MoE and Mamba2."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, applicable_shapes, get_smoke, shape_skip_reason
from repro.models.lm import (
    init_decode_cache,
    init_lm,
    lm_apply,
    lm_decode_step,
    lm_loss,
)


def _batch_for(cfg, B=2, S=32, seed=0):
    rs = np.random.RandomState(seed)
    batch = {"tokens": jnp.asarray(rs.randint(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["images"] = jnp.asarray(
            rs.randn(B, cfg.n_img_tokens, cfg.vision_dim), jnp.float32
        )
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rs.randn(B, cfg.n_audio_frames, cfg.d_model), jnp.float32
        )
    return batch


# Largest smoke configs dominate tier-1 wall-clock; they run in the slow
# lane (CI main pushes / `pytest -m slow`).  Every arch keeps fast-tier
# coverage through test_smoke_decode_step.
_HEAVY_ARCHS = {"llama-3.2-vision-90b", "deepseek-v2-lite-16b", "zamba2-2.7b"}


def _arch_params(archs):
    return [
        pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_ARCHS else a
        for a in archs
    ]


@pytest.mark.parametrize("arch", _arch_params(sorted(ARCHS)))
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 64
    batch = _batch_for(cfg, B, S)
    logits, aux = jax.jit(lambda p, b: lm_apply(p, cfg, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    (loss, metrics), grads = jax.jit(
        lambda p, b: jax.value_and_grad(lambda q: lm_loss(q, cfg, b),
                                        has_aux=True)(p)
    )(params, batch)
    assert np.isfinite(float(loss))
    gnorm = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_decode_step(arch):
    cfg = get_smoke(arch)
    if not applicable_shapes(arch):  # pragma: no cover
        pytest.skip("no decode shapes")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    B, S_max = 2, 16
    cache = init_decode_cache(cfg, B, S_max)
    rs = np.random.RandomState(1)
    if cfg.family == "vlm":
        cache["img"] = jnp.asarray(rs.randn(B, cfg.n_img_tokens, cfg.d_model),
                                   jnp.float32)
    if cfg.family == "audio":
        cache["enc"] = jnp.asarray(rs.randn(B, cfg.n_audio_frames, cfg.d_model),
                                   jnp.float32)
    tok = jnp.asarray(rs.randint(0, cfg.vocab, (B,)), jnp.int32)
    step = jax.jit(lambda p, c, t, pos: lm_decode_step(p, cfg, c, t, pos))
    logits, cache = step(params, cache, tok, 3)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", [
    "qwen2-0.5b",
    # decode parity per family is kept fast via qwen2 (attention) and
    # test_mamba2_decode_matches_full (SSM); the rest run in the slow lane
    pytest.param("gemma2-2b", marks=pytest.mark.slow),
    pytest.param("granite-3-2b", marks=pytest.mark.slow),
    pytest.param("mamba2-130m", marks=pytest.mark.slow),
])
def test_decode_matches_full_forward(arch):
    """Greedy decode over a prompt must reproduce the full forward logits."""
    cfg = get_smoke(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 1, 12
    batch = _batch_for(cfg, B, S, seed=3)
    full_logits, _ = lm_apply(params, cfg, batch)

    cache = init_decode_cache(cfg, B, S)
    outs = []
    for pos in range(S):
        tok = batch["tokens"][:, pos]
        logits, cache = lm_decode_step(params, cfg, cache, tok, pos)
        outs.append(logits)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_moe_capacity_dispatch_matches_dense_reference():
    from repro.models.moe import MoEDims, init_moe, moe_apply, moe_ref_dense

    md = MoEDims(d_model=32, d_ff_expert=64, n_experts=8, top_k=2, n_shared=1,
                 capacity_factor=8.0)  # big capacity: no token drops
    p = init_moe(jax.random.PRNGKey(0), md, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    got, aux = moe_apply(p, md, x)
    want = moe_ref_dense(p, md, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) > 0


def test_mamba2_chunked_matches_recurrent():
    from repro.models.mamba import MambaDims, init_mamba2, mamba2_apply, mamba2_ref

    md = MambaDims(d_model=64, d_state=16, head_dim=32, chunk=16)
    p = init_mamba2(jax.random.PRNGKey(0), md, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64), jnp.float32) * 0.5
    got = mamba2_apply(p, md, x)
    want = mamba2_ref(p, md, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_mamba2_decode_matches_full():
    from repro.models.mamba import (
        MambaDims, init_mamba2, init_mamba2_cache, mamba2_ref, mamba2_step,
    )

    md = MambaDims(d_model=32, d_state=8, head_dim=16, chunk=8)
    p = init_mamba2(jax.random.PRNGKey(0), md, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32), jnp.float32) * 0.5
    full = mamba2_ref(p, md, x)
    cache = init_mamba2_cache(md, 1, jnp.float32)
    outs = []
    for t in range(16):
        y, cache = mamba2_step(p, md, x[:, t:t + 1], cache)
        outs.append(y[:, 0])
    got = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_long500k_skip_rules():
    assert shape_skip_reason("mamba2-130m", "long_500k") is None
    assert shape_skip_reason("zamba2-2.7b", "long_500k") is None
    for arch in ["qwen2-0.5b", "gemma2-2b", "mistral-large-123b",
                 "kimi-k2-1t-a32b", "whisper-base"]:
        assert shape_skip_reason(arch, "long_500k") is not None


def test_full_configs_match_assignment():
    c = ARCHS["kimi-k2-1t-a32b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv) == (61, 7168, 64, 8)
    assert (c.moe_experts, c.moe_top_k, c.vocab) == (384, 8, 163840)
    c = ARCHS["zamba2-2.7b"]
    assert (c.n_layers, c.d_model, c.ssm_state) == (54, 2560, 64)
    c = ARCHS["gemma2-2b"]
    assert (c.attn_softcap, c.vocab, c.d_ff) == (50.0, 256000, 9216)
    c = ARCHS["mistral-large-123b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff) == (88, 12288, 96, 28672)


def test_gqa_grouped_matches_expanded():
    """§Perf H2: the grouped-GQA einsum must be numerically identical to the
    head-expanded formulation."""
    import dataclasses

    from repro.models.lm import init_lm, lm_apply

    cfg = get_smoke("granite-3-2b")
    cfgg = dataclasses.replace(cfg, gqa_grouped=True)
    params = init_lm(jax.random.PRNGKey(2), cfg)
    batch = _batch_for(cfg, 2, 32, seed=7)
    a, _ = lm_apply(params, cfg, batch)
    b, _ = lm_apply(params, cfgg, batch)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_ssd_bf16_close_to_f32():
    """§Perf H1: bf16 intra-chunk SSD stays close to the f32 oracle."""
    from repro.models.mamba import MambaDims, init_mamba2, mamba2_apply, mamba2_ref

    md32 = MambaDims(d_model=64, d_state=16, head_dim=32, chunk=16)
    md16 = MambaDims(d_model=64, d_state=16, head_dim=32, chunk=16,
                     ssd_bf16=True)
    p = init_mamba2(jax.random.PRNGKey(0), md32, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64), jnp.float32) * 0.5
    ref = mamba2_ref(p, md32, x)
    got = mamba2_apply(p, md16, x)
    err = np.abs(np.asarray(got) - np.asarray(ref))
    rel = err.max() / (np.abs(np.asarray(ref)).max() + 1e-9)
    assert rel < 0.05, rel
