"""Trace generator: Table 2/3 statistics must converge to the paper's values."""
import numpy as np
import pytest

from repro.traces import MIXES, WORKLOADS, gen_trace, mix_traces
from repro.traces.generator import to_pages

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYP = True
except Exception:  # pragma: no cover
    HAVE_HYP = False


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_table2_statistics(name):
    read_pct, avg_kb, avg_iat = WORKLOADS[name]
    tr = gen_trace(name, 20000, seed=0)
    assert np.mean(tr["is_read"]) == pytest.approx(read_pct / 100.0, abs=0.02)
    assert tr["size_bytes"].mean() / 1024 == pytest.approx(avg_kb, rel=0.05)
    iat = np.diff(tr["arrival_us"], prepend=0.0)
    assert iat.mean() == pytest.approx(avg_iat, rel=0.08)


def _seq_stream_offsets_ref(off, sz_align, is_seq, stream_of, n_align, n_streams):
    """The pre-vectorization per-request loop, verbatim (pin reference)."""
    off = off.copy()
    streams = np.zeros((n_streams,), dtype=np.int64)
    for i in range(len(off)):
        if is_seq[i]:
            off[i] = streams[stream_of[i]] % n_align
        streams[stream_of[i]] = off[i] + sz_align[i]
    return off


@pytest.mark.parametrize("name,seed", [("usr_0", 0), ("src2_1", 3),
                                       ("prxy_0", 7), ("ssd-00", 11)])
def test_seq_stream_vectorization_pins_scalar_loop(name, seed):
    """The grouped-cumsum stream resolver must reproduce the scalar loop's
    offsets bit-for-bit (same RandomState draws, same cursor semantics)."""
    from repro.traces.generator import _ALIGN, _seq_stream_offsets

    n = 4000
    rs = np.random.RandomState(seed)
    n_align = 32768
    n_streams = 8
    off = rs.randint(0, n_align, n).astype(np.int64)
    sz = rs.randint(1, 200, n).astype(np.int64)
    is_seq = rs.rand(n) < 0.5
    stream_of = rs.randint(0, n_streams, n)
    got = _seq_stream_offsets(off, sz, is_seq, stream_of, n_align)
    want = _seq_stream_offsets_ref(off, sz, is_seq, stream_of, n_align,
                                   n_streams)
    assert np.array_equal(got, want)
    # and through the public generator (end-to-end determinism of the path)
    tr = gen_trace(name, 1500, seed=seed)
    assert (tr["offset_bytes"] >= 0).all()
    assert (tr["offset_bytes"] < tr["footprint_bytes"]).all()
    assert (tr["offset_bytes"] % _ALIGN == 0).all()


def test_traces_are_deterministic():
    a = gen_trace("hm_0", 500, seed=9)
    b = gen_trace("hm_0", 500, seed=9)
    assert np.array_equal(a["offset_bytes"], b["offset_bytes"])
    assert np.array_equal(a["arrival_us"], b["arrival_us"])


def test_offsets_within_footprint():
    tr = gen_trace("usr_0", 5000, seed=1)
    assert (tr["offset_bytes"] >= 0).all()
    assert (tr["offset_bytes"] < tr["footprint_bytes"]).all()
    assert (tr["size_bytes"] % 4096 == 0).all()


@pytest.mark.parametrize("mix", sorted(MIXES))
def test_mixes_overlay_and_sort(mix):
    tr = mix_traces(mix, 500, seed=0)
    assert (np.diff(tr["arrival_us"]) >= 0).all()
    assert len(tr["arrival_us"]) >= 500  # fast tenants contribute more
    # mixes have higher intensity than any constituent (Table 3)
    iat = np.diff(tr["arrival_us"]).mean()
    assert iat < min(WORKLOADS[w][2] for w in MIXES[mix])


def test_to_pages_covers_request():
    tr = gen_trace("web_1", 300, seed=0)
    pg = to_pages(tr, 16384)
    # every request covers its byte range
    cover = pg["n_pages"] * 16384
    assert (cover >= tr["size_bytes"]).all()
    assert (pg["n_pages"] >= 1).all()


if HAVE_HYP:

    @settings(max_examples=25, deadline=None)
    @given(
        name=st.sampled_from(sorted(WORKLOADS)),
        n=st.integers(1, 2000),
        seed=st.integers(0, 10000),
        page=st.sampled_from([4096, 16384]),
    )
    def test_property_trace_wellformed(name, n, seed, page):
        tr = gen_trace(name, n, seed=seed)
        assert len(tr["arrival_us"]) == n
        assert (np.diff(tr["arrival_us"]) >= 0).all()
        pg = to_pages(tr, page)
        assert (pg["offset_page"] * page < tr["footprint_bytes"]).all()
