"""Observability: flight-recorder reconstruction, trace schema, registry.

The load-bearing invariants:

* **Zero overhead off** — recording changes nothing: SimResult arrays are
  bit-identical with the recorder armed or disarmed (the scan carries no
  new state; reconstruction is post-hoc numpy).
* **Golden trace schema** — an exported trace is valid Chrome-trace-event
  JSON: nondecreasing timestamps, every B matched by an E in LIFO order,
  one ``cat="txn"`` X slice per recorded transaction.
* **Streamed == monolithic** — the flight-recorder run accumulated across
  stream windows (absolute-tick rebased) is array-identical to the run
  recorded from the monolithic planner pass of the same trace.
* **Per-run PERF deltas** — scenario engines publish the counter delta of
  their own run (``last_run_perf``), so back-to-back runs report
  independent (not cumulative) work.
"""
import json
import os
import warnings

import numpy as np
import pytest

from repro import obs
from repro.obs import events as obs_events
from repro.obs import spans as obs_spans
from repro.obs.export import DEVICE_PID0, HARNESS_PID, TraceBuilder, validate_trace
from repro.obs.heatmap import bucket_matrix, run_heatmaps
from repro.obs.registry import MetricsRegistry
from repro.ssd import bench, decompose_trace
from repro.ssd.sweep_plan import execute_sim_runs
from repro.traces.generator import gen_trace, to_pages

FIXTURE = os.path.join(os.path.dirname(__file__), "data", "msr_sample.csv")


@pytest.fixture(autouse=True)
def _clean_slate():
    """Every test starts untraced with cold run caches and leaves no
    tracer behind for the rest of the tier."""
    obs.disable_tracing()
    bench.clear_caches()
    yield
    obs.disable_tracing()
    bench.clear_caches()


def _run(cfg, txns, designs, seed=7):
    return execute_sim_runs(
        [(cfg, txns, tuple(designs), (seed,) * len(designs), "auto")]
    )[0]


# ---------------------------------------------------------------------------
# layer 2: metrics registry semantics
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_view_reset_snapshot_delta(self):
        reg = MetricsRegistry()
        reg.counter("hits")
        reg.timer("t_s")
        reg.object("groups", [])
        view = reg.view()
        view["hits"] += 2
        view["t_s"] += 0.5
        view["groups"].append("g0")
        snap = view.snapshot()
        view["hits"] += 3
        assert view.delta(snap) == {"hits": 3, "t_s": 0.0}
        alias = view  # reset is in place: aliases keep observing the view
        view.reset()
        assert alias is view and alias["hits"] == 0 and alias["t_s"] == 0.0
        assert alias["groups"] == []

    def test_redeclare_kind_conflict(self):
        reg = MetricsRegistry()
        reg.counter("x")
        reg.counter("x")  # same kind: idempotent
        with pytest.raises(ValueError):
            reg.timer("x")

    def test_bench_perf_is_registry_backed(self):
        # the historical keys survive the registry conversion — the
        # BENCH_*.json schema reads these directly
        for key in ("ftl_s", "sim_s", "compile_s", "exec_s", "groups",
                    "xc_hits", "stream_windows", "kernel_backends",
                    "phase", "accel", "ingest_skipped_rows"):
            assert key in bench.PERF, key
        snap = bench.PERF.snapshot()
        bench.PERF["decomp_hits"] += 1
        assert bench.PERF.delta(snap)["decomp_hits"] == 1
        bench.PERF["decomp_hits"] -= 1


# ---------------------------------------------------------------------------
# layer 1: flight recorder
# ---------------------------------------------------------------------------


class TestFlightRecorder:
    def test_fail_timeout_mirrors_sim(self):
        from repro.ssd import sim as S

        assert obs_events.FAIL_TIMEOUT == int(S.FAIL_TIMEOUT)

    def test_zero_overhead_off_bit_identity(self, tiny_cfg, tiny_txns):
        """Arming the recorder must not change a single output bit."""
        designs = ("baseline", "venice")
        off = _run(tiny_cfg, tiny_txns, designs)
        bench.clear_caches()
        obs.enable_tracing()
        on = _run(tiny_cfg, tiny_txns, designs)
        rec = obs_events.RECORDER
        assert rec is not None and len(rec.finalized_runs()) == len(designs)
        for a, b in zip(off, on):
            assert np.array_equal(a.completion, b.completion)
            assert np.array_equal(a.latency, b.latency)
            assert np.array_equal(a.wait, b.wait)
            assert np.array_equal(a.conflict, b.conflict)
            assert np.array_equal(a.hops, b.hops)
            assert a.exec_ticks == b.exec_ticks
            assert a.flash_energy_j == b.flash_energy_j

    def test_reconstruction_identity_static(self, tiny_cfg, tiny_txns):
        """completion == t0 + fc_stall + wait + d0 + op + d1, exactly."""
        obs.enable_tracing()
        _run(tiny_cfg, tiny_txns, ("baseline",))
        (run,) = obs_events.RECORDER.finalized_runs()
        tl = obs_events.derive_timeline(run)
        ph = tl["phases"]
        recon = (tl["t0"] + ph["fc_stall"] + ph["wait"] + ph["cmd_data"]
                 + ph["flash"] + ph["read_xfer"])
        ok = ~run["failed"]
        assert np.array_equal(recon[ok], run["completion"][ok])
        assert (tl["queue"] >= 0).all()
        # fixed-FC lane: no FC-availability stall outside ``wait``
        assert (ph["fc_stall"] == 0).all()

    def test_reconstruction_scout_circuit_bounds(self, tiny_cfg, tiny_txns):
        obs.enable_tracing()
        _run(tiny_cfg, tiny_txns, ("venice",))
        (run,) = obs_events.RECORDER.finalized_runs()
        assert run["is_scout"]
        tl = obs_events.derive_timeline(run)
        ((t_resv, commit_end, mask),) = [tl["occ"][0]]
        ok = ~run["failed"]
        assert np.array_equal(mask, ok)
        assert (t_resv[ok] >= tl["t0"][ok]).all()
        assert (commit_end[ok] <= run["completion"][ok]).all()


# ---------------------------------------------------------------------------
# trace export: golden schema + stream/monolithic identity
# ---------------------------------------------------------------------------


class TestTraceExport:
    def test_golden_schema(self, tiny_cfg, tiny_txns, tmp_path):
        obs.enable_tracing()
        designs = ("baseline", "venice")
        _run(tiny_cfg, tiny_txns, designs)
        with obs_spans.span("phase", "unit-test"):
            with obs_spans.span("dispatch", "group:test"):
                pass
        path = str(tmp_path / "t.trace.json")
        info = obs.export_trace(path, heatmap_csv=str(tmp_path / "h.csv"))
        summary = validate_trace(path)  # raises on any schema violation
        n_txns = len(tiny_txns["arrival"])
        assert summary["n_txn"] == n_txns * len(designs)
        assert info["n_device_pids"] == len(designs)
        # the planner emits its own dispatch spans on top of the two
        # explicit ones; pairs always balance
        assert summary["counts"]["B"] == summary["counts"]["E"] >= 2
        with open(path) as fh:
            doc = json.load(fh)
        pids = {e["pid"] for e in doc["traceEvents"] if e["ph"] != "M"}
        assert HARNESS_PID in pids
        assert {DEVICE_PID0, DEVICE_PID0 + 1} <= pids
        # heatmap CSV: header + at least one nonzero utilization cell
        lines = (tmp_path / "h.csv").read_text().strip().split("\n")
        assert lines[0] == ("run,design,metric,resource,bucket,"
                            "bucket_start_us,value")
        assert len(lines) > 1

    def test_cli_validator(self, tiny_cfg, tiny_txns, tmp_path):
        from repro.obs.export import main as validate_main

        obs.enable_tracing()
        _run(tiny_cfg, tiny_txns, ("venice",))
        path = str(tmp_path / "t.trace.json")
        obs.export_trace(path)
        assert validate_main([path]) == 0
        bad = tmp_path / "bad.json"
        bad.write_text('{"traceEvents": []}')
        assert validate_main([str(bad)]) == 1

    def test_be_tie_ordering_survives_sort(self):
        """Spans sharing boundary timestamps still nest LIFO after the
        global ts sort (the _k secondary key)."""
        tracer = obs_spans.SpanTracer()
        tracer.complete("t", "outer", 100.0, 50.0)
        tracer.complete("t", "inner", 100.0, 50.0)  # identical bounds
        tracer.complete("t", "next", 150.0, 10.0)  # starts where both end
        b = TraceBuilder()
        b.add_harness_spans(tracer.drain())
        import tempfile

        with tempfile.NamedTemporaryFile("w", suffix=".json",
                                         delete=False) as fh:
            path = fh.name
        try:
            b.write(path)
            validate_trace(path)
        finally:
            os.unlink(path)

    def test_streamed_trace_identical_to_monolithic(self, tmp_path):
        """The stream-accumulated run (absolute-tick rebased windows) is
        array-identical to the monolithic recording of the same trace."""
        from repro.ssd.config import perf_optimized
        from repro.ssd.stream import stream_simulate
        from repro.workloads import load_trace

        cfg = perf_optimized(rows=2, cols=2, pages_per_block=64)
        trace = load_trace(FIXTURE)
        span_s = float(trace["arrival_us"][-1]) * 1e-6

        obs.enable_tracing()
        pages = to_pages(trace, cfg.page_bytes)
        txns = decompose_trace(cfg, pages, int(pages["footprint_pages"]))
        _run(cfg, txns, ("venice",), seed=0)
        stream_simulate(cfg, trace, ("venice",), seeds=0,
                        window_s=span_s / 4)

        runs = obs_events.RECORDER.finalized_runs()
        mono = next(r for r in runs if r["label"].startswith("run"))
        streamed = next(r for r in runs if r["label"].startswith("stream"))
        assert streamed["n"] == mono["n"] > 0
        for f in obs_events._ARRAY_FIELDS:
            assert np.array_equal(mono[f], streamed[f]), f
        assert mono["scalars"] == streamed["scalars"]
        # and the rendered device events agree too
        path_m = str(tmp_path / "m.json")
        bm = TraceBuilder()
        bm.add_device_run(mono)
        bm.write(path_m)
        path_s = str(tmp_path / "s.json")
        bs = TraceBuilder()
        bs.add_device_run(streamed)
        bs.write(path_s)

        def device_events(path):
            with open(path) as fh:
                evs = json.load(fh)["traceEvents"]
            return [{k: v for k, v in e.items() if k != "pid"}
                    for e in evs if e["ph"] != "M"]

        assert device_events(path_m) == device_events(path_s)


# ---------------------------------------------------------------------------
# heatmaps
# ---------------------------------------------------------------------------


class TestHeatmap:
    def test_bucket_matrix_preserves_totals(self):
        rng = np.random.default_rng(5)
        n = 300
        s = rng.integers(0, 10_000, n)
        e = s + rng.integers(1, 700, n)
        r = rng.integers(0, 4, n)
        bt = 64
        nb = int(e.max()) // bt + 1
        mat = bucket_matrix(s, e, r, 4, bt, nb)
        for res in range(4):
            assert mat[res].sum() == (e - s)[r == res].sum()

    def test_run_heatmap_totals_match_occupancy(self, tiny_cfg, tiny_txns):
        obs.enable_tracing()
        _run(tiny_cfg, tiny_txns, ("baseline",))
        (run,) = obs_events.RECORDER.finalized_runs()
        hm = run_heatmaps(run, bucket_ticks=256)
        tl = obs_events.derive_timeline(run)
        total = sum(int((e - s)[m].sum()) for s, e, m in tl["occ"])
        assert int(hm["util_ticks"].sum()) == total
        assert int(hm["conflicts"].sum()) == int(
            (run["conflict"] & ~run["failed"]).sum())


# ---------------------------------------------------------------------------
# satellites: scenario PERF isolation, ingest warning, check_perf gate
# ---------------------------------------------------------------------------


class TestScenarioPerfIsolation:
    def test_back_to_back_sweeps_report_independent_deltas(self, tiny_cfg):
        from repro.workloads import scenario
        from repro.workloads.scenario import QueueDepthSweep

        scn = QueueDepthSweep("hm_0", qds=(1, 4), iters=2, n_requests=40)
        first = scenario.run_queue_depth_sweeps(tiny_cfg, (scn,),
                                                ("venice",))
        d1 = scenario.last_run_perf()
        assert d1 is not None and d1["lanes"] > 0
        bench.clear_caches()  # same work both times
        second = scenario.run_queue_depth_sweeps(tiny_cfg, (scn,),
                                                 ("venice",))
        d2 = scenario.last_run_perf()
        # per-run deltas, not process-cumulative: identical work reports
        # identical counters, and the scoreboard holds the sum
        assert d2["lanes"] == d1["lanes"]
        assert d2["decomp_misses"] == d1["decomp_misses"] > 0
        assert bench.PERF["lanes"] >= d1["lanes"] + d2["lanes"]
        assert first == second  # records stay bit-identical (no perf keys)


class TestIngestSkipWarning:
    def _write_fixture(self, path, n_bad=1):
        base = 129_000_000_000_000_000
        with open(path, "w") as f:
            for i in range(6):
                f.write(f"{base + i * 10},host,0,Read,{4096 * i},4096,0\n")
                if i < n_bad:
                    f.write(f"{base + i * 10 + 5},host,0,Write,oops,4096,0\n")

    def test_warns_once_per_file_and_counts(self, tmp_path):
        path = str(tmp_path / "corrupt.csv")
        self._write_fixture(path, n_bad=2)
        from repro.workloads.ingest import load_trace

        before = bench.PERF["ingest_skipped_rows"]
        with pytest.warns(UserWarning, match="skipped 2 corrupted rows"):
            tr = load_trace(path, on_error="skip")
        assert tr["skipped_rows"] == 2
        assert bench.PERF["ingest_skipped_rows"] == before + 2
        # second ingest of the same file: counter still moves, warning
        # deduplicates
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            load_trace(path, on_error="skip")
        assert not [w for w in caught if "corrupt.csv" in str(w.message)]
        assert bench.PERF["ingest_skipped_rows"] == before + 4

    def test_raise_mode_untouched(self, tmp_path):
        path = str(tmp_path / "corrupt2.csv")
        self._write_fixture(path)
        from repro.workloads.ingest import load_trace

        with pytest.raises(ValueError, match="corrupted trace row"):
            load_trace(path)


class TestCheckPerf:
    def _artifact(self, total_s, phases=None, preset="smoke"):
        return {"preset": preset, "total_s": total_s,
                "phases": phases or {}, "stream": None}

    def _write(self, tmp_path, fresh, base):
        fp = tmp_path / "BENCH_fresh.json"
        bp = tmp_path / "BENCH_base.json"
        fp.write_text(json.dumps(fresh))
        bp.write_text(json.dumps(base))
        return str(fp), str(bp)

    def test_ok_exit_codes_and_summary(self, tmp_path):
        from benchmarks.check_perf import main

        fp, bp = self._write(tmp_path, self._artifact(10.0),
                             self._artifact(10.0))
        assert main([fp, bp]) == 0
        assert main([fp, bp, "--strict"]) == 0
        summary = json.loads(
            (tmp_path / "check_perf_summary.json").read_text())
        assert summary["status"] == "ok" and summary["findings"] == []

    def test_regression_gates_only_under_strict(self, tmp_path):
        from benchmarks.check_perf import main

        fp, bp = self._write(
            tmp_path,
            self._artifact(20.0, {"tail": {"s": 9.0}}),
            self._artifact(10.0, {"tail": {"s": 2.0}}))
        assert main([fp, bp]) == 0  # default stays fail-open
        assert main([fp, bp, "--strict"]) == 1
        summary = json.loads(
            (tmp_path / "check_perf_summary.json").read_text())
        assert summary["status"] == "regressed"
        kinds = {f["kind"] for f in summary["findings"]}
        assert kinds == {"total_regression", "phase_regression"}

    def test_unreadable_probe_skips(self, tmp_path):
        from benchmarks.check_perf import main

        fp, _ = self._write(tmp_path, self._artifact(1.0),
                            self._artifact(1.0))
        missing = str(tmp_path / "nope.json")
        assert main([fp, missing]) == 0
        assert main([fp, missing, "--strict"]) == 2
        summary = json.loads(
            (tmp_path / "check_perf_summary.json").read_text())
        assert summary["status"] == "skipped"
