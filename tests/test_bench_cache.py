"""WorkloadRun / decomposition cache semantics (no simulation needed).

The subset-serving path of ``run_workload`` returns before any trace
generation or simulation, so these tests drive the caches with synthetic
entries and assert the LRU contract: hits refresh recency, subset hits are
derived views that never insert duplicate entries, and eviction drops the
least-recently-used run.
"""
import numpy as np
import pytest

from repro.ssd import bench, perf_optimized
from repro.ssd.bench import WorkloadRun, _lru_get, _lru_put, run_workload


@pytest.fixture(autouse=True)
def _clean_caches():
    bench.clear_caches()
    yield
    bench.clear_caches()


def _fake_run(cfg, designs):
    return WorkloadRun(name="wl", cfg=cfg, accel=1.0, n_requests=7,
                       results={d: object() for d in designs})


def _seed_entry(cfg, designs, n_req=100):
    key = ("wl", cfg, tuple(designs), n_req, 1.5, 0)
    bench._RUN_CACHE[key] = _fake_run(cfg, designs)
    return key


def test_lru_hit_moves_to_end():
    cache = {}
    _lru_put(cache, "a", 1, cap=3)
    _lru_put(cache, "b", 2, cap=3)
    _lru_put(cache, "c", 3, cap=3)
    assert _lru_get(cache, "a") == 1  # refresh "a"
    _lru_put(cache, "d", 4, cap=3)  # evicts LRU = "b", not "a"
    assert list(cache) == ["c", "a", "d"]


def test_direct_hit_refreshes_recency():
    cfg = perf_optimized()
    k1 = _seed_entry(cfg, ("baseline", "venice"))
    k2 = _seed_entry(cfg, ("baseline", "nossd"), n_req=200)
    run_workload("wl", cfg, designs=("baseline", "venice"), n_requests=100)
    assert list(bench._RUN_CACHE) == [k2, k1]  # k1 moved to MRU position


def test_subset_hit_is_derived_view_not_a_new_entry():
    cfg = perf_optimized()
    designs = ("baseline", "pssd", "venice", "ideal")
    key = _seed_entry(cfg, designs)
    sup = bench._RUN_CACHE[key]
    before = list(bench._RUN_CACHE)
    sub = run_workload("wl", cfg, designs=("baseline", "venice"),
                       n_requests=100)
    # served from the superset: same result objects, no simulation
    assert sub.results["venice"] is sup.results["venice"]
    assert set(sub.results) == {"baseline", "venice"}
    # and the cache holds exactly the entries it held before — the old
    # behaviour inserted a derived duplicate that evicted the oldest run
    assert list(bench._RUN_CACHE) == before
    assert bench.PERF["run_subset_hits"] >= 1


def test_subset_hits_do_not_evict_unrelated_runs():
    cfg = perf_optimized()
    keys = [_seed_entry(cfg, ("baseline", "venice", f"d{i}"), n_req=i)
            for i in range(bench._RUN_CACHE_MAX)]  # cache exactly full
    for _ in range(10):  # repeated subset hits must not push anything out
        run_workload("wl", cfg, designs=("baseline", "venice"), n_requests=3)
    assert set(bench._RUN_CACHE) == set(keys)


def test_decomp_cache_keyed_on_ftl_geometry_only():
    """Configs differing only in timing/interconnect share decompositions;
    geometry changes (page size) do not."""
    cfg_a = perf_optimized()
    cfg_b = perf_optimized(t_read_us=99.0, chan_gbps=2.4,
                           bus_protocol_ovh_ns=0.0)
    cfg_c = perf_optimized(page_bytes=16384)
    assert bench.ftl_geometry(cfg_a) == bench.ftl_geometry(cfg_b)
    assert bench.ftl_geometry(cfg_a) != bench.ftl_geometry(cfg_c)
    from repro.traces.generator import gen_trace, to_pages

    pages = to_pages(gen_trace("hm_0", 40, seed=1), cfg_a.page_bytes)
    fp = int(pages["footprint_pages"])
    t1 = bench.decompose_cached(cfg_a, pages, fp)
    t2 = bench.decompose_cached(cfg_b, pages, fp)
    assert t1 is t2  # shared entry
    assert bench.PERF["decomp_hits"] >= 1
