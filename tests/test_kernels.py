"""Pallas scout-step kernel vs pure-jnp oracle: shape/mesh/density sweeps,
plus full-DFS replay against the scalar Algorithm-1 reference."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import build_mesh, scout_route_ref
from repro.core.rng import seed_for_scout
from repro.kernels.ops import make_route_batch
from repro.kernels.ref import scout_step_ref
from repro.kernels.scout_step import (
    LINK_PAD,
    STATE_W,
    pack_tables,
    scout_step_pallas,
    umod,
    xorshift32_i32,
)
from repro.ssd.designs import DESIGNS, KIND_SCOUT, REGISTRY


def _mk_batch(topo, B, density, seed):
    rs = np.random.RandomState(seed)
    n_pad = pack_tables(topo).shape[0]
    state = np.zeros((B, STATE_W), np.int32)
    state[:, 0] = rs.randint(0, topo.n_nodes, B)  # cur
    state[:, 1] = rs.randint(0, topo.n_nodes, B)  # dst
    state[:, 2] = rs.randint(-1, 4, B)  # entry
    state[:, 3] = rs.randint(1, 2**31 - 1, B)  # rng bits
    busy = np.zeros((B, LINK_PAD), np.int32)
    busy[:, : topo.n_links] = rs.rand(B, topo.n_links) < density
    tried = np.zeros((B, 4 * n_pad), np.int32)
    tried[:, : 4 * topo.n_nodes] = rs.rand(B, 4 * topo.n_nodes) < density / 2
    return state, busy, tried


@pytest.mark.parametrize("rows,cols", [(8, 8), (4, 16), (16, 4), (4, 4)])
@pytest.mark.parametrize("density", [0.0, 0.3, 0.8])
def test_kernel_matches_ref_over_meshes(rows, cols, density):
    topo = build_mesh(rows, cols)
    tables = jnp.asarray(pack_tables(topo))
    B = 256
    state, busy, tried = _mk_batch(topo, B, density, rows * 31 + cols)
    got = scout_step_pallas(
        jnp.asarray(state), jnp.asarray(busy), jnp.asarray(tried), tables,
        cols=cols, n_nodes=topo.n_nodes, interpret=True, b_tile=128,
    )
    n = topo.n_nodes
    want = scout_step_ref(
        jnp.asarray(state), jnp.asarray(busy), jnp.asarray(tried),
        tables[:n, 0:4], tables[:n, 4:8], cols,
    )
    for g, w, name in zip(got, want, ["state", "busy", "tried"]):
        assert np.array_equal(np.asarray(g), np.asarray(w)), name


@pytest.mark.parametrize("b_tile,B", [(128, 128), (128, 384), (256, 512)])
def test_kernel_tile_shapes(b_tile, B):
    topo = build_mesh(8, 8)
    tables = jnp.asarray(pack_tables(topo))
    state, busy, tried = _mk_batch(topo, B, 0.4, B)
    got = scout_step_pallas(
        jnp.asarray(state), jnp.asarray(busy), jnp.asarray(tried), tables,
        cols=8, n_nodes=64, interpret=True, b_tile=b_tile,
    )
    want = scout_step_ref(
        jnp.asarray(state), jnp.asarray(busy), jnp.asarray(tried),
        tables[:64, 0:4], tables[:64, 4:8], 8,
    )
    assert np.array_equal(np.asarray(got[0]), np.asarray(want[0]))


def test_kernel_minimal_only_mode():
    topo = build_mesh(8, 8)
    tables = jnp.asarray(pack_tables(topo))
    state, busy, tried = _mk_batch(topo, 128, 0.6, 5)
    got = scout_step_pallas(
        jnp.asarray(state), jnp.asarray(busy), jnp.asarray(tried), tables,
        cols=8, n_nodes=64, interpret=True, b_tile=128, allow_nonminimal=False,
    )
    # in minimal-only mode no step may be a misroute
    assert int(np.asarray(got[0])[:, 6].sum()) == 0


def test_umod_matches_python_unsigned():
    xs = np.array([0, 1, 2**31 - 1, -1, -2**31, 12345, -98765], np.int32)
    for m in [1, 2, 3, 4]:
        got = np.asarray(umod(jnp.asarray(xs), jnp.int32(m)))
        want = np.array([(int(x) & 0xFFFFFFFF) % m for x in xs], np.int32)
        assert np.array_equal(got, want), (m, got, want)


def test_xorshift_matches_python():
    from repro.core.rng import xorshift32_py

    xs = np.array([1, 7, 2**31 - 1, -5, 123456789], np.int32)
    got = np.asarray(xorshift32_i32(jnp.asarray(xs))).astype(np.uint32)
    want = np.array([xorshift32_py(int(x) & 0xFFFFFFFF) for x in xs], np.uint32)
    assert np.array_equal(got, want)


@pytest.mark.parametrize("design", DESIGNS)
def test_kernel_ref_parity_per_design(design):
    """jnp-reference vs Pallas-interpret parity under each registered
    design's routing knobs.  Statically-routed designs never walk the
    mesh — their scout degenerates to a dst == src (zero-length) walk —
    so their batches pin the degenerate path; scout designs pin their
    ``allow_nonminimal`` setting over a half-busy mesh."""
    spec = REGISTRY[design]
    topo = build_mesh(8, 8)
    tables = jnp.asarray(pack_tables(topo))
    B = 128
    state, busy, tried = _mk_batch(topo, B, 0.5, DESIGNS.index(design) + 11)
    if spec.kind != KIND_SCOUT:
        state[:, 1] = state[:, 0]  # degenerate walk: already at destination
    got = scout_step_pallas(
        jnp.asarray(state), jnp.asarray(busy), jnp.asarray(tried), tables,
        cols=8, n_nodes=64, allow_nonminimal=spec.allow_nonminimal,
        interpret=True, b_tile=64,
    )
    want = scout_step_ref(
        jnp.asarray(state), jnp.asarray(busy), jnp.asarray(tried),
        tables[:64, 0:4], tables[:64, 4:8], 8,
        allow_nonminimal=spec.allow_nonminimal,
    )
    for g, w, name in zip(got, want, ["state", "busy", "tried"]):
        assert np.array_equal(np.asarray(g), np.asarray(w)), (design, name)
    if spec.kind != KIND_SCOUT:
        assert (np.asarray(got[0])[:, 4] == 2).all(), design  # all arrived


def test_kernel_degenerate_dst_eq_src_is_noop():
    """A scout already at its destination must arrive (flags == 2) without
    moving, claiming a link, or burning RNG state."""
    topo = build_mesh(4, 4)
    tables = jnp.asarray(pack_tables(topo))
    state, busy, tried = _mk_batch(topo, 64, 0.7, 21)
    state[:, 1] = state[:, 0]
    got = scout_step_pallas(
        jnp.asarray(state), jnp.asarray(busy), jnp.asarray(tried), tables,
        cols=4, n_nodes=16, interpret=True, b_tile=64,
    )
    s = np.asarray(got[0])
    assert (s[:, 4] == 2).all()  # flags: arrived
    assert np.array_equal(s[:, 0], state[:, 0])  # no movement
    assert np.array_equal(s[:, 3], state[:, 3])  # RNG untouched
    assert np.array_equal(np.asarray(got[1]), busy)
    assert np.array_equal(np.asarray(got[2]), tried)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_full_dfs_replay_matches_scalar_reference(use_pallas):
    topo = build_mesh(8, 8)
    rs = np.random.RandomState(3)
    B = 48
    src = np.array([int(topo.fc_node[rs.randint(8)]) for _ in range(B)], np.int32)
    dst = rs.randint(0, 64, B).astype(np.int32)
    busy = rs.rand(B, topo.n_links) < rs.uniform(0, 0.7, (B, 1))
    seeds = np.array([seed_for_scout(9, i) for i in range(B)], np.uint32)
    route = make_route_batch(topo, use_pallas=use_pallas, interpret=True)
    out = route(jnp.asarray(src), jnp.asarray(dst), jnp.asarray(busy),
                jnp.asarray(seeds))
    for i in range(B):
        ref = scout_route_ref(topo, int(src[i]), int(dst[i]), busy[i].copy(),
                              int(seeds[i]))
        assert bool(out.success[i]) == ref.success
        assert int(out.steps[i]) == ref.steps
        if ref.success:
            mask = np.zeros(topo.n_links, bool)
            mask[ref.path_links] = True
            assert np.array_equal(
                np.asarray(out.path_mask[i, : topo.n_links]), mask
            )
            assert int(out.hops[i]) == ref.hops
            assert int(out.misroutes[i]) == ref.misroutes
