"""FTL invariants and array-native engine parity.

Two layers of guarantees:

* **Invariants** of the scalar FTL (the oracle): L2P/P2L stay mutually
  inverse, per-block valid-page accounting conserves live LPNs across GC,
  erase counts only grow, and wrap-around overwrite pressure drives GC
  without violating the free-block headroom guard.
* **Parity**: the vectorized engine (``repro.ssd.ftl_engine``) must be
  bit-identical to the scalar oracle — every Transactions array and every
  piece of FTL state — on every workload fixture, including GC-heavy
  geometries where the engine's epochs are interrupted by scalar GC.
"""
import numpy as np
import pytest

from repro.ssd import cost_optimized, perf_optimized
from repro.ssd.ftl import FTL, decompose_trace
from repro.ssd.ftl_engine import _precondition_vectorized
from repro.traces.generator import gen_trace, to_pages

FTL_STATE = (
    "l2p", "p2l", "valid", "written", "erase_count", "is_free",
    "open_block", "next_page",
)
FTL_SCALARS = ("_stripe", "gc_events", "gc_page_moves",
               "read_precond_pages", "read_precond_gc_txns")


def _decompose_both(cfg, trace, overprovision=1.28):
    pages = to_pages(trace, cfg.page_bytes)
    fp = int(pages["footprint_pages"])
    a = decompose_trace(cfg, pages, footprint_pages=fp, engine="scalar",
                        overprovision=overprovision)
    b = decompose_trace(cfg, pages, footprint_pages=fp, engine="vector",
                        overprovision=overprovision)
    return a, b


def _assert_bit_identical(a, b):
    assert set(a) == set(b)
    for k in a:
        assert np.array_equal(a[k], b[k]), f"Transactions[{k}] diverges"
    for attr in FTL_STATE:
        assert np.array_equal(getattr(a.ftl, attr), getattr(b.ftl, attr)), attr
    for attr in FTL_SCALARS:
        assert getattr(a.ftl, attr) == getattr(b.ftl, attr), attr
    assert a.n_requests == b.n_requests


class TestVectorEngineParity:
    """The acceptance bar: vector output is bit-identical to the oracle."""

    @pytest.mark.parametrize("wl", ["hm_0", "src2_1", "prxy_0", "usr_0"])
    def test_full_geometry_workloads(self, wl):
        cfg = perf_optimized()
        a, b = _decompose_both(cfg, gen_trace(wl, 200, seed=2))
        _assert_bit_identical(a, b)

    @pytest.mark.parametrize("wl", ["hm_0", "mds_0"])
    def test_cost_config(self, wl):
        cfg = cost_optimized()
        a, b = _decompose_both(cfg, gen_trace(wl, 200, seed=2))
        _assert_bit_identical(a, b)

    def test_tiny_geometry(self, tiny_cfg):
        tr = dict(gen_trace("src2_1", 60, seed=3))
        tr["arrival_us"] = tr["arrival_us"] / 16.0
        a, b = _decompose_both(tiny_cfg, tr)
        _assert_bit_identical(a, b)

    def test_gc_heavy_epochs(self):
        """Hundreds of GC triggers — every epoch boundary must line up."""
        cfg = perf_optimized(rows=2, cols=2, pages_per_block=16)
        tr = gen_trace("prxy_0", 2500, seed=5, footprint_bytes=1 << 20)
        a, b = _decompose_both(cfg, tr, overprovision=3.0)
        assert a.ftl.gc_events > 100  # the fixture really exercises GC
        _assert_bit_identical(a, b)

    def test_precondition_fallback_parity(self):
        """A fill dense enough to GC mid-precondition falls back to the
        scalar loop; a read-only trace then survives identically."""
        cfg = perf_optimized(rows=2, cols=2, pages_per_block=8)
        fp = 256
        assert not _precondition_vectorized(
            FTL(cfg, n_lpns=fp, overprovision=1.2)
        )
        rs = np.random.RandomState(0)
        tr = {
            "arrival_us": np.cumsum(rs.exponential(50.0, 300)),
            "is_read": np.ones(300, bool),
            "offset_page": rs.randint(0, fp, 300).astype(np.int64),
            "n_pages": rs.randint(1, 5, 300).astype(np.int64),
        }
        a = decompose_trace(cfg, tr, footprint_pages=fp, engine="scalar",
                            overprovision=1.2)
        b = decompose_trace(cfg, tr, footprint_pages=fp, engine="vector",
                            overprovision=1.2)
        _assert_bit_identical(a, b)
        assert a.ftl.gc_events > 0  # the fill itself collected

    def test_engine_guards(self):
        cfg = perf_optimized(rows=2, cols=2)
        tr = gen_trace("hm_0", 20, seed=0)
        pages = to_pages(tr, cfg.page_bytes)
        with pytest.raises(ValueError):
            decompose_trace(cfg, pages, footprint_pages=64, engine="warp")
        with pytest.raises(ValueError):
            decompose_trace(cfg, pages, footprint_pages=64, engine="vector",
                            precondition=False)


class TestInvariants:
    """Oracle-level FTL invariants on tiny fixtures."""

    def _churn(self, ftl, n_writes, n_lpns, seed=0):
        rs = np.random.RandomState(seed)
        for lpn in rs.randint(0, n_lpns, n_writes):
            ftl.write_page(int(lpn), [], 0)

    def test_l2p_p2l_mutually_inverse(self):
        cfg = perf_optimized(rows=2, cols=2, pages_per_block=16)
        ftl = FTL(cfg, n_lpns=512, overprovision=2.5)
        self._churn(ftl, 4000, 512, seed=1)
        mapped = np.flatnonzero(ftl.l2p >= 0)
        assert np.array_equal(ftl.p2l[ftl.l2p[mapped]], mapped)
        live = np.flatnonzero(ftl.p2l >= 0)
        assert np.array_equal(ftl.l2p[ftl.p2l[live]], live)
        assert len(mapped) == len(live)

    def test_valid_accounting_conserves_live_lpns_across_gc(self):
        cfg = perf_optimized(rows=2, cols=2, pages_per_block=16)
        ftl = FTL(cfg, n_lpns=512, overprovision=2.5)
        for lpn in range(512):  # full precondition: every LPN live
            ftl.write_page(lpn, [], 0)
        self._churn(ftl, 6000, 512, seed=2)
        assert ftl.gc_events > 0
        # GC moved pages but never lost one: all 512 LPNs still live, and
        # the per-block valid counters sum to exactly the live population
        assert (ftl.l2p >= 0).all()
        assert int(ftl.valid.sum()) == 512
        # per-block valid equals the P2L census of that block
        P, B, ppb = ftl.n_planes, ftl.blocks_per_plane, ftl.pages_per_block
        census = (ftl.p2l.reshape(P, B, ppb) >= 0).sum(axis=2)
        assert np.array_equal(census, ftl.valid)

    def test_erase_counts_only_grow(self):
        cfg = perf_optimized(rows=2, cols=2, pages_per_block=16)
        ftl = FTL(cfg, n_lpns=256, overprovision=3.0)
        prev = ftl.erase_count.copy()
        rs = np.random.RandomState(3)
        for batch in range(12):
            for lpn in rs.randint(0, 256, 800):
                ftl.write_page(int(lpn), [], 0)
            assert (ftl.erase_count >= prev).all()
            prev = ftl.erase_count.copy()
        assert int(prev.sum()) > 0

    def test_wraparound_pressure_respects_headroom_guard(self):
        """Sequential wrap-around overwrites (the worst case for a striped
        FTL) must drive GC yet never leave a plane without the reserved
        headroom GC's copyback draws from."""
        cfg = perf_optimized(rows=2, cols=2, pages_per_block=16)
        ftl = FTL(cfg, n_lpns=384, overprovision=3.0)
        for i in range(6 * 384):  # six full footprint wraps
            ftl.write_page(i % 384, [], 0)
            if i % 97 == 0:
                assert (ftl.is_free.sum(axis=1) >= 1).all()
        assert ftl.gc_events > 0
        assert ftl.gc_page_moves >= 0
        assert (ftl.is_free.sum(axis=1) >= 1).all()

    def test_read_before_write_precondition_gc_is_counted(self):
        """Satellite: reads of unmapped LPNs precondition on demand; the GC
        work that triggers is dropped from the stream but must be counted
        and surfaced on Transactions (DESIGN.md §3)."""
        cfg = perf_optimized(rows=2, cols=2, pages_per_block=16)
        fp = 512
        rs = np.random.RandomState(7)
        n = 1500
        is_read = rs.rand(n) < 0.3
        # writes churn a hot 64-page range (invalidating pages so GC can
        # reclaim); reads roam the whole unmapped footprint
        off = np.where(is_read, rs.randint(0, fp, n),
                       rs.randint(0, 64, n)).astype(np.int64)
        tr = {
            "arrival_us": np.cumsum(rs.exponential(30.0, n)),
            "is_read": is_read,
            "offset_page": off,
            "n_pages": rs.randint(1, 6, n).astype(np.int64),
        }
        txns = decompose_trace(cfg, tr, footprint_pages=fp,
                               precondition=False, overprovision=2.0)
        assert txns.read_precond_pages > 0
        assert txns.read_precond_pages == txns.ftl.read_precond_pages
        # GC ran during on-demand mapping and its transactions were
        # dropped from the stream (reads are modeled as hitting resident
        # data) — but the work is counted
        assert txns.ftl.gc_events > 0
        assert txns.read_precond_gc_txns > 0
        # … and a preconditioned decomposition reports zero such work
        pre = decompose_trace(cfg, tr, footprint_pages=fp, precondition=True,
                              overprovision=3.0)
        assert pre.read_precond_pages == 0
        assert pre.read_precond_gc_txns == 0
