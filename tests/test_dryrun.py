"""Dry-run machinery: lower+compile on a small placeholder mesh (subprocess:
jax locks device count at first init, so tests must not pollute the main
process), HLO collective parsing, roofline arithmetic."""
import json
import os
import subprocess
import sys

import pytest

from repro.roofline.analysis import (
    collective_bytes_from_hlo,
    model_flops,
    param_counts,
    roofline_terms,
)

_MINI = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.launch.mesh import make_mesh
from repro.launch.steps import cell_abstract
from repro.configs import input_specs

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
notes = []
fn, args, in_sh, kind = cell_abstract("qwen2-0.5b", "train_4k", mesh, notes)
# shrink the batch so the mini-mesh cell is light
import dataclasses
import jax.numpy as jnp
bshape = input_specs("qwen2-0.5b", "train_4k", batch_override=4)
args = (args[0], args[1], bshape)
with mesh:
    lowered = jax.jit(fn, in_shardings=(in_sh[0], in_sh[1], in_sh[2])).lower(*args)
    compiled = lowered.compile()
cost = compiled.cost_analysis()
cost = cost[0] if isinstance(cost, (list, tuple)) else cost
mem = compiled.memory_analysis()
print(json.dumps({
    "flops": float(cost.get("flops", 0)),
    "temp": int(mem.temp_size_in_bytes),
    "has_collectives": ("all-reduce" in compiled.as_text()
                        or "all-gather" in compiled.as_text()),
}))
"""


@pytest.mark.slow
def test_mini_multipod_dryrun_compiles():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _MINI], capture_output=True, text=True,
        timeout=560, env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["temp"] > 0
    assert rec["has_collectives"]  # the pod/data axes must induce comms


def test_collective_parser():
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256] %p), replica_groups={}
  %ag.1 = bf16[64]{0} all-gather(bf16[4] %x), dimensions={0}
  %rs = f32[16,16]{1,0} reduce-scatter(f32[128,16] %y), dimensions={0}
  %a2a-start = (f32[8,8], f32[8,8]) all-to-all-start(f32[8,8] %z)
  %a2a-done = f32[8,8] all-to-all-done(%a2a-start)
  %cp = u32[10]{0} collective-permute(u32[10] %w), source_target_pairs={{0,1}}
  %notacoll = f32[999,999] add(f32[999,999] %a, f32[999,999] %b)
"""
    got = collective_bytes_from_hlo(hlo)
    assert got["all-reduce"] == 128 * 256 * 4
    assert got["all-gather"] == 64 * 2
    assert got["reduce-scatter"] == 16 * 16 * 4
    assert got["all-to-all"] == 8 * 8 * 4 * 2  # tuple of two
    assert got["collective-permute"] == 10 * 4
    assert got["counts"]["all-to-all"] == 1  # -done not double counted


def test_param_counts_sane():
    total, active = param_counts("qwen2-0.5b")
    assert 0.3e9 < total < 0.7e9  # ~0.5B incl embeddings
    assert active == total  # dense
    total_k, active_k = param_counts("kimi-k2-1t-a32b")
    assert total_k > 0.8e12  # ~1T
    assert active_k < 0.1 * total_k  # a32b: sparse activation


def test_roofline_terms_shape():
    rec = {
        "devices": 256,
        "shape": "train_4k",
        "cost": {"flops": 1e15, "bytes accessed": 1e12},
        "collectives": {"all-reduce": 1e9, "all-gather": 0.0,
                        "reduce-scatter": 0.0, "all-to-all": 0.0,
                        "collective-permute": 0.0},
    }
    t = roofline_terms(rec, "qwen2-0.5b")
    assert t["compute_s"] == pytest.approx(1e15 / 197e12)
    assert t["memory_s"] == pytest.approx(1e12 / 819e9)
    assert t["collective_s"] == pytest.approx(2e9 / 50e9)
    assert t["dominant"] == "compute"
    assert t["model_flops"] == model_flops("qwen2-0.5b", "train_4k")
