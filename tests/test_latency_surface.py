"""Host-request latency surface pinned against scalar references.

``SimResult.req_latency`` / ``req_completion`` (and the ``p99_latency_us``
/ ``latency_cdf_us`` metrics on top) were dead code until the workloads
subsystem started consuming them; these tests pin the vectorized
scatter-reduce in ``sim._finish_result`` — including its GC exclusion —
against a plain-Python per-transaction loop on tiny fixtures.
"""
import numpy as np
import pytest

from repro.ssd import decompose_trace, perf_optimized, simulate
from repro.ssd.config import TICK_NS
from repro.ssd.sim import _nominal_order
from repro.traces.generator import gen_trace, to_pages

from conftest import mk_txns


def _scalar_request_surface(cfg, txns, res):
    """Reference: walk transactions one by one in the scan's (nominal)
    order, folding completions/arrivals into per-request records; GC rows
    (req < 0) are background traffic and never touch a record."""
    order = _nominal_order(cfg, txns)
    req = np.asarray(txns["req"])[order]
    arrival = np.asarray(txns["arrival"])[order]
    done, arr = {}, {}
    for i in range(len(req)):
        r = int(req[i])
        if r < 0:
            continue
        done[r] = max(done.get(r, 0), int(res.completion[i]))
        arr[r] = min(arr.get(r, 1 << 62), int(arrival[i]))
    ids = sorted(done)
    lat = np.array([done[r] - arr[r] for r in ids], np.int64)
    comp = np.array([done[r] for r in ids], np.int64)
    return lat, comp


@pytest.fixture(scope="module")
def gc_heavy(tiny_cfg_gc):
    """A write-heavy trace whose decomposition injects GC transactions."""
    tr = gen_trace("prxy_0", 300, seed=5, footprint_bytes=4 << 20)
    tr = dict(tr)
    tr["arrival_us"] = tr["arrival_us"] / 8.0
    pages = to_pages(tr, tiny_cfg_gc.page_bytes)
    txns = decompose_trace(
        tiny_cfg_gc, pages, footprint_pages=int(pages["footprint_pages"])
    )
    assert (np.asarray(txns["req"]) < 0).any(), "fixture must contain GC"
    return txns


@pytest.fixture(scope="module")
def tiny_cfg_gc():
    return perf_optimized(rows=2, cols=2, pages_per_block=16)


class TestRequestSurfacePins:
    def test_req_latency_matches_scalar_reference(self, tiny_cfg, tiny_txns):
        res = simulate(tiny_cfg, tiny_txns, "baseline")
        lat, comp = _scalar_request_surface(tiny_cfg, tiny_txns, res)
        assert np.array_equal(res.req_latency, lat)
        assert np.array_equal(res.req_completion, comp)

    def test_gc_rows_are_excluded(self, tiny_cfg_gc, gc_heavy):
        res = simulate(tiny_cfg_gc, gc_heavy, "baseline")
        lat, comp = _scalar_request_surface(tiny_cfg_gc, gc_heavy, res)
        assert np.array_equal(res.req_latency, lat)
        assert np.array_equal(res.req_completion, comp)
        # every host request is represented exactly once
        assert len(res.req_latency) == gc_heavy.n_requests

    def test_gc_exclusion_hand_built(self, tiny_cfg):
        # 3 host reads + 1 GC-tagged read (req = -1) that finishes LAST:
        # were GC counted, some request's latency would absorb its tail
        txns = mk_txns([0.0, 0.0, 0.0, 0.0], [0, 0, 0, 0], [0, 2, 4, 0],
                       [4096] * 4, tiny_cfg)
        txns["req"] = np.array([0, 1, 2, -1], np.int64)
        res = simulate(tiny_cfg, txns, "baseline")
        assert len(res.req_latency) == 3
        lat, comp = _scalar_request_surface(tiny_cfg, txns, res)
        assert np.array_equal(res.req_latency, lat)

    def test_p99_and_cdf_match_numpy_reference(self, tiny_cfg, tiny_txns):
        res = simulate(tiny_cfg, tiny_txns, "venice")
        want_p99 = float(np.percentile(res.req_latency, 99)) * TICK_NS * 1e-3
        assert res.p99_latency_us() == pytest.approx(want_p99)
        xs, ys = res.latency_cdf_us()
        assert len(xs) == len(ys) == len(res.req_latency)
        assert (np.diff(xs) >= 0).all()
        assert ys[0] == pytest.approx(1 / len(ys))
        assert ys[-1] == pytest.approx(1.0)
        assert np.array_equal(
            xs, np.sort(res.req_latency) * (TICK_NS * 1e-3)
        )
        pcts = res.latency_percentiles_us()
        assert pcts["p99"] == pytest.approx(want_p99)
        assert pcts["p50"] <= pcts["p95"] <= pcts["p99"]

    def test_surface_is_design_agnostic_metadata(self, tiny_cfg, tiny_txns):
        """req_completion/req_tenant must not perturb the simulation: the
        pre-existing arrays are byte-identical to the seed's semantics."""
        a = simulate(tiny_cfg, tiny_txns, "baseline")
        b = simulate(tiny_cfg, tiny_txns, "baseline")
        assert np.array_equal(a.completion, b.completion)
        assert a.req_tenant is None  # untagged trace stays untagged
