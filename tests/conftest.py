"""Shared fixtures: tiny-geometry configs keep tier-1 JIT under control.

The full 8x8 mesh compiles a large scan program; most behavioural properties
hold on a 2x2 mesh with short traces, which compiles in seconds.  Heavy
full-geometry sweeps are marked ``@pytest.mark.slow`` and excluded from the
default run (see pytest.ini).
"""
# Two virtual XLA host devices so the whole tier runs against the sweep
# planner's sharded (shard_map) execution path — the multi-core layout the
# benchmarks use — and the legacy (non-thunk) CPU runtime the benchmarks
# run under (see repro.xla_env).  The single-device environment is covered
# by the subprocess parity test in tests/test_sweep_plan.py.  MUST run
# before any jax import: jax locks these on first init.
#
# The persistent executable cache (repro.ssd.exec_cache) is pointed at a
# repo-local dir that SURVIVES pytest sessions: the tier compiles dozens of
# tiny-geometry programs, and re-runs load them instead (the cache key
# covers jax/jaxlib versions, XLA flags and the simulator sources, so a
# code change invalidates exactly the affected entries).  Tests that need
# cold-cache behaviour point REPRO_XC_DIR elsewhere (tests/test_exec_cache).
import os as _os

_os.environ.setdefault(
    "REPRO_XC_DIR",
    _os.path.join(_os.path.dirname(__file__), "..", ".pytest_cache",
                  "repro-xc"),
)

from repro.xla_env import configure as _configure_xla

_configure_xla(device_count=2)

import numpy as np
import pytest

from repro.ssd import decompose_trace, perf_optimized
from repro.traces.generator import gen_trace, to_pages


@pytest.fixture(scope="session")
def tiny_cfg():
    """2x2 mesh (4 chips, 8 planes) — smallest geometry with path diversity."""
    return perf_optimized(rows=2, cols=2, pages_per_block=64)


@pytest.fixture(scope="session")
def tiny_txns(tiny_cfg):
    """A short saturating trace decomposed for the tiny geometry."""
    tr = gen_trace("src2_1", 60, seed=3)
    tr = dict(tr)
    tr["arrival_us"] = tr["arrival_us"] / 16.0  # intensify into conflicts
    pages = to_pages(tr, tiny_cfg.page_bytes)
    return decompose_trace(
        tiny_cfg, pages, footprint_pages=int(pages["footprint_pages"])
    )


def mk_txns(arrival_us, kinds, planes, nbytes, cfg):
    """Hand-built transaction dict (mirrors repro.ssd.ftl's layout)."""
    from repro.ssd.config import us_to_ticks

    n = len(arrival_us)
    planes = np.asarray(planes, np.int64)
    chips = planes // (cfg.dies_per_chip * cfg.planes_per_die)
    return {
        "arrival": np.array([us_to_ticks(a) for a in arrival_us], np.int64),
        "kind": np.asarray(kinds, np.int64),
        "plane": planes,
        "node": chips,
        "row": chips // cfg.cols,
        "nbytes": np.asarray(nbytes, np.int64),
        "req": np.arange(n, dtype=np.int64),
    }
