"""Algorithm-1 routing: reference properties + JAX engine parity."""
import numpy as np
import pytest

from repro.core import build_mesh, make_scout_fn, minimal_ports, scout_route_ref
from repro.core.rng import seed_for_scout
from repro.core.topology import all_xy_paths, xy_path_links

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYP = True
except Exception:  # pragma: no cover
    HAVE_HYP = False


def test_mesh_tables_consistent():
    topo = build_mesh(8, 8)
    assert topo.n_nodes == 64 and topo.n_links == 112  # paper §6.6: 112 links
    # every link appears on exactly two (node, port) slots, opposite directions
    counts = np.zeros(topo.n_links, dtype=int)
    for n in range(topo.n_nodes):
        for p in range(4):
            l = topo.port_link[n, p]
            if l >= 0:
                counts[l] += 1
                nb = topo.port_neighbor[n, p]
                assert topo.port_link[nb, (p + 2) % 4] == l  # OPPOSITE port
    assert (counts == 2).all()


def test_xy_paths_minimal():
    topo = build_mesh(4, 6)
    paths, hops = all_xy_paths(topo)
    for f in range(topo.n_fcs):
        src = int(topo.fc_node[f])
        r0, c0 = divmod(src, topo.cols)
        for n in range(topo.n_nodes):
            r1, c1 = divmod(n, topo.cols)
            assert hops[f, n] == abs(r0 - r1) + abs(c0 - c1)
            p = paths[f, n]
            assert (p[: hops[f, n]] >= 0).all() and (p[hops[f, n]:] == -1).all()


def test_scout_empty_network_is_minimal():
    """On an idle mesh the scout must find a minimal path (no misroutes)."""
    topo = build_mesh(8, 8)
    busy = np.zeros(topo.n_links, dtype=bool)
    for trial in range(100):
        rs = np.random.RandomState(trial)
        src = int(topo.fc_node[rs.randint(8)])
        dst = int(rs.randint(64))
        res = scout_route_ref(topo, src, dst, busy, seed_for_scout(1, trial))
        assert res.success
        assert res.hops == res.minimal_hops
        assert res.misroutes == 0 and res.backtracks == 0


def test_scout_path_is_connected_and_conflict_free():
    topo = build_mesh(8, 8)
    rs = np.random.RandomState(7)
    found_nonminimal = False
    for trial in range(400):
        busy = rs.rand(topo.n_links) < rs.uniform(0, 0.7)
        src = int(topo.fc_node[rs.randint(8)])
        dst = int(rs.randint(64))
        res = scout_route_ref(topo, src, dst, busy.copy(), seed_for_scout(3, trial))
        if not res.success:
            continue
        # no reserved link was previously busy
        assert not busy[res.path_links].any()
        # links are distinct (each output port reserved at most once ⇒ no dup)
        assert len(set(res.path_links.tolist())) == len(res.path_links)
        # path connects src to dst through neighbors
        assert res.path_nodes[0] == src and res.path_nodes[-1] == dst
        if res.hops > res.minimal_hops:
            found_nonminimal = True
    assert found_nonminimal, "non-minimal routing never exercised"


def test_scout_livelock_bound():
    """DFS steps are bounded by the livelock rule (≤ ~8·n_nodes)."""
    topo = build_mesh(8, 8)
    rs = np.random.RandomState(11)
    for trial in range(200):
        busy = rs.rand(topo.n_links) < 0.9
        src = int(topo.fc_node[rs.randint(8)])
        dst = int(rs.randint(64))
        res = scout_route_ref(topo, src, dst, busy, seed_for_scout(5, trial))
        assert res.steps <= 8 * topo.n_nodes + 8


def test_scout_succeeds_iff_reachable():
    """With a fully idle or fully busy mesh, success is deterministic."""
    topo = build_mesh(4, 4)
    idle = np.zeros(topo.n_links, bool)
    full = np.ones(topo.n_links, bool)
    assert scout_route_ref(topo, 0, 15, idle, 12345).success
    r = scout_route_ref(topo, 0, 15, full, 12345)
    assert not r.success
    # src == dst trivially succeeds with zero hops even on a busy mesh
    r2 = scout_route_ref(topo, 5, 5, full, 1)
    assert r2.success and r2.hops == 0


@pytest.mark.parametrize("rows,cols", [(4, 4), (8, 8), (4, 16), (16, 4), (3, 5)])
def test_jax_engine_matches_reference(rows, cols):
    topo = build_mesh(rows, cols)
    fn = make_scout_fn(topo)
    rs = np.random.RandomState(rows * 100 + cols)
    for trial in range(60):
        busy = rs.rand(topo.n_links) < rs.choice([0.0, 0.3, 0.6, 0.9])
        src = int(topo.fc_node[rs.randint(topo.n_fcs)])
        dst = int(rs.randint(topo.n_nodes))
        seed = seed_for_scout(42 + rows, trial)
        ref = scout_route_ref(topo, src, dst, busy.copy(), seed)
        out = fn(src, dst, busy, np.uint32(seed))
        assert bool(out.success) == ref.success, (trial, src, dst)
        assert int(out.steps) == ref.steps
        if ref.success:
            mask = np.zeros(topo.n_links, bool)
            mask[ref.path_links] = True
            assert np.array_equal(np.asarray(out.path_mask), mask)
            assert int(out.hops) == ref.hops
            assert int(out.misroutes) == ref.misroutes


def test_minimal_ports_cases():
    topo = build_mesh(8, 8)
    # node (2,3)=19 -> dst (5,6)=46: Diff_x>0, Diff_y>0 -> RIGHT & UP
    assert set(minimal_ports(topo, 19, 46)) == {0, 1}
    # dst west of node: LEFT only
    assert minimal_ports(topo, 19, 17) == [2]
    # same node: no minimal ports (ejection)
    assert minimal_ports(topo, 19, 19) == []


if HAVE_HYP:

    @settings(max_examples=60, deadline=None)
    @given(
        rows=st.integers(2, 8),
        cols=st.integers(2, 8),
        seed=st.integers(0, 2**31 - 1),
        density=st.floats(0.0, 1.0),
    )
    def test_property_scout_never_reserves_busy_link(rows, cols, seed, density):
        topo = build_mesh(rows, cols)
        rs = np.random.RandomState(seed % 100000)
        busy = rs.rand(topo.n_links) < density
        src = int(topo.fc_node[rs.randint(topo.n_fcs)])
        dst = int(rs.randint(topo.n_nodes))
        res = scout_route_ref(topo, src, dst, busy.copy(), seed_for_scout(seed, 0))
        if res.success:
            assert not busy[res.path_links].any()
            assert res.hops >= res.minimal_hops
