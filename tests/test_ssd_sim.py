"""SSD simulator behaviour: §3.1 analytic example, design orderings, FTL."""
import numpy as np
import pytest

from repro.ssd import cost_optimized, decompose_trace, perf_optimized, simulate
from repro.ssd.config import us_to_ticks
from repro.ssd.ftl import FTL, KIND_READ, KIND_WRITE
from repro.ssd.sim import _nominal_order
from repro.traces.generator import gen_trace, to_pages


def _mk_txns(arrival_us, kinds, planes, nbytes, cfg):
    n = len(arrival_us)
    planes = np.asarray(planes, np.int64)
    chips = planes // (cfg.dies_per_chip * cfg.planes_per_die)
    return {
        "arrival": np.array([us_to_ticks(a) for a in arrival_us], np.int64),
        "kind": np.asarray(kinds, np.int64),
        "plane": planes,
        "node": chips,
        "row": chips // cfg.cols,
        "nbytes": np.asarray(nbytes, np.int64),
        "req": np.arange(n, dtype=np.int64),
    }


class TestSection31Example:
    """Reproduce the paper's §3.1 two-read service-time example exactly:
    conflicting reads on one channel: CMD + RD + XFER + XFER = 11.01 us;
    reads on two different channels: CMD + RD + XFER = 7.01 us.
    (Latencies per the paper: CMD 10 ns, RD 3 us, XFER 4 us.)"""

    def _cfg(self):
        # per-§3.1 numbers: XFER of one 4KB page = 4 us exactly
        return perf_optimized(bus_protocol_ovh_ns=0.0, chan_gbps=1.024)

    def test_same_channel_conflict(self):
        cfg = self._cfg()
        # two reads to two different chips on channel 0 (planes on chips 0, 1)
        txns = _mk_txns([0, 0], [0, 0], [0, 2], [4096, 4096], cfg)
        r = simulate(cfg, txns, "baseline")
        total_us = r.exec_ticks / 100.0
        assert total_us == pytest.approx(11.01, abs=0.03)
        assert r.conflict.sum() == 1  # the second read waits on the channel

    def test_different_channels_no_conflict(self):
        cfg = self._cfg()
        # chips 0 and 8 (channel 0 and 1)
        txns = _mk_txns([0, 0], [0, 0], [0, 16], [4096, 4096], cfg)
        r = simulate(cfg, txns, "baseline")
        total_us = r.exec_ticks / 100.0
        assert total_us == pytest.approx(7.01, abs=0.03)
        assert r.conflict.sum() == 0

    def test_ideal_never_conflicts_on_distinct_chips(self):
        cfg = self._cfg()
        txns = _mk_txns([0] * 8, [0] * 8, [2 * c for c in range(8)],
                        [4096] * 8, cfg)
        r = simulate(cfg, txns, "ideal")
        assert r.conflict.sum() == 0
        assert r.exec_ticks / 100.0 == pytest.approx(7.01, abs=0.03)


def _intense_txns(cfg, n, seed=3, wl="src2_1"):
    tr = gen_trace(wl, n, seed=seed)
    tr = dict(tr)
    tr["arrival_us"] = tr["arrival_us"] / 16.0  # intensify
    pages = to_pages(tr, cfg.page_bytes)
    return decompose_trace(
        cfg, pages, footprint_pages=int(pages["footprint_pages"])
    )


@pytest.fixture(scope="module")
def behaviour_runs():
    """One full-geometry sweep shared by the behaviour assertions below —
    per-design ``simulate`` is bit-identical to its sweep lane (enforced by
    tests/test_designs.py), so asserting on sweep lanes loses nothing."""
    from repro.ssd import simulate_sweep

    cfg = perf_optimized()
    txns = _intense_txns(cfg, 250)
    designs = ("baseline", "nossd", "venice", "venice_hold", "ideal")
    return dict(zip(designs, simulate_sweep(cfg, txns, designs)))


class TestDesignBehaviour:
    def test_venice_reduces_conflicts_vs_baseline(self, behaviour_runs):
        assert (behaviour_runs["venice"].conflict_rate()
                < behaviour_runs["baseline"].conflict_rate())

    def test_venice_not_slower_than_nossd(self, behaviour_runs):
        assert (behaviour_runs["venice"].exec_s
                <= behaviour_runs["nossd"].exec_s * 1.05)

    def test_ideal_is_fastest(self, behaviour_runs):
        for d in ["baseline", "venice", "nossd"]:
            assert (behaviour_runs["ideal"].exec_s
                    <= behaviour_runs[d].exec_s * 1.02)

    def test_completion_after_arrival_and_deterministic(self):
        cfg = cost_optimized()
        txns = _intense_txns(cfg, 200)
        r1 = simulate(cfg, txns, "venice")
        r2 = simulate(cfg, txns, "venice")
        assert (r1.latency >= 0).all()
        assert np.array_equal(r1.completion, r2.completion)  # same seed

    def test_venice_hold_wastes_link_hours(self, behaviour_runs):
        """Ablation: holding the circuit across tR occupies more link-ticks."""
        assert (behaviour_runs["venice_hold"].link_hold_ticks
                > behaviour_runs["venice"].link_hold_ticks)

    def test_energy_accounting_consistent(self, behaviour_runs):
        r = behaviour_runs["venice"]
        assert r.energy_j == pytest.approx(
            r.flash_energy_j + r.transfer_energy_j + r.static_energy_j
        )
        assert r.avg_power_w > 0


class TestFTL:
    def test_l2p_roundtrip_and_out_of_place(self):
        cfg = perf_optimized()
        ftl = FTL(cfg, n_lpns=4096)
        p1 = ftl.write_page(7, None, 0)
        assert ftl.read_page(7) == p1
        p2 = ftl.write_page(7, None, 0)
        assert p2 != p1  # out-of-place
        assert ftl.read_page(7) == p2
        assert ftl.p2l[p1] == -1  # old page invalidated

    def test_gc_triggers_and_recovers_space(self):
        cfg = perf_optimized(pages_per_block=16)
        ftl = FTL(cfg, n_lpns=2048, overprovision=1.15)
        out = []
        rs = np.random.RandomState(0)
        for i in range(20000):
            ftl.write_page(int(rs.randint(2048)), out, 0)
        assert ftl.gc_events > 0
        assert ftl.gc_page_moves > 0
        assert any(k == 2 for (_, k, _, _, _) in out)  # erases emitted
        # all lpns still resolve
        for lpn in range(0, 2048, 97):
            assert ftl.read_page(lpn) >= 0

    def test_wear_leveling_spreads_erases(self):
        cfg = perf_optimized(pages_per_block=16)
        ftl = FTL(cfg, n_lpns=1024, overprovision=1.2)
        rs = np.random.RandomState(1)
        for i in range(30000):
            ftl.write_page(int(rs.randint(1024)), None, 0)
        per_plane_max = ftl.erase_count.max(axis=1)
        per_plane_mean = ftl.erase_count.mean(axis=1)
        busy = per_plane_mean > 1
        assert (per_plane_max[busy] <= per_plane_mean[busy] * 3 + 4).all()

    def test_chunked_striping_keeps_runs_on_one_channel(self):
        cfg = perf_optimized()
        ftl = FTL(cfg, n_lpns=4096)
        ppns = [ftl.write_page(l, None, 0) for l in range(cfg.chunk_pages)]
        planes = {ftl.plane_of_ppn(p) for p in ppns}
        assert len(planes) == 1  # one chunk -> one plane
        # the next cfg.cols-1 chunks stay on the same channel, different chips
        chans = set()
        for c in range(cfg.cols):
            ppn = ftl.write_page(4000 + c * cfg.chunk_pages, None, 0)
            chip = ftl.chip_of_plane(ftl.plane_of_ppn(ppn))
            chans.add(chip // cfg.cols)
        assert len(chans) <= 2

    def test_decompose_maps_all_requests(self):
        cfg = cost_optimized()
        tr = gen_trace("hm_0", 200, seed=2)
        pages = to_pages(tr, cfg.page_bytes)
        txns = decompose_trace(cfg, pages, footprint_pages=int(pages["footprint_pages"]))
        host = txns["req"] >= 0
        assert txns.n_requests == 200
        assert set(np.unique(txns["req"][host])) == set(range(200))
        assert (txns["node"] == txns["plane"] // 2).all()
        assert (txns["row"] == txns["node"] // cfg.cols).all()


def test_nominal_order_is_plane_causal():
    """Per plane, nominal order must preserve arrival order (FIFO)."""
    cfg = perf_optimized()
    rs = np.random.RandomState(5)
    n = 500
    txns = {
        "arrival": np.sort(rs.randint(0, 10000, n)),
        "kind": rs.randint(0, 2, n),
        "plane": rs.randint(0, cfg.n_planes, n),
        "nbytes": np.full(n, 4096),
    }
    order = _nominal_order(cfg, txns)
    pos = np.empty(n, np.int64)
    pos[order] = np.arange(n)
    for p in np.unique(txns["plane"]):
        idx = np.flatnonzero(txns["plane"] == p)
        assert (np.diff(pos[idx]) > 0).all()


def test_venice_kscout_shortens_paths():
    """Beyond-paper k-scout: committing the fewest-hop scout of 3 must not
    lengthen average paths, and the sim must stay deterministic."""
    cfg = perf_optimized()
    txns = _intense_txns(cfg, 150, seed=4)
    v1 = simulate(cfg, txns, "venice")
    vk = simulate(cfg, txns, "venice_kscout")
    assert vk.hops[vk.hops > 0].mean() <= v1.hops[v1.hops > 0].mean() + 1e-9
    vk2 = simulate(cfg, txns, "venice_kscout")
    assert np.array_equal(vk.completion, vk2.completion)


@pytest.mark.slow
def test_full_geometry_sweep_parity_slow():
    """Heavy sweep: all nine registered designs on the full 8x8 geometry in
    one call, each lane bit-identical to its standalone simulation."""
    from repro.ssd import DESIGNS, simulate_sweep

    cfg = perf_optimized()
    txns = _intense_txns(cfg, 600)
    sweep = simulate_sweep(cfg, txns, DESIGNS, seeds=11)
    for lane, design in zip(sweep, DESIGNS):
        solo = simulate(cfg, txns, design, seed=11)
        assert np.array_equal(lane.completion, solo.completion), design
        assert np.array_equal(lane.conflict, solo.conflict), design
