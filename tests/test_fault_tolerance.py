"""Unit tests for ``repro.runtime.fault_tolerance`` (ISSUE 8).

The module backs the compile-server watchdog (``sweep_plan``), so its
edge semantics are load-bearing: the heartbeat deadline is strict
(``now - t > timeout``, not >=), straggler strikes reset on any on-time
step, and ``replan_mesh`` never emits a mesh that splits a model-parallel
group.
"""
import pytest

from repro.runtime.fault_tolerance import (ElasticPlan, HeartbeatMonitor,
                                           StragglerDetector, replan_mesh)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


class TestHeartbeatMonitor:
    def test_deadline_edge_is_strict(self):
        clk = FakeClock()
        mon = HeartbeatMonitor(["a", "b"], timeout_s=10.0, clock=clk)
        clk.t = 10.0  # exactly at the deadline: still alive
        assert mon.dead_hosts() == []
        assert sorted(mon.alive()) == ["a", "b"]
        clk.t = 10.0 + 1e-9  # one tick past: dead
        assert sorted(mon.dead_hosts()) == ["a", "b"]
        assert mon.alive() == []

    def test_beat_resets_deadline(self):
        clk = FakeClock()
        mon = HeartbeatMonitor(["a", "b"], timeout_s=10.0, clock=clk)
        clk.t = 9.0
        mon.beat("a")
        clk.t = 15.0  # b is 15s silent, a only 6s
        assert mon.dead_hosts() == ["b"]
        assert mon.alive() == ["a"]
        mon.beat("b")  # a late beat resurrects
        assert mon.dead_hosts() == []

    def test_construction_anchors_now(self):
        clk = FakeClock(100.0)
        mon = HeartbeatMonitor(["a"], timeout_s=1.0, clock=clk)
        assert mon.dead_hosts() == []  # not dead at birth


class TestStragglerDetector:
    def test_patience_accumulates_then_flags(self):
        det = StragglerDetector(k=2.0, deadline_floor_s=0.0, patience=3)
        step = {"a": 1.0, "b": 1.0, "c": 10.0}  # deadline = 2*1.0
        assert det.observe_step(step) == []
        assert det.observe_step(step) == []
        assert det.observe_step(step) == ["c"]  # third strike
        assert det.observe_step(step) == ["c"]  # stays flagged

    def test_on_time_step_resets_strikes(self):
        det = StragglerDetector(k=2.0, deadline_floor_s=0.0, patience=2)
        slow = {"a": 1.0, "b": 1.0, "c": 10.0}
        ok = {"a": 1.0, "b": 1.0, "c": 1.0}
        assert det.observe_step(slow) == []
        assert det.observe_step(ok) == []  # strike reset
        assert det.observe_step(slow) == []  # back to one strike
        assert det.observe_step(slow) == ["c"]

    def test_deadline_floor_masks_fast_steps(self):
        """Sub-floor jitter is never a strike: 3x the median still beats
        the absolute floor."""
        det = StragglerDetector(k=2.0, deadline_floor_s=1.0, patience=1)
        assert det.observe_step({"a": 0.1, "b": 0.1, "c": 0.3}) == []
        # past the floor the relative rule takes over
        assert det.observe_step({"a": 1.0, "b": 1.0, "c": 3.0}) == ["c"]

    def test_empty_step_is_noop(self):
        det = StragglerDetector(patience=1)
        assert det.observe_step({}) == []


class TestReplanMesh:
    def test_too_few_survivors_raises(self):
        with pytest.raises(ValueError):
            replan_mesh(15, model_parallel=16)
        replan_mesh(16, model_parallel=16)  # boundary survives

    def test_whole_pod_slices_keep_full_data_axis(self):
        plan = replan_mesh(1024, model_parallel=16)
        assert (plan.pods, plan.data, plan.model) == (4, 16, 16)
        assert plan.devices == 1024
        assert plan.global_batch == 4 * 16

    def test_sub_slice_shrinks_data_axis(self):
        plan = replan_mesh(255, model_parallel=16)  # < one 256-dev slice
        assert plan.pods == 1
        assert plan.data == 255 // 16 == 15
        assert plan.model == 16
        assert plan.devices <= 255

    def test_reshard_only_when_shape_changes(self):
        prev = replan_mesh(512, model_parallel=16)
        assert prev.reshard  # no prior plan
        same = replan_mesh(512, model_parallel=16, prev=prev)
        assert not same.reshard
        shrunk = replan_mesh(511, model_parallel=16, prev=prev)
        assert shrunk.reshard
        assert isinstance(shrunk, ElasticPlan)
